//! Streaming KMeans end-to-end (paper §6.4's first workload).
//!
//! MASS cluster-source producers stream batches of 5,000 3-D points
//! (0.32 MB messages) through the pilot-managed broker; the MASA KMeans
//! processor scores each batch against the model with the Pallas
//! assignment kernel (AOT artifact `kmeans_score`) and applies the
//! MLlib-style decayed update (`kmeans_update`).  The example verifies
//! the streaming model actually *locks onto the source's cluster
//! structure*: the final within-cluster variance (inertia per point)
//! must be a small fraction of the raw data variance.
//!
//! Run with: `cargo run --release --example kmeans_streaming`

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{
    MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind,
};
use pilot_streaming::pilot::{
    DaskDescription, KafkaDescription, PilotComputeService, SparkDescription,
};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::Result;

fn main() -> Result<()> {
    let runtime = ModelRuntime::load_default()?;
    let k = runtime.manifest().kmeans.k;

    // Pilot-managed deployment: 1 broker, 1 producer, 1 processing node.
    let service = PilotComputeService::new(Machine::unthrottled(4));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1))?;
    let (dask, producers) =
        service.start_dask(DaskDescription::new(1).with_config("workers_per_node", "2"))?;
    let (spark, engine) =
        service.start_spark(SparkDescription::new(1).with_config("executors_per_node", "2"))?;
    cluster.create_topic("points", 4)?;

    // MASA: streaming KMeans with a short window for the demo.
    let masa = MasaApp::new(
        MasaConfig::new(ProcessorKind::KMeans, "points", Duration::from_millis(150)),
        runtime,
    );
    println!("compiling kmeans artifacts...");
    masa.processor.warmup()?;
    let job = masa.start(&engine, cluster.clone())?;

    // MASS: the paper's `cluster` source — points around k centers.
    let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: k }, "points");
    cfg.messages_per_producer = 15;
    let mass = MassSource::new(cfg);
    println!("streaming {} messages of 5,000 points...", 2 * 15);
    let report = mass.run(&producers, &cluster, 2)?;
    println!(
        "produced {} msgs ({:.2} MB/s)",
        report.messages,
        report.mb_rate()
    );

    // Drain.
    let deadline = std::time::Instant::now() + Duration::from_secs(300);
    while job.stats().processed.messages() < report.messages
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(100));
    }
    let stats = job.stop();

    let model = masa.processor.model();
    println!(
        "processed {} msgs; model updates: {}; exec {:.2} ms/msg",
        stats.processed.messages(),
        model.updates,
        masa.processor.stats.exec_secs.mean_secs() * 1e3
    );
    println!(
        "inertia: first batch {:.0} -> final {:.0}",
        model.first_inertia, model.last_inertia
    );
    // Quality: within-cluster variance must be a small fraction of the
    // raw data variance.  Cluster centers are uniform over a +-50 cube
    // (variance ~ 100^2/12 per dim, 3 dims ~ 2500 per point); a learned
    // model leaves far less residual.
    let per_point = model.last_inertia / 5000.0;
    let data_variance = 2500.0;
    println!(
        "residual variance/point {per_point:.1} vs raw data variance {data_variance:.0} \
         ({:.1}% unexplained)",
        per_point / data_variance * 100.0
    );
    assert!(
        per_point < 0.2 * data_variance,
        "streaming model failed to lock on: residual {per_point}"
    );

    // Weights must be positive for (almost) all clusters.
    let live = model.weights.iter().filter(|w| **w > 0.0).count();
    println!("clusters with mass: {live}/{k}");

    let _ = Arc::strong_count(&masa.processor);
    service.stop_pilot(&spark)?;
    service.stop_pilot(&dask)?;
    service.stop_pilot(&kafka)?;
    Ok(())
}
