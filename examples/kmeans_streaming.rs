//! Streaming KMeans end-to-end (paper §6.4's first workload), on the
//! declarative application API.
//!
//! A `StreamingApp` spec wires MASS cluster-source producers (batches
//! of 5,000 3-D points, 0.32 MB messages) through the pilot-managed
//! broker into the MASA KMeans processor — the Pallas assignment kernel
//! (AOT artifact `kmeans_score`) plus the MLlib-style decayed update
//! (`kmeans_update`) — as one `.broker().source().stage()` chain.  The
//! example verifies the streaming model actually *locks onto the
//! source's cluster structure*: the final within-cluster variance
//! (inertia per point) must be a small fraction of the raw data
//! variance.
//!
//! Run with: `cargo run --release --example kmeans_streaming`

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::app::{SourceSpec, StageSpec, StreamingApp};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{MasaProcessor, MassConfig, ProcessorKind, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::Result;

fn main() -> Result<()> {
    let runtime = ModelRuntime::load_default()?;
    let k = runtime.manifest().kmeans.k;
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(4)));
    let processor = MasaProcessor::new(ProcessorKind::KMeans, runtime);

    let total_msgs = 30u64;
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("points", 4)])
        .source(
            SourceSpec::mass(MassConfig::new(
                SourceKind::KmeansRandom { n_centroids: k },
                "points",
            ))
            .with_producers(2)
            .with_total_messages(total_msgs),
        )
        .stage(
            StageSpec::new("kmeans", "points", processor.clone())
                .with_window(Duration::from_millis(150))
                .with_executors_per_node(2),
        )
        .build()?;

    println!("compiling kmeans artifacts...");
    let handle = app.launch(&service)?; // warmup runs before the job starts
    println!("streaming {total_msgs} messages of 5,000 points...");
    let produced = handle.await_sources()?;
    println!(
        "produced {} msgs ({:.2} MB/s)",
        produced[0].messages,
        produced[0].mb_rate()
    );

    let report = handle.drain_and_stop()?;
    assert!(report.drained, "burst failed to drain");
    assert_eq!(
        report.processed_messages(),
        report.produced_messages(),
        "pipeline dropped messages"
    );

    let model = processor.model();
    println!(
        "processed {} msgs; model updates: {}; exec {:.2} ms/msg",
        report.processed_messages(),
        model.updates,
        processor.stats.exec_secs.mean_secs() * 1e3
    );
    println!(
        "inertia: first batch {:.0} -> final {:.0}",
        model.first_inertia, model.last_inertia
    );
    // Quality: within-cluster variance must be a small fraction of the
    // raw data variance.  Cluster centers are uniform over a +-50 cube
    // (variance ~ 100^2/12 per dim, 3 dims ~ 2500 per point); a learned
    // model leaves far less residual.
    let per_point = model.last_inertia / 5000.0;
    let data_variance = 2500.0;
    println!(
        "residual variance/point {per_point:.1} vs raw data variance {data_variance:.0} \
         ({:.1}% unexplained)",
        per_point / data_variance * 100.0
    );
    assert!(
        per_point < 0.2 * data_variance,
        "streaming model failed to lock on: residual {per_point}"
    );

    // Weights must be positive for (almost) all clusters.
    let live = model.weights.iter().filter(|w| **w > 0.0).count();
    println!("clusters with mass: {live}/{k}");
    println!("all pilots stopped; free nodes: {}", service.machine().free_nodes());
    Ok(())
}
