//! Quickstart: one declarative `StreamingApp` spec — pilot-managed
//! broker, paced source, processing stage — replaces the hand-wired
//! assembly of the paper's Listings 2-6.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use pilot_streaming::app::{CountingProcessor, SourceSpec, StageSpec, StreamingApp};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{MassConfig, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};
use pilot_streaming::Result;

fn main() -> Result<()> {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
    let counter = CountingProcessor::new();
    let mut points = MassConfig::new(SourceKind::KmeansStatic, "points");
    points.points_per_msg = 500;
    points.target_msg_bytes = Some(0); // unpadded: keep the smoke run snappy

    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("points", 4)])
        .source(SourceSpec::mass(points).with_producers(2).with_total_messages(25))
        .stage(StageSpec::new("count", "points", counter.clone()))
        .build()?;

    let handle = app.launch(&service)?;
    for (pilot, s) in handle.startup_breakdowns() {
        println!("{pilot}: queue {:.1}s + boot {:.1}s", s.queue_wait_secs, s.bootstrap_secs);
    }
    handle.await_sources()?;
    let report = handle.drain_and_stop()?;
    println!("produced {} msgs, processed {} msgs, terminal lag {}",
        report.produced_messages(), report.processed_messages(), report.terminal_lag());
    assert!(report.drained && counter.messages() == 25, "quickstart lost messages");
    println!("all pilots stopped; free nodes: {}", service.machine().free_nodes());
    Ok(())
}
