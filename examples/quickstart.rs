//! Quickstart: the paper's Listings 2-6 as one runnable program.
//!
//! * Listing 2 — create a pilot-managed Spark cluster from a
//!   Pilot-Compute-Description;
//! * Listing 4 — extend it at runtime by referencing the parent pilot;
//! * Listing 5 — submit a framework-agnostic Compute-Unit;
//! * Listing 6 — use the native framework context directly.
//!
//! Run with: `cargo run --release --example quickstart`

use pilot_streaming::cluster::Machine;
use pilot_streaming::cu::{submit_unit, ComputeUnitDescription};
use pilot_streaming::pilot::{DaskDescription, PilotComputeService, SparkDescription};
use pilot_streaming::Result;

fn main() -> Result<()> {
    // An 8-node Wrangler-like machine managed by a modeled SLURM queue.
    let machine = Machine::wrangler(8);
    let service = PilotComputeService::new(machine);

    // --- Listing 2: pilot_compute_description for a Spark cluster ----
    let (spark_pilot, engine) = service.start_spark(
        SparkDescription::new(2).with_config("executors_per_node", "2"),
    )?;
    let startup = spark_pilot.startup().unwrap();
    println!(
        "spark pilot {} RUNNING: {} nodes, {} executors",
        spark_pilot.id(),
        spark_pilot.nodes().len(),
        engine.executor_count()
    );
    println!(
        "  startup: queue {:.1}s + bootstrap {:.1}s = {:.1}s (modeled Wrangler)",
        startup.queue_wait_secs,
        startup.bootstrap_secs,
        startup.total_secs()
    );

    // --- Listing 5: framework-agnostic compute unit ------------------
    // def compute(x): return x*x ; pilot.submit(compute, 2)
    let cu = submit_unit(&spark_pilot, ComputeUnitDescription::new("square"), || {
        2 * 2
    })?;
    println!("compute unit result: {}", cu.wait()?);

    // --- Listing 6: native context (Spark-like map over a batch) -----
    let pool = engine.executor_pool();
    let futures: Vec<_> = [1, 2, 3]
        .into_iter()
        .map(|x| pool.submit(move |_| x * x).unwrap())
        .collect();
    let mapped: Vec<i32> = futures.into_iter().map(|f| f.wait().unwrap()).collect();
    println!("native map([1,2,3], x*x) = {mapped:?}");

    // --- Listing 4: extend the cluster by referencing the parent -----
    let before = engine.executor_count();
    let extension = service.extend_pilot(&spark_pilot, 2)?;
    println!(
        "extended {} -> {} executors via pilot {}",
        before,
        engine.executor_count(),
        extension.id()
    );
    // Stopping the extension resizes the cluster back down.
    service.stop_pilot(&extension)?;
    println!("extension stopped; machine free nodes: {}", service.machine().free_nodes());

    // The same CU also runs on a Dask pilot (interoperability).
    let (dask_pilot, _dask) = service.start_dask(DaskDescription::new(1))?;
    let cu = submit_unit(&dask_pilot, ComputeUnitDescription::new("square"), || 2 * 2)?;
    println!("same compute unit on dask pilot: {}", cu.wait()?);

    service.stop_pilot(&dask_pilot)?;
    service.stop_pilot(&spark_pilot)?;
    println!("all pilots stopped; free nodes: {}", service.machine().free_nodes());
    Ok(())
}
