//! End-to-end light-source pipeline — the repo's full-system driver
//! (EXPERIMENTS.md §End-to-end).
//!
//! Exercises every layer on a real workload: pilot-managed Kafka /
//! Dask / Spark deployments on the simulated machine; MASS streaming
//! APS-format frames (2 MB messages, the paper's LCLS-like feed); the
//! micro-batch engine scheduling one task per partition; GridRec
//! reconstruction through the PJRT-compiled Pallas backprojection
//! artifact; a *runtime pilot extension* mid-stream (the paper's core
//! capability); and a final reconstruction-quality check against the
//! ground-truth phantom.
//!
//! Run with: `cargo run --release --example light_source_pipeline`

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{
    MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind,
};
use pilot_streaming::pilot::{
    DaskDescription, KafkaDescription, PilotComputeService, SparkDescription,
};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::Result;

fn main() -> Result<()> {
    let runtime = ModelRuntime::load_default()?;
    let tomo = runtime.manifest().tomo.clone();
    let template = Arc::new(runtime.read_f32_file("template_sinogram.bin")?);
    let phantom = runtime.read_f32_file("phantom.bin")?;

    // ---- Pilot-managed deployment (paper Fig 3/4 control flow) ------
    let service = PilotComputeService::new(Machine::unthrottled(8));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1))?;
    let (dask, producers) =
        service.start_dask(DaskDescription::new(1).with_config("workers_per_node", "2"))?;
    let (spark, engine) =
        service.start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))?;
    for p in [&kafka, &dask, &spark] {
        let s = p.startup().unwrap();
        println!(
            "pilot {:<16} nodes={} startup {:.1}s (queue {:.1} + bootstrap {:.1})",
            p.id(),
            p.nodes().len(),
            s.total_secs(),
            s.queue_wait_secs,
            s.bootstrap_secs
        );
    }
    cluster.create_topic("aps-frames", 4)?;

    // ---- MASA: GridRec reconstruction job ----------------------------
    let masa = MasaApp::new(
        MasaConfig::new(ProcessorKind::GridRec, "aps-frames", Duration::from_millis(250)),
        runtime.clone(),
    );
    println!("compiling gridrec artifact (Pallas backprojection, AOT via PJRT)...");
    masa.processor.warmup()?;
    let job = masa.start(&engine, cluster.clone())?;

    // ---- MASS: template source streaming APS frames -------------------
    let total_msgs = 24u64;
    let mut cfg = MassConfig::new(
        SourceKind::Lightsource {
            template: template.clone(),
        },
        "aps-frames",
    );
    cfg.messages_per_producer = (total_msgs / 2) as usize;
    let mass = MassSource::new(cfg);
    println!("streaming {total_msgs} APS frames (2 MB each)...");
    let t0 = Instant::now();
    let producer_handle = {
        let mass_cfg = mass.config().clone();
        let cluster2 = cluster.clone();
        let producers2 = producers.clone();
        std::thread::spawn(move || MassSource::new(mass_cfg).run(&producers2, &cluster2, 2))
    };

    // ---- Mid-stream pilot extension (paper Listing 4) ----------------
    std::thread::sleep(Duration::from_millis(300));
    let before = engine.executor_count();
    let extension = service.extend_pilot(&spark, 1)?;
    println!(
        "mid-stream extend: {} -> {} executors (pilot {})",
        before,
        engine.executor_count(),
        extension.id()
    );

    let report = producer_handle
        .join()
        .expect("producer thread")?;
    println!(
        "producer side: {} msgs, {:.1} MB/s",
        report.messages,
        report.mb_rate()
    );

    // ---- Drain and report --------------------------------------------
    let deadline = Instant::now() + Duration::from_secs(600);
    while job.stats().processed.messages() < report.messages && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(100));
    }
    let stats = job.stop();
    let elapsed = t0.elapsed().as_secs_f64();
    assert_eq!(
        stats.processed.messages(),
        report.messages,
        "pipeline dropped messages"
    );
    println!("--- end-to-end results -------------------------------------");
    println!(
        "frames processed   : {} in {:.1} s  ({:.1} msg/s, {:.1} MB/s end-to-end)",
        stats.processed.messages(),
        elapsed,
        stats.processed.messages() as f64 / elapsed,
        stats.processed.bytes() as f64 / 1e6 / elapsed,
    );
    println!(
        "reconstruction     : {:.1} ms/frame (p50), {:.1} ms (p99)",
        masa.processor.stats.exec_secs.p50_secs() * 1e3,
        masa.processor.stats.exec_secs.p99_secs() * 1e3,
    );
    println!(
        "e2e frame latency  : p50 {:.2} s, p99 {:.2} s",
        masa.processor.stats.e2e_latency.p50_secs(),
        masa.processor.stats.e2e_latency.p99_secs(),
    );

    // Reconstruction quality vs ground truth (interior RMSE).
    let img = masa.processor.last_image();
    let (h, w) = (tomo.img_h, tomo.img_w);
    let mut se = 0.0f64;
    let mut n = 0usize;
    for i in 16..h - 16 {
        for j in 16..w - 16 {
            let d = (img[i * w + j] - phantom[i * w + j]) as f64;
            se += d * d;
            n += 1;
        }
    }
    let rmse = (se / n as f64).sqrt();
    println!("reconstruction RMSE vs phantom (interior): {rmse:.4}");
    assert!(rmse < 0.12, "reconstruction quality regression: {rmse}");

    service.stop_pilot(&extension)?;
    service.stop_pilot(&spark)?;
    service.stop_pilot(&dask)?;
    service.stop_pilot(&kafka)?;
    println!("pipeline complete; all pilots stopped");
    Ok(())
}
