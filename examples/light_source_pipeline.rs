//! End-to-end light-source pipeline — the repo's full-system driver
//! (EXPERIMENTS.md §End-to-end), on the declarative application API.
//!
//! One `StreamingApp` spec exercises every layer on a real workload:
//! pilot-managed Kafka / Dask / Spark deployments on the simulated
//! machine; MASS streaming APS-format frames (2 MB messages, the
//! paper's LCLS-like feed); the micro-batch engine scheduling one task
//! per partition; GridRec reconstruction through the PJRT-compiled
//! Pallas backprojection artifact; a *runtime pilot extension*
//! mid-stream via `AppHandle::extend` (the paper's core capability);
//! and a final reconstruction-quality check against the ground-truth
//! phantom.  Teardown is `drain_and_stop` — fence the source, drain
//! consumer lag to zero, stop jobs and pilots — instead of the old
//! sleep-and-hope loop.
//!
//! Run with: `cargo run --release --example light_source_pipeline`

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::app::{SourceSpec, StageSpec, StreamingApp};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::{MasaProcessor, MassConfig, ProcessorKind, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::Result;

fn main() -> Result<()> {
    let runtime = ModelRuntime::load_default()?;
    let tomo = runtime.manifest().tomo.clone();
    let template = Arc::new(runtime.read_f32_file("template_sinogram.bin")?);
    let phantom = runtime.read_f32_file("phantom.bin")?;
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));
    let processor = MasaProcessor::new(ProcessorKind::GridRec, runtime);

    // ---- The whole pipeline as one spec (paper Fig 3/4 control flow):
    // 24 APS frames split across 2 producers (remainders distribute —
    // no hand-computed total/2), reconstructed in 250 ms windows.
    let total_msgs = 24u64;
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("aps-frames", 4)])
        .source(
            SourceSpec::mass(MassConfig::new(
                SourceKind::Lightsource { template },
                "aps-frames",
            ))
            .with_producers(2)
            .with_total_messages(total_msgs),
        )
        .stage(
            StageSpec::new("recon", "aps-frames", processor.clone())
                .with_window(Duration::from_millis(250))
                .with_executors_per_node(1),
        )
        .build()?;

    println!("compiling gridrec artifact (Pallas backprojection, AOT via PJRT)...");
    let handle = app.launch(&service)?;
    // Streaming starts inside launch; stamp t0 here so the end-to-end
    // rate excludes artifact compilation and modeled pilot startup.
    let t0 = Instant::now();
    for (pilot, s) in handle.startup_breakdowns() {
        println!(
            "pilot {pilot:<16} startup {:.1}s (queue {:.1} + bootstrap {:.1})",
            s.total_secs(),
            s.queue_wait_secs,
            s.bootstrap_secs
        );
    }
    println!("streaming {total_msgs} APS frames (2 MB each)...");

    // ---- Mid-stream pilot extension (paper Listing 4) ----------------
    // The source streams in the background; grow the recon stage now.
    std::thread::sleep(Duration::from_millis(300));
    let extension = handle.extend("recon", 1)?;
    println!("mid-stream extend: recon stage grew via pilot {}", extension.id());

    let produced = handle.await_sources()?;
    println!(
        "producer side: {} msgs, {:.1} MB/s",
        produced[0].messages,
        produced[0].mb_rate()
    );

    // ---- Drain and report --------------------------------------------
    let report = handle.drain_and_stop()?;
    let elapsed = t0.elapsed().as_secs_f64();
    assert!(report.drained, "pipeline failed to drain");
    assert_eq!(
        report.processed_messages(),
        report.produced_messages(),
        "pipeline dropped messages"
    );
    println!("--- end-to-end results -------------------------------------");
    println!(
        "frames processed   : {} in {:.1} s  ({:.1} msg/s, {:.1} MB/s end-to-end)",
        report.processed_messages(),
        elapsed,
        report.processed_messages() as f64 / elapsed,
        report.stages[0].processed_bytes as f64 / 1e6 / elapsed,
    );
    println!(
        "reconstruction     : {:.1} ms/frame (p50), {:.1} ms (p99)",
        processor.stats.exec_secs.p50_secs() * 1e3,
        processor.stats.exec_secs.p99_secs() * 1e3,
    );
    println!(
        "e2e frame latency  : p50 {:.2} s, p99 {:.2} s",
        processor.stats.e2e_latency.p50_secs(),
        processor.stats.e2e_latency.p99_secs(),
    );

    // Reconstruction quality vs ground truth (interior RMSE).
    let img = processor.last_image();
    let (h, w) = (tomo.img_h, tomo.img_w);
    let mut se = 0.0f64;
    let mut n = 0usize;
    for i in 16..h - 16 {
        for j in 16..w - 16 {
            let d = (img[i * w + j] - phantom[i * w + j]) as f64;
            se += d * d;
            n += 1;
        }
    }
    let rmse = (se / n as f64).sqrt();
    println!("reconstruction RMSE vs phantom (interior): {rmse:.4}");
    assert!(rmse < 0.12, "reconstruction quality regression: {rmse}");

    println!(
        "pipeline complete; all pilots stopped (free nodes: {})",
        service.machine().free_nodes()
    );
    Ok(())
}
