//! Dynamic resource management under backpressure (paper §1, §4).
//!
//! "Minor changes in data rates ... can lead to backpressure and a
//! dysfunctional system.  Pilot-Streaming provides the ability to
//! overcome these problems by ... adding/removing resources at
//! runtime."
//!
//! This example demonstrates the mechanism on the real plane — consumer
//! lag as the backpressure signal, pilot extension as the remedy — and
//! then uses the simulation plane to show the same decision at paper
//! scale (when does adding processing nodes actually help?).
//!
//! Run with: `cargo run --release --example dynamic_scaling`

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::broker::Record;
use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::{StreamingJobConfig, TaskContext};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService, SparkDescription};
use pilot_streaming::sim::{CostModel, ProcessingScenario, ProcessingSim, SimMachine};
use pilot_streaming::Result;

fn main() -> Result<()> {
    // ---- Real plane: lag-driven extension ----------------------------
    let service = PilotComputeService::new(Machine::unthrottled(6));
    let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1))?;
    let (spark, engine) =
        service.start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))?;
    cluster.create_topic("load", 4)?;

    // A deliberately slow processor: 40 ms per message on 1 executor.
    let processor = |_: &TaskContext, recs: &[Record]| {
        std::thread::sleep(Duration::from_millis(40) * recs.len() as u32);
        Ok(())
    };
    let mut jc = StreamingJobConfig::new("load", Duration::from_millis(100));
    jc.group = "scaler".into();
    let job = engine.start_job(cluster.clone(), jc, Arc::new(processor))?;

    // Offer more load than one executor can absorb.
    for i in 0..120u64 {
        cluster.produce("load", (i % 4) as usize, 0, &[vec![0u8; 1024]])?;
    }
    std::thread::sleep(Duration::from_millis(600));
    let lag_before = cluster.group_lag("scaler", "load")?;
    println!("backpressure signal: consumer lag = {lag_before} messages");

    // React: extend the processing pilot (paper Listing 4).
    let extension = service.extend_pilot(&spark, 3)?;
    println!(
        "extended processing pilot: {} executors now",
        engine.executor_count()
    );

    // Lag must drain after scaling out.
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let mut lag_after = lag_before;
    while lag_after > 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(200));
        lag_after = cluster.group_lag("scaler", "load")?;
    }
    println!("lag after extension: {lag_after} (drained)");
    assert_eq!(lag_after, 0, "extension failed to drain the backlog");
    let stats = job.stop();
    println!(
        "processed {} messages across {} batches ({} fell behind the window before scaling)",
        stats.processed.messages(),
        stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        stats.behind.load(std::sync::atomic::Ordering::Relaxed),
    );

    service.stop_pilot(&extension)?;
    service.stop_pilot(&spark)?;
    service.stop_pilot(&kafka)?;

    // ---- Simulation plane: the same decision at paper scale ----------
    println!("\nwhat-if at Wrangler scale (paper-era costs, ML-EM, 4 brokers):");
    let sim = ProcessingSim::new(SimMachine::default(), CostModel::paper_era());
    for nodes in [1usize, 2, 4, 8] {
        let res = sim.run(&ProcessingScenario {
            processor: "mlem".into(),
            msg_bytes: 2e6,
            input_rate: 60.0,
            processing_nodes: nodes,
            broker_nodes: 4,
            partitions_per_node: 12,
            window_secs: 60.0,
            windows: 10,
        });
        println!(
            "  {nodes} processing nodes -> {:>6.1} msg/s (cores {:>3.0}% busy, behind {:>3.0}%)",
            res.msg_rate,
            res.core_util * 100.0,
            res.behind_fraction * 100.0
        );
    }
    println!(
        "scaling helps while executor cores < partitions (48); beyond that the \
         partition-parallelism cap binds — exactly the paper's §6.4 observation"
    );
    Ok(())
}
