//! Dynamic resource management under backpressure (paper §1, §4) —
//! *closed-loop and declarative*: the whole application, including both
//! autoscale loops, is one `StreamingApp` spec; no manual
//! `extend_pilot` calls anywhere.
//!
//! "Minor changes in data rates ... can lead to backpressure and a
//! dysfunctional system.  Pilot-Streaming provides the ability to
//! overcome these problems by ... adding/removing resources at
//! runtime."
//!
//! A bursty MASS source streams KMeans batches through the pilot-managed
//! broker into a KMeans consumer stage.  Every decision flows through
//! the two-stage pipeline: policies emit *intents*, and the planner
//! turns each intent into a costed plan (per-framework extension costs
//! weighed against drain benefit; broker-tier steps co-scheduled when
//! needed) before the controller actuates anything.  Two autoscale
//! specs watch the same stage signals:
//!
//! * the **processing loop** (threshold policy + hysteresis) extends the
//!   stage's Spark pilot while lag stays high and shrinks it back after
//!   the burst drains — with broker co-scheduling enabled, so plans may
//!   pair broker extensions with repartitions;
//! * the **broker loop** (a custom produce-rate policy, showing the
//!   pluggable [`ScalingPolicy`] SPI) adds a broker node while the
//!   offered rate saturates the cluster and releases it afterwards.
//!
//! The full step-by-step plan history lands on the handle's scaling
//! timelines (with each step's modeled cost in the `cost_s` column);
//! the run asserts a complete scale-up AND scale-down cycle happened,
//! then replays the planner's co-scheduled repartition +
//! broker-extension behaviour deterministically at 32-node Wrangler
//! scale on the simulation plane.
//!
//! Run with: `cargo run --release --example dynamic_scaling`

use std::sync::Arc;
use std::time::{Duration, Instant};

use pilot_streaming::app::{
    AutoscaleSpec, SourceSpec, StageSpec, StreamProcessor, StreamingApp,
};
use pilot_streaming::autoscale::{
    PartitionElastic, Planner, PlannerConfig, ScalingIntent, ScalingPolicy, SignalSnapshot,
    ThresholdPolicy,
};
use pilot_streaming::broker::Record;
use pilot_streaming::cluster::Machine;
use pilot_streaming::engine::TaskContext;
use pilot_streaming::metrics::ScalingAction;
use pilot_streaming::miniapp::{MasaProcessor, MassConfig, ProcessorKind, SourceKind};
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService, PilotScalingEvent};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::sim::{CostModel, ElasticScenario, ElasticSim, SimMachine};
use pilot_streaming::util::RateSchedule;
use pilot_streaming::Result;

/// Broker-side policy: scale the Kafka pilot on the *offered rate*
/// rather than lag (a saturated broker slows producers down; consumer
/// lag alone would mis-attribute that to the processing tier).
struct BrokerLoadPolicy {
    up_msgs_per_sec: f64,
    down_msgs_per_sec: f64,
    cooldown_secs: f64,
    last_action_t: f64,
}

impl ScalingPolicy for BrokerLoadPolicy {
    fn name(&self) -> &'static str {
        "broker-load"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> ScalingIntent {
        if s.t_secs - self.last_action_t < self.cooldown_secs {
            return ScalingIntent::Hold;
        }
        if s.produce_rate >= self.up_msgs_per_sec && s.nodes < s.max_nodes {
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleUp(1);
        }
        if s.produce_rate <= self.down_msgs_per_sec && s.nodes > s.min_nodes {
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleDown(1);
        }
        ScalingIntent::Hold
    }
}

fn main() -> Result<()> {
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(8)));

    // Every pilot lifecycle change is observable through the service's
    // scaling hooks — here they narrate the run (launch included).
    service.add_scaling_hook(Arc::new(|e: &PilotScalingEvent| {
        println!("[pilot-event] {:?}: {} ({} nodes)", e.kind, e.pilot_id, e.nodes);
    }));

    // ---- Consumer stage ----------------------------------------------
    // With AOT artifacts present the real PJRT-executed KMeans runs;
    // otherwise a stand-in with the same per-message cost keeps the
    // control problem identical.
    let mut points_per_msg = 1000;
    let masa = match ModelRuntime::load_default() {
        Ok(rt) if rt.warmup("kmeans_score").is_ok() => {
            points_per_msg = rt.manifest().kmeans.n_points;
            Some(MasaProcessor::new(ProcessorKind::KMeans, rt))
        }
        _ => None,
    };
    let processor: Arc<dyn StreamProcessor> = match &masa {
        Some(p) => {
            println!("consumer: MASA streaming KMeans (PJRT artifacts)");
            p.clone()
        }
        None => {
            println!("consumer: synthetic 25 ms/msg KMeans stand-in (`make artifacts` for real)");
            Arc::new(|_: &TaskContext, recs: &[Record]| {
                std::thread::sleep(Duration::from_millis(25) * recs.len() as u32);
                Ok(())
            })
        }
    };

    // ---- The whole application, both control loops included ----------
    // A 1.2 s burst far above what the single base executor absorbs,
    // then a trickle.  The real PJRT KMeans is much faster per message
    // than the stand-in, so the burst rate scales with the consumer.
    let burst_secs = 1.2;
    let per_producer_burst = if masa.is_some() { 250.0 } else { 50.0 };
    let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: 8 }, "load");
    cfg.points_per_msg = points_per_msg;
    cfg.messages_per_producer = (per_producer_burst * burst_secs) as usize + 6;
    cfg.schedule =
        Some(RateSchedule::starting_at(burst_secs, per_producer_burst).then(f64::INFINITY, 3.0));

    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("load", 8)])
        .source(SourceSpec::mass(cfg).with_producers(2))
        .stage(
            StageSpec::new("analyze", "load", processor)
                .with_window(Duration::from_millis(100))
                .with_executors_per_node(1),
        )
        .autoscale(
            AutoscaleSpec::for_stage(
                "analyze",
                ThresholdPolicy::new(24, 2)
                    .with_sustain(2)
                    .with_cooldown_secs(0.5)
                    .with_step(3),
            )
            .with_sample_interval(Duration::from_millis(100))
            .with_max_extension_nodes(3)
            .with_max_step(3)
            // The planner may co-schedule broker extensions with a
            // processing scale-up (saturation-triggered here; the
            // machine is unthrottled, so in this run they stay
            // hypothetical).
            .with_broker_coscheduling(),
        )
        .autoscale(
            AutoscaleSpec::for_broker(
                "analyze",
                BrokerLoadPolicy {
                    up_msgs_per_sec: 60.0,
                    down_msgs_per_sec: 10.0,
                    cooldown_secs: 1.0,
                    last_action_t: f64::NEG_INFINITY,
                },
            )
            .with_sample_interval(Duration::from_millis(200))
            .with_max_extension_nodes(1),
        )
        .build()?;
    let handle = app.launch(&service)?;

    println!(
        "offering a {:.0} msg/s burst, then a 6 msg/s trickle...",
        2.0 * per_producer_burst
    );
    let produced = handle.await_sources()?;
    println!(
        "produced {} msgs at {:.0} msg/s peak-inclusive",
        produced[0].messages,
        produced[0].msg_rate()
    );

    // ---- Watch the cycle complete -----------------------------------
    let timeline = handle.timeline("analyze").expect("processing timeline");
    let deadline = Instant::now() + Duration::from_secs(120);
    while Instant::now() < deadline {
        let drained = handle.lag("analyze")? == 0;
        let cycled = timeline.count(ScalingAction::Up) >= 1
            && timeline.count(ScalingAction::Down) >= 1
            && handle.extension_count("analyze") == Some(0);
        if drained && cycled {
            break;
        }
        std::thread::sleep(Duration::from_millis(100));
    }

    println!("\nprocessing-tier scaling timeline:");
    print!("{}", timeline.to_recorder().to_table());
    println!("broker-tier scaling timeline:");
    print!(
        "{}",
        handle.timeline("analyze-broker").expect("broker timeline").to_recorder().to_table()
    );

    assert!(
        timeline.count(ScalingAction::Up) >= 1,
        "no automatic scale-up happened"
    );
    assert!(
        timeline.count(ScalingAction::Down) >= 1,
        "no automatic scale-down happened"
    );
    let report = handle.drain_and_stop()?;
    assert!(report.drained, "burst failed to drain");
    assert_eq!(report.terminal_lag(), 0);
    println!(
        "processed {} msgs across {} batches ({} fell behind the window during the burst)",
        report.processed_messages(),
        report.stages[0].batches,
        report.stages[0].behind,
    );

    // ---- The same control problem at Wrangler scale -----------------
    // The calibrated burst oversubscribes the 48-partition topic, so the
    // partition-elastic intents become co-scheduled plans: repartition
    // steps paired with broker-extension steps whenever the new
    // partition count would blow the 12-partition per-broker-node I/O
    // budget — all deterministic in virtual time.
    println!("\nplanned burst response at 32-node scale (simulation plane):");
    let sim = ElasticSim::new(
        SimMachine {
            executors_per_node: 2,
            ..Default::default()
        },
        CostModel::calibrated_default(),
    );
    let sc = ElasticScenario::calibrated_burst(60.0);
    let planner = Planner::new(
        PlannerConfig::default()
            .with_max_step(8)
            .with_drain_horizon_secs(6.0 * sc.window_secs)
            .with_partitions_per_broker_node(sc.partitions_per_node)
            .with_max_broker_step(2),
    );
    let inner = ThresholdPolicy::new(20_000, 2_000)
        .with_sustain(1)
        .with_cooldown_secs(2.0 * sc.window_secs)
        .with_step(8);
    let mut policy = PartitionElastic::new(inner, 2);
    let res = sim.run_planned(&sc, &mut policy, &planner);
    for r in res.rows.iter().step_by(5) {
        println!(
            "  t={:>5.0}s  rate {:>6.1} msg/s  nodes {:>2}  brokers {:>2}  partitions {:>3}  lag {:>7.0}{}",
            r.t_secs,
            r.input_rate,
            r.nodes,
            r.broker_nodes,
            r.partitions,
            r.lag,
            if r.behind { "  (behind)" } else { "" }
        );
    }
    println!(
        "peak {} nodes / {} brokers / {} partitions; {} scale-ups, {} broker-ups, {} repartitions, {} deferrals",
        res.peak_nodes,
        res.peak_broker_nodes,
        res.peak_partitions,
        res.scale_ups,
        res.broker_ups,
        res.repartitions,
        res.deferrals,
    );
    assert!(res.broker_ups >= 1, "no co-scheduled broker extension");
    assert!(res.peak_partitions > 48, "the knee never moved");

    // And the cost gate: with a drain horizon shorter than the Spark
    // extension lead, every scale-up is deferred — the planner refuses
    // to buy capacity that cannot pay for itself.
    let strict =
        Planner::new(PlannerConfig::default().with_max_step(8).with_drain_horizon_secs(10.0));
    let mut policy = ThresholdPolicy::new(20_000, 2_000)
        .with_sustain(1)
        .with_cooldown_secs(2.0 * sc.window_secs)
        .with_step(8);
    let deferred = sim.run_planned(&sc, &mut policy, &strict);
    println!(
        "with a 10 s drain horizon the planner defers every scale-up: {} deferrals, fleet pinned at {} nodes",
        deferred.deferrals, deferred.peak_nodes
    );
    assert_eq!(deferred.scale_ups, 0);
    Ok(())
}
