//! Autoscaler benches: how fast does the loop close?
//!
//! * reaction latency on the real plane — from backpressure appearing
//!   in the broker to the extension pilot reaching Running, measured
//!   end-to-end through detection (signal sample), decision (policy)
//!   and actuation (`extend_pilot` queue + bootstrap);
//! * policy decision cost — the per-sample overhead the control loop
//!   adds (threshold vs 48-partition bin-packing);
//! * planner overhead — intent→plan latency, which sits on every
//!   control-loop sample and must stay far below a millisecond so the
//!   planner never gates the loop (asserted, not just reported);
//! * the virtual-time burst response at 32-node Wrangler scale, both
//!   the legacy intent path and the plan-aware path.
//!
//! Run: `cargo bench --bench autoscale_reaction`

use std::sync::Arc;
use std::time::Duration;

use pilot_streaming::autoscale::{
    Autoscaler, AutoscalerConfig, BinPackingPolicy, Planner, PlannerConfig, ScalingIntent,
    ScalingPolicy, SignalSnapshot, ThresholdPolicy,
};
use pilot_streaming::cluster::Machine;
use pilot_streaming::metrics::ScalingAction;
use pilot_streaming::pilot::{KafkaDescription, PilotComputeService, SparkDescription};
use pilot_streaming::sim::{CostModel, ElasticScenario, ElasticSim, SimMachine};
use pilot_streaming::util::bench::Bench;
use pilot_streaming::util::RateSchedule;

fn snapshot(lag: u64, partitions: usize) -> SignalSnapshot {
    SignalSnapshot {
        t_secs: 10.0,
        lag,
        lag_slope: 25.0,
        produce_rate: 120.0,
        consume_rate: 80.0,
        partition_backlog: vec![lag / partitions.max(1) as u64; partitions],
        partitions,
        behind_batches: 3,
        last_batch_secs: 1.4,
        window_secs: 1.0,
        nodes: 4,
        min_nodes: 2,
        max_nodes: 32,
        service_rate_per_node: 25.0,
        broker_nodes: 4,
        broker_nic_util: 0.9,
        broker_disk_util: 0.4,
        under_replicated: 0,
        below_min_insync: 0,
        broker_util_skew: 0.0,
        rack_skew: 0.0,
        shard_queue_depths: Vec::new(),
        edge_lags: Vec::new(),
    }
}

fn main() {
    let mut bench = Bench::from_args();

    // --- Policy decision cost (the control loop's per-sample overhead) --
    let mut threshold = ThresholdPolicy::new(100, 10).with_cooldown_secs(f64::INFINITY);
    let snap = snapshot(5_000, 48);
    bench.run("autoscale/decide-threshold", 20_000, || {
        std::hint::black_box(threshold.decide(&snap));
    });
    let mut packing = BinPackingPolicy::new()
        .with_node_capacity(500.0)
        .with_cooldown_secs(f64::INFINITY);
    bench.run("autoscale/decide-binpack-48part", 5_000, || {
        std::hint::black_box(packing.decide(&snap));
    });

    // --- Planner overhead: intent -> costed plan -----------------------
    // The planner runs on every sample of every control loop; its cost
    // must be negligible against the 250 ms default sample interval.
    let planner = Planner::new(
        PlannerConfig::default()
            .with_max_step(8)
            .with_partitions_per_broker_node(12)
            .with_max_broker_step(2),
    );
    let snap = snapshot(250_000, 48);
    bench.run("autoscale/plan-scale-up", 20_000, || {
        std::hint::black_box(planner.plan(ScalingIntent::ScaleUp(8), &snap));
    });
    bench.run("autoscale/plan-repartition-coschedule", 20_000, || {
        std::hint::black_box(
            planner.plan(ScalingIntent::Repartition { partitions: 96, scale_up: 8 }, &snap),
        );
    });
    // Hard gate: the mean intent->plan latency stays sub-millisecond.
    let gate_iters = 10_000u32;
    let t0 = std::time::Instant::now();
    for _ in 0..gate_iters {
        std::hint::black_box(
            planner.plan(ScalingIntent::Repartition { partitions: 96, scale_up: 8 }, &snap),
        );
    }
    let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / gate_iters as f64;
    assert!(
        mean_ms < 1.0,
        "planner overhead {mean_ms:.4} ms/plan breaches the sub-millisecond gate"
    );
    println!("planner overhead: {:.4} ms/plan (gate: < 1 ms)", mean_ms);

    // --- Reaction latency: detection -> extension pilot Running --------
    // Fresh deployment per round: produce a backlog, let the autoscaler
    // detect it (5 ms sampling) and extend the pilot.  Reported:
    // wall-clock from the first backpressure byte to the Up event
    // (detect + decide + actuate) and the actuation share alone
    // (extend_pilot: modeled queue + bootstrap, recorded on the event).
    let rounds = if bench.quick() { 3 } else { 10 };
    bench.run_once("autoscale/reaction-detect-to-running", || {
        let mut detect_to_running = 0.0;
        let mut actuation = 0.0;
        for _ in 0..rounds {
            let service = Arc::new(PilotComputeService::new(Machine::unthrottled(4)));
            let (kafka, cluster) = service.start_kafka(KafkaDescription::new(1)).unwrap();
            let (spark, _engine) = service
                .start_spark(SparkDescription::new(1).with_config("executors_per_node", "1"))
                .unwrap();
            cluster.create_topic("bench", 2).unwrap();
            let policy = ThresholdPolicy::new(10, 1).with_sustain(1).with_cooldown_secs(0.0);
            let scaler = Autoscaler::spawn(
                service.clone(),
                spark.clone(),
                cluster.clone(),
                None,
                Box::new(policy),
                AutoscalerConfig::new("bench", "g")
                    .with_sample_interval(Duration::from_millis(5))
                    .with_max_extension_nodes(1),
            );
            let t0 = std::time::Instant::now();
            for i in 0..32u8 {
                cluster.produce("bench", (i % 2) as usize, 0, &[vec![i]]).unwrap();
            }
            let timeline = scaler.timeline();
            while timeline.count(ScalingAction::Up) == 0 && t0.elapsed().as_secs() < 10 {
                std::thread::sleep(Duration::from_millis(1));
            }
            let events = timeline.events();
            let up = events
                .iter()
                .find(|e| e.action == ScalingAction::Up)
                .expect("scale-up never fired");
            detect_to_running += t0.elapsed().as_secs_f64();
            actuation += up.reaction_secs;
            for p in scaler.stop() {
                service.stop_pilot(&p).unwrap();
            }
            service.stop_pilot(&spark).unwrap();
            service.stop_pilot(&kafka).unwrap();
        }
        let n = rounds as f64;
        vec![
            ("detect_to_running_ms".into(), detect_to_running / n * 1e3),
            ("actuation_ms".into(), actuation / n * 1e3),
        ]
    });

    // --- Virtual-time burst response at 32-node scale -------------------
    bench.run_once("autoscale/sim-burst-32n", || {
        let machine = SimMachine {
            executors_per_node: 2,
            ..Default::default()
        };
        let sim = ElasticSim::new(machine, CostModel::paper_era());
        let sc = ElasticScenario {
            processor: "gridrec".into(),
            schedule: RateSchedule::bursty(4.0, 40.0, 1200.0, 600.0),
            window_secs: 60.0,
            windows: 60,
            broker_nodes: 4,
            partitions_per_node: 12,
            min_nodes: 2,
            max_nodes: 32,
            initial_nodes: 2,
            provision_delay_secs: 90.0,
            repartition_delay_secs: 60.0,
            max_partitions: 128,
            replication_factor: 1,
            node_death_window: None,
            ack_mode: pilot_streaming::broker::AckMode::Leader,
            replica_lag_records: 0.0,
            racks: 0,
            rack_death_window: None,
        };
        let mut policy = ThresholdPolicy::new(600, 60)
            .with_sustain(1)
            .with_cooldown_secs(120.0)
            .with_step(8);
        let res = sim.run(&sc, &mut policy);
        vec![
            ("peak_nodes".into(), res.peak_nodes as f64),
            ("scale_ups".into(), res.scale_ups as f64),
            ("scale_downs".into(), res.scale_downs as f64),
            ("behind_windows".into(), res.behind_windows as f64),
            ("node_secs".into(), res.node_secs),
        ]
    });
}
