//! Figure 9 bench: MASA processing throughput for KMeans and the two
//! light-source reconstruction algorithms (GridRec, ML-EM).
//!
//! (i) the Wrangler-scale figure on the simulation plane; (ii) the
//! real-plane per-message execution costs of the actual AOT artifacts
//! through PJRT — the calibration inputs; (iii) the §6.5 headline row.
//!
//! Run: `cargo bench --bench fig9_processing`

use pilot_streaming::config::{CostPreset, ExperimentConfig};
use pilot_streaming::exp;
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::sim::CostModel;
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();

    for (label, preset) in [
        ("paper-era", CostPreset::PaperEra),
        ("calibrated", CostPreset::Calibrated),
    ] {
        bench.run_once(&format!("fig9/grid/{label}"), || {
            let config = ExperimentConfig {
                preset,
                ..Default::default()
            };
            let costs = match preset {
                CostPreset::PaperEra => CostModel::paper_era(),
                CostPreset::Calibrated => exp::resolve_costs(&config, true),
            };
            let rec = exp::fig9(&config, &costs);
            println!("\n{}", rec.to_table());
            vec![("rows".into(), rec.to_csv().lines().count() as f64 - 1.0)]
        });
    }

    // Real per-message artifact execution (the compute hot path).
    let quick = bench.quick();
    if let Ok(runtime) = ModelRuntime::load_default() {
        let reps = if quick { 3 } else { 10 };
        for artifact in ["kmeans_score", "kmeans_update", "gridrec", "mlem"] {
            bench.run_once(&format!("fig9/real-exec/{artifact}"), || {
                let secs = runtime.calibrate(artifact, reps).unwrap();
                vec![("ms_per_msg".into(), secs * 1e3)]
            });
        }
    } else {
        eprintln!("(artifacts missing — run `make artifacts` for real-exec benches)");
    }

    // §6.5 headline.
    bench.run_once("headline/6.5", || {
        let config = ExperimentConfig {
            preset: CostPreset::PaperEra,
            ..Default::default()
        };
        let rec = exp::headline(&config, &CostModel::paper_era());
        println!("\n{}", rec.to_table());
        vec![]
    });
}
