//! Figure 8 bench: MASS producer throughput across source types and
//! resource configurations (producer nodes x broker nodes).
//!
//! Two parts: (i) the full Wrangler-scale figure on the simulation
//! plane (both cost presets), (ii) a real-plane throughput measurement
//! of the in-process broker with actual MASS producers — the numbers
//! that calibrate the simulator.
//!
//! Run: `cargo bench --bench fig8_producer`

use pilot_streaming::broker::BrokerCluster;
use pilot_streaming::cluster::Machine;
use pilot_streaming::config::{CostPreset, ExperimentConfig};
use pilot_streaming::engine::TaskEngine;
use pilot_streaming::exp;
use pilot_streaming::miniapp::{MassConfig, MassSource, SourceKind};
use pilot_streaming::sim::CostModel;
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();

    for (label, preset) in [
        ("paper-era", CostPreset::PaperEra),
        ("calibrated", CostPreset::Calibrated),
    ] {
        bench.run_once(&format!("fig8/grid/{label}"), || {
            let config = ExperimentConfig {
                preset,
                ..Default::default()
            };
            let costs = match preset {
                CostPreset::PaperEra => CostModel::paper_era(),
                CostPreset::Calibrated => exp::resolve_costs(&config, true),
            };
            let rec = exp::fig8(&config, &costs);
            println!("\n{}", rec.to_table());
            vec![("rows".into(), rec.to_csv().lines().count() as f64 - 1.0)]
        });
    }

    // Real-plane producer throughput (single host, real bytes).
    let quick = bench.quick();
    for source in ["kmeans-random", "kmeans-static"] {
        bench.run_once(&format!("fig8/real/{source}"), || {
            let machine = Machine::unthrottled(3);
            let cluster = BrokerCluster::new(machine.clone(), vec![0]);
            cluster.create_topic("t", 4).unwrap();
            let engine = TaskEngine::new(machine, vec![1], 2);
            let kind = match source {
                "kmeans-static" => SourceKind::KmeansStatic,
                _ => SourceKind::KmeansRandom { n_centroids: 10 },
            };
            let mut cfg = MassConfig::new(kind, "t");
            cfg.messages_per_producer = if quick { 20 } else { 100 };
            let report = MassSource::new(cfg).run(&engine, &cluster, 2).unwrap();
            engine.stop();
            vec![
                ("msgs_per_s".into(), report.msg_rate()),
                ("mb_per_s".into(), report.mb_rate()),
            ]
        });
    }
}
