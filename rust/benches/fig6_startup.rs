//! Figure 6 bench: Kafka/Spark/Dask(/Flink) startup vs cluster size.
//!
//! Regenerates the paper's startup comparison: per-framework queue wait
//! + framework-init time on 1..32 nodes, and measures the *live*
//! coordinator's pilot-creation path (adaptor + plugin bootstrap) so the
//! modeled figure and the real control plane are benchmarked together.
//!
//! Run: `cargo bench --bench fig6_startup`

use pilot_streaming::cluster::Machine;
use pilot_streaming::config::ExperimentConfig;
use pilot_streaming::exp;
use pilot_streaming::pilot::{FrameworkKind, PilotComputeDescription, PilotComputeService};
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();

    // The figure itself (modeled, full grid).
    bench.run_once("fig6/grid", || {
        let rec = exp::fig6(&ExperimentConfig::default());
        println!("\n{}", rec.to_table());
        let csv = rec.to_csv();
        let rows = csv.lines().count() - 1;
        vec![("rows".into(), rows as f64)]
    });

    // Live control-plane cost: how fast the coordinator itself turns a
    // description into a RUNNING pilot (models at time_scale = 0).
    for kind in [FrameworkKind::Kafka, FrameworkKind::Spark, FrameworkKind::Dask] {
        for nodes in [1usize, 4, 16] {
            let name = format!("fig6/live-pilot/{}/{nodes}n", kind.name());
            bench.run(&name, 10, || {
                let service = PilotComputeService::new(Machine::unthrottled(nodes + 1));
                let pilot = service
                    .create_pilot(PilotComputeDescription::new("slurm://wrangler", kind, nodes))
                    .unwrap();
                let s = pilot.startup().unwrap();
                assert!(s.total_secs() > 0.0);
                service.stop_pilot(&pilot).unwrap();
            });
        }
    }
}
