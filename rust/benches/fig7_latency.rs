//! Figure 7 bench: end-to-end latency at 100 msg/s — Kafka client,
//! Spark Streaming (0.2-8 s windows), Amazon Kinesis, Google Pub/Sub.
//!
//! The figure itself comes from the calibrated latency models; the
//! second part measures the *real plane's* produce->consume latency
//! through the in-process broker as the floor the models sit on.
//!
//! Run: `cargo bench --bench fig7_latency`

use std::time::Duration;

use pilot_streaming::broker::BrokerCluster;
use pilot_streaming::cluster::Machine;
use pilot_streaming::config::ExperimentConfig;
use pilot_streaming::exp;
use pilot_streaming::metrics::Histogram;
use pilot_streaming::sim::CostModel;
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();
    let config = ExperimentConfig::default();

    bench.run_once("fig7/models", || {
        let costs = CostModel::paper_era();
        let rec = exp::fig7(&config, &costs);
        println!("\n{}", rec.to_table());
        vec![("configs".into(), rec.to_csv().lines().count() as f64 - 1.0)]
    });

    // Real-plane broker latency floor at ~100 msg/s.
    let quick = bench.quick();
    bench.run_once("fig7/real-broker-floor", || {
        let machine = Machine::unthrottled(3);
        let cluster = BrokerCluster::new(machine, vec![0]);
        cluster.create_topic("lat", 1).unwrap();
        let hist = Histogram::new();
        let n = if quick { 100 } else { 500 };
        for i in 0..n {
            let t0 = cluster.elapsed_ns();
            cluster
                .produce("lat", 0, 1, &[vec![0u8; 1024]])
                .unwrap();
            let recs = cluster
                .fetch("lat", 0, i, usize::MAX, 2, Duration::from_millis(100))
                .unwrap();
            assert_eq!(recs.len(), 1);
            hist.record_ns(cluster.elapsed_ns() - t0);
            std::thread::sleep(Duration::from_millis(10)); // ~100 msg/s
        }
        vec![
            ("p50_us".into(), hist.p50_secs() * 1e6),
            ("p99_us".into(), hist.p99_secs() * 1e6),
        ]
    });
}
