//! Hot-path micro-benchmarks (the §Perf optimization targets).
//!
//! L3 data plane: log append/read (zero-copy slab views), wire
//! encode/decode (owned vs borrowed-payload), producer batching,
//! payload generation, and a concurrent produce+fetch contention
//! workload over the lock-split partition log.  L1/L2: per-artifact
//! PJRT execution.
//!
//! Run: `cargo bench --bench hotpath`
//! JSON (perf trajectory): `cargo bench --bench hotpath -- --json \
//!   --baseline=BENCH_pr10.json > bench.json`

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use pilot_streaming::broker::{copytrack, BrokerCluster, LogConfig, PartitionLog, ReplicationConfig};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::mass::{MassConfig, PayloadGenerator, SourceKind};
use pilot_streaming::miniapp::{Message, PayloadKind};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();

    // --- Broker log -----------------------------------------------------
    let payload_320k = vec![0u8; 320_000];
    bench.run("log/append-320k", 2000, || {
        // Fresh small log each run would dominate with allocation; use a
        // rolling log with retention to steady-state the append path.
        thread_local! {
            static LOG: PartitionLog = PartitionLog::new(LogConfig {
                segment_bytes: 64 << 20,
                retention_bytes: Some(256 << 20),
            });
        }
        LOG.with(|l| l.append_batch([payload_320k.as_slice()], 0));
    });

    let read_log = PartitionLog::new(LogConfig::default());
    for _ in 0..64 {
        read_log.append_batch([payload_320k.as_slice()], 0);
    }
    bench.run("log/read-8x320k", 2000, || {
        let recs = read_log.read(0, 8 * 320_000).unwrap();
        assert_eq!(recs.len(), 8);
        std::hint::black_box(recs);
    });

    // --- Wire format ------------------------------------------------------
    let values = vec![0.5f32; 15_000];
    let msg = Message::new(PayloadKind::KmeansPoints, 1, 2, values);
    bench.run("wire/encode-0.32MB", 2000, || {
        std::hint::black_box(msg.encode(320_000));
    });
    let encoded = msg.encode(320_000);
    // The borrowed-payload path consumers actually run: header parse +
    // tensor view, no f32 materialization.
    bench.run("wire/decode-0.32MB", 2000, || {
        std::hint::black_box(Message::decode_view(&encoded).unwrap());
    });
    // The owned decode kept for trajectory comparison (collects 15k f32).
    bench.run("wire/decode-owned-0.32MB", 2000, || {
        std::hint::black_box(Message::decode(&encoded).unwrap());
    });

    // --- MASS generators ---------------------------------------------------
    let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: 10 }, "b");
    cfg.points_per_msg = 5000;
    let mut generator = PayloadGenerator::new(&cfg, 1);
    bench.run("mass/gen-kmeans-random", 500, || {
        std::hint::black_box(generator.generate());
    });
    let cfg2 = MassConfig::new(SourceKind::KmeansStatic, "b");
    let mut static_generator = PayloadGenerator::new(&cfg2, 1);
    bench.run("mass/gen-kmeans-static", 500, || {
        std::hint::black_box(static_generator.generate());
    });

    // --- Broker end-to-end (unthrottled, real bytes) -----------------------
    let machine = Machine::unthrottled(2);
    let cluster = BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("bench", 1).unwrap();
    let mut produced = 0u64;
    bench.run("broker/produce-fetch-0.32MB", 500, || {
        cluster
            .produce("bench", 0, 1, &[encoded.clone()])
            .unwrap();
        let recs = cluster
            .fetch(
                "bench",
                0,
                produced,
                usize::MAX,
                1,
                std::time::Duration::from_millis(100),
            )
            .unwrap();
        produced += recs.len() as u64;
        std::hint::black_box(recs);
    });

    // --- Contention: concurrent producers vs fetchers ----------------------
    // The sharded data-plane acceptance workloads: `ways` producer
    // threads append 64 KB records to `ways` partitions while `ways`
    // fetcher threads tail them, on a cluster pinned to `ways` reactor
    // shards.  Under the old per-partition `Condvar` scheme the wakeup
    // and ack paths serialized on shared locks; with per-shard
    // coalesced doorbells the per-thread fetch throughput should hold
    // roughly flat as `ways` grows (≈ linear aggregate scaling), which
    // is what the `--metric fetch_msgs_per_sec` CI gates pin.
    let quick = bench.quick();
    for ways in [4usize, 16, 32] {
        let name = format!("broker/contended-produce-fetch-{ways}x{ways}");
        bench.run_once(&name, move || contended_workload(quick, ways));
    }

    // --- Dataflow DAG: 3-stage chained hops --------------------------------
    // End-to-end cost of the chained emission path: every record crosses
    // three engine hops (relay → relay → count), each hop re-emitting
    // downstream through a keyed producer that flushes before the hop's
    // input offsets commit.  One run produces the whole stream at the
    // head and topologically drains the chain; the drained end-to-end
    // rate is gated in CI via `--metric chain_msgs_per_sec`.
    bench.run_once("broker/dag-3stage-chain", move || dag_chain_workload(quick));

    // --- Failover: broker death to promoted leaders ------------------------
    // Time-to-recover for a factor-2 replicated topic: one iteration
    // kills a broker (every partition it led fails over to its
    // follower) and heals the tier by re-adding the node as a follower.
    // Recovery sits on the lag path of every consumer during a node
    // death, so its p50 is gated in CI like the data-plane rows.
    let machine = Machine::unthrottled(3);
    let failover_cluster = BrokerCluster::new(machine, vec![0, 1]);
    failover_cluster
        .create_topic_replicated("fo", 8, ReplicationConfig::new(2))
        .unwrap();
    for p in 0..8 {
        failover_cluster.produce("fo", p, 2, &[vec![0u8; 1024]]).unwrap();
    }
    let mut victim = 0;
    bench.run("broker/failover-8part", 300, || {
        let report = failover_cluster.kill_broker(victim).unwrap();
        failover_cluster.add_brokers(vec![victim]);
        victim ^= 1;
        std::hint::black_box(report);
    });

    // --- Follower fetch: KIP-392-style read locality -----------------------
    // Every fetch targets the node hosting the partition's *follower*:
    // with `follower_fetch` on, the read is served by the co-located
    // in-sync mirror (zero-copy through the shared slabs) instead of
    // crossing to the leader.  This is the consumer read path of a
    // rack-aware deployment, so it is gated in CI like failover.
    let machine = Machine::unthrottled(3);
    let ff_cluster = BrokerCluster::new(machine, vec![0, 1]);
    ff_cluster
        .create_topic_replicated("ff", 8, ReplicationConfig::new(2).with_follower_fetch(true))
        .unwrap();
    let ff_batch = vec![vec![0u8; 1024]; 16];
    for p in 0..8 {
        ff_cluster.produce("ff", p, 2, &ff_batch).unwrap();
    }
    let mut ff_part = 0usize;
    bench.run("broker/follower-fetch-8part", 2000, || {
        // Partition p is led by broker p % 2; its follower lives on the
        // other broker — fetch from there.
        let follower = (ff_part + 1) % 2;
        let recs = ff_cluster
            .fetch(
                "ff",
                ff_part,
                0,
                usize::MAX,
                follower,
                std::time::Duration::from_millis(50),
            )
            .unwrap();
        assert_eq!(recs.len(), 16);
        ff_part = (ff_part + 1) % 8;
        std::hint::black_box(recs);
    });

    // --- ISR shrink/expand cycle -------------------------------------------
    // The control-plane cost of the lag model: a slow follower (held
    // lag past `replica_lag_max`) is ejected by the produce-path sync
    // of every partition it follows, then a cleared injection plus one
    // heartbeat re-admits it everywhere.  One iteration is a full
    // shrink + expand cycle across 8 factor-2 partitions.
    let machine = Machine::unthrottled(3);
    let isr_cluster = BrokerCluster::new(machine, vec![0, 1]);
    isr_cluster
        .create_topic_replicated("isr", 8, ReplicationConfig::new(2).with_replica_lag_max(2))
        .unwrap();
    bench.run("broker/isr-shrink-expand-8part", 400, || {
        isr_cluster.inject_follower_lag("isr", 0, 8).unwrap();
        isr_cluster.inject_follower_lag("isr", 1, 8).unwrap();
        for p in 0..8 {
            isr_cluster.produce("isr", p, 2, &[vec![0u8; 1024]]).unwrap();
        }
        isr_cluster.inject_follower_lag("isr", 0, 0).unwrap();
        isr_cluster.inject_follower_lag("isr", 1, 0).unwrap();
        isr_cluster.replication_heartbeat("isr").unwrap();
    });

    // --- Re-join with divergence truncation --------------------------------
    // The full bounce of one broker: its follower is held behind, the
    // leader takes appends past the follower's watermark, the broker
    // dies (unclean promotion abandons the gap), and the returning
    // replica truncates exactly that divergent tail before re-entering
    // as a follower.  One iteration = lag + produce + kill + rejoin +
    // catch-up heartbeat; this is the recovery path a node reboot puts
    // every consumer behind, so its p50 is gated in CI.
    let machine = Machine::unthrottled(3);
    let rj_cluster = BrokerCluster::new(machine, vec![0, 1]);
    rj_cluster
        .create_topic_replicated("rj", 8, ReplicationConfig::new(2))
        .unwrap();
    for p in 0..8 {
        rj_cluster.produce("rj", p, 2, &[vec![0u8; 1024]]).unwrap();
    }
    let mut victim = 0;
    bench.run("broker/rejoin-divergence-8part", 300, || {
        let survivor = victim ^ 1;
        rj_cluster.inject_follower_lag("rj", survivor, 4).unwrap();
        for p in 0..8 {
            rj_cluster.produce("rj", p, 2, &[vec![0u8; 1024]]).unwrap();
        }
        let fo = rj_cluster.kill_broker(victim).unwrap();
        let rejoin = rj_cluster.rejoin_broker(victim).unwrap();
        rj_cluster.inject_follower_lag("rj", victim, 0).unwrap();
        rj_cluster.inject_follower_lag("rj", survivor, 0).unwrap();
        rj_cluster.replication_heartbeat("rj").unwrap();
        victim ^= 1;
        std::hint::black_box((fo, rejoin));
    });

    // --- Rack failover: a whole failure domain dies and returns ------------
    // Four brokers striped across two racks, factor-2 anti-affine
    // placement: killing a rack fails over *every* partition at once
    // (each set loses exactly one replica), then both victims re-join
    // and a heartbeat re-syncs them.  The blast-radius recovery path of
    // a rack-aware deployment, gated in CI alongside single-node
    // failover.
    let machine = Machine::unthrottled(5);
    let rk_cluster = BrokerCluster::with_racks(machine, vec![0, 1, 2, 3], 2);
    rk_cluster
        .create_topic_replicated("rk", 8, ReplicationConfig::new(2))
        .unwrap();
    for p in 0..8 {
        rk_cluster.produce("rk", p, 4, &[vec![0u8; 1024]]).unwrap();
    }
    let mut rack = 0usize;
    bench.run("broker/rack-failover-8part", 300, || {
        let reports = rk_cluster.kill_rack(rack).unwrap();
        for r in &reports {
            rk_cluster.rejoin_broker(r.killed).unwrap();
        }
        rk_cluster.replication_heartbeat("rk").unwrap();
        rack ^= 1;
        std::hint::black_box(reports);
    });

    // --- L1/L2 artifact execution ------------------------------------------
    if let Ok(runtime) = ModelRuntime::load_default() {
        let km = runtime.manifest().kmeans.clone();
        let tomo = runtime.manifest().tomo.clone();
        let points = vec![0.5f32; km.n_points * km.dim];
        let centroids = vec![0.1f32; km.k * km.dim];
        runtime.warmup("kmeans_score").unwrap();
        bench.run("xla/kmeans_score", 50, || {
            std::hint::black_box(runtime.execute("kmeans_score", &[&points, &centroids]).unwrap());
        });
        let sino = vec![0.3f32; tomo.n_angles * tomo.n_det];
        runtime.warmup("gridrec").unwrap();
        bench.run("xla/gridrec", 30, || {
            std::hint::black_box(runtime.execute("gridrec", &[&sino]).unwrap());
        });
        runtime.warmup("mlem").unwrap();
        bench.run("xla/mlem", 10, || {
            std::hint::black_box(runtime.execute("mlem", &[&sino]).unwrap());
        });
    } else if !bench.json() {
        eprintln!("(artifacts missing — run `make artifacts` for xla benches)");
    }

    bench.emit("hotpath");
}

/// One contended produce/fetch run at `ways`-way parallelism.
///
/// `ways` producers blast 64 KB records at `ways` partitions while
/// `ways` fetchers tail them on a [`BrokerCluster`] pinned to `ways`
/// reactor shards.  Total bytes moved is held constant across widths
/// (`per_producer` scales as `4/ways` relative to the 4x4 row) so the
/// resident payload set stays bounded and the rows compare aggregate
/// throughput on equal work.  Emits the aggregate fetch rate plus the
/// per-thread rate (`fetch_msgs_per_sec_per_thread`) the scaling claim
/// is judged on.
/// One end-to-end run of a 3-stage chained DAG (relay → relay → count
/// across three broker topics): keyed records enter at the head, every
/// hop re-emits 1:1, and the run ends with a topological drain.  The
/// wall-clock covers produce + all three hops + drain, so the rate is
/// the chain's sustained end-to-end throughput, not a single hop's.
fn dag_chain_workload(quick: bool) -> Vec<(String, f64)> {
    use pilot_streaming::app::{CountingProcessor, RelayProcessor, StageSpec, StreamingApp};
    use pilot_streaming::broker::{Partitioner, Producer, ProducerConfig};
    use pilot_streaming::pilot::{KafkaDescription, PilotComputeService};
    use std::time::Duration;

    let window = Duration::from_millis(10);
    let app = StreamingApp::builder()
        .broker(KafkaDescription::new(1), &[("a", 2), ("b", 2), ("c", 2)])
        .stage(
            StageSpec::new("hop1", "a", RelayProcessor::new(1))
                .with_window(window)
                .with_output_topic("b"),
        )
        .stage(
            StageSpec::new("hop2", "b", RelayProcessor::new(1))
                .with_window(window)
                .with_output_topic("c"),
        )
        .stage(StageSpec::new("sink", "c", CountingProcessor::new()).with_window(window))
        .drain_timeout(Duration::from_secs(120))
        .build()
        .unwrap();
    let service = Arc::new(PilotComputeService::new(Machine::unthrottled(6)));
    let handle = app.launch(&service).unwrap();
    let msgs: u64 = if quick { 200 } else { 2000 };
    let mut producer = Producer::new(
        handle.cluster().clone(),
        "a",
        1,
        ProducerConfig {
            partitioner: Partitioner::Keyed,
            ..Default::default()
        },
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    for i in 0..msgs {
        let k = (i % 251) as u8;
        let mut v = vec![k; 64];
        v[1..9].copy_from_slice(&i.to_le_bytes());
        producer.send(Some(&[k]), v).unwrap();
    }
    producer.flush().unwrap();
    let report = handle.drain_and_stop().unwrap();
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    assert!(report.drained, "chain drain timed out");
    let sink = report.stages.iter().find(|s| s.name == "sink").unwrap();
    assert_eq!(sink.processed_messages, msgs, "chain lost records");
    vec![
        ("chain_msgs".to_string(), msgs as f64),
        ("chain_msgs_per_sec".to_string(), msgs as f64 / secs),
        ("chain_hops".to_string(), 3.0),
    ]
}

fn contended_workload(quick: bool, ways: usize) -> Vec<(String, f64)> {
    let machine = Machine::unthrottled(2);
    let cluster = BrokerCluster::with_shards(machine, vec![0], LogConfig::default(), ways.min(32));
    cluster.create_topic("cont", ways).unwrap();
    let base: u64 = if quick { 200 } else { 2000 };
    let per_producer: u64 = (base * 4 / ways as u64).max(1);
    let payload = vec![0u8; 64 * 1024];
    let done = Arc::new(AtomicBool::new(false));
    let fetched_msgs = Arc::new(AtomicU64::new(0));
    let fetched_bytes = Arc::new(AtomicU64::new(0));
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let producers: Vec<_> = (0..ways)
            .map(|p| {
                let cluster = cluster.clone();
                let payload = payload.clone();
                s.spawn(move || {
                    for _ in 0..per_producer {
                        cluster.produce("cont", p, 1, &[payload.clone()]).unwrap();
                    }
                })
            })
            .collect();
        for p in 0..ways {
            let cluster = cluster.clone();
            let done = done.clone();
            let fetched_msgs = fetched_msgs.clone();
            let fetched_bytes = fetched_bytes.clone();
            s.spawn(move || {
                let copies_before = copytrack::payload_copies();
                let mut pos = 0u64;
                while pos < per_producer {
                    let recs = cluster
                        .fetch(
                            "cont",
                            p,
                            pos,
                            8 << 20,
                            1,
                            std::time::Duration::from_millis(50),
                        )
                        .unwrap();
                    if recs.is_empty() {
                        if done.load(Ordering::Relaxed) {
                            break;
                        }
                        continue;
                    }
                    pos = recs.last().unwrap().offset + 1;
                    fetched_msgs.fetch_add(recs.len() as u64, Ordering::Relaxed);
                    let bytes: u64 = recs.iter().map(|r| r.value.len() as u64).sum();
                    fetched_bytes.fetch_add(bytes, Ordering::Relaxed);
                }
                // The zero-copy invariant holds under contention too:
                // no fetch on this thread materialized a payload.
                assert_eq!(
                    copytrack::payload_copies(),
                    copies_before,
                    "fetch path copied payloads at {ways}-way contention"
                );
            });
        }
        // Join producers, then release fetchers' empty-fetch exit
        // path — every appended record is fetchable by then.
        for h in producers {
            h.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
    });
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let msgs = fetched_msgs.load(Ordering::Relaxed);
    let bytes = fetched_bytes.load(Ordering::Relaxed);
    let per_sec = msgs as f64 / secs;
    vec![
        ("fetched_msgs".to_string(), msgs as f64),
        ("fetch_msgs_per_sec".to_string(), per_sec),
        ("fetch_mb_per_sec".to_string(), bytes as f64 / 1e6 / secs),
        (
            "fetch_msgs_per_sec_per_thread".to_string(),
            per_sec / ways as f64,
        ),
    ]
}
