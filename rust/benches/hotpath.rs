//! Hot-path micro-benchmarks (the §Perf optimization targets).
//!
//! L3 data plane: log append/read, wire encode/decode, producer
//! batching, payload generation.  L1/L2: per-artifact PJRT execution.
//!
//! Run: `cargo bench --bench hotpath`

use pilot_streaming::broker::{LogConfig, PartitionLog};
use pilot_streaming::cluster::Machine;
use pilot_streaming::miniapp::mass::{MassConfig, PayloadGenerator, SourceKind};
use pilot_streaming::miniapp::{Message, PayloadKind};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::util::bench::Bench;

fn main() {
    let mut bench = Bench::from_args();

    // --- Broker log -----------------------------------------------------
    let payload_320k = vec![0u8; 320_000];
    bench.run("log/append-320k", 2000, || {
        // Fresh small log each run would dominate with allocation; use a
        // rolling log with retention to steady-state the append path.
        thread_local! {
            static LOG: std::cell::RefCell<PartitionLog> =
                std::cell::RefCell::new(PartitionLog::new(LogConfig {
                    segment_bytes: 64 << 20,
                    retention_bytes: Some(256 << 20),
                }));
        }
        LOG.with(|l| {
            l.borrow_mut()
                .append_batch([payload_320k.as_slice()], 0)
        });
    });

    let mut read_log = PartitionLog::new(LogConfig::default());
    for _ in 0..64 {
        read_log.append_batch([payload_320k.as_slice()], 0);
    }
    bench.run("log/read-8x320k", 2000, || {
        let recs = read_log.read(0, 8 * 320_000).unwrap();
        assert_eq!(recs.len(), 8);
        std::hint::black_box(recs);
    });

    // --- Wire format ------------------------------------------------------
    let values = vec![0.5f32; 15_000];
    let msg = Message::new(PayloadKind::KmeansPoints, 1, 2, values);
    bench.run("wire/encode-0.32MB", 2000, || {
        std::hint::black_box(msg.encode(320_000));
    });
    let encoded = msg.encode(320_000);
    bench.run("wire/decode-0.32MB", 2000, || {
        std::hint::black_box(Message::decode(&encoded).unwrap());
    });

    // --- MASS generators ---------------------------------------------------
    let mut cfg = MassConfig::new(SourceKind::KmeansRandom { n_centroids: 10 }, "b");
    cfg.points_per_msg = 5000;
    let mut generator = PayloadGenerator::new(&cfg, 1);
    bench.run("mass/gen-kmeans-random", 500, || {
        std::hint::black_box(generator.generate());
    });
    let cfg2 = MassConfig::new(SourceKind::KmeansStatic, "b");
    let mut static_generator = PayloadGenerator::new(&cfg2, 1);
    bench.run("mass/gen-kmeans-static", 500, || {
        std::hint::black_box(static_generator.generate());
    });

    // --- Broker end-to-end (unthrottled, real bytes) -----------------------
    let machine = Machine::unthrottled(2);
    let cluster = pilot_streaming::broker::BrokerCluster::new(machine, vec![0]);
    cluster.create_topic("bench", 1).unwrap();
    let mut produced = 0u64;
    bench.run("broker/produce-fetch-0.32MB", 500, || {
        cluster
            .produce("bench", 0, 1, &[encoded.clone()])
            .unwrap();
        let recs = cluster
            .fetch(
                "bench",
                0,
                produced,
                usize::MAX,
                1,
                std::time::Duration::from_millis(100),
            )
            .unwrap();
        produced += recs.len() as u64;
        std::hint::black_box(recs);
    });

    // --- L1/L2 artifact execution ------------------------------------------
    if let Ok(runtime) = ModelRuntime::load_default() {
        let km = runtime.manifest().kmeans.clone();
        let tomo = runtime.manifest().tomo.clone();
        let points = vec![0.5f32; km.n_points * km.dim];
        let centroids = vec![0.1f32; km.k * km.dim];
        runtime.warmup("kmeans_score").unwrap();
        bench.run("xla/kmeans_score", 50, || {
            std::hint::black_box(runtime.execute("kmeans_score", &[&points, &centroids]).unwrap());
        });
        let sino = vec![0.3f32; tomo.n_angles * tomo.n_det];
        runtime.warmup("gridrec").unwrap();
        bench.run("xla/gridrec", 30, || {
            std::hint::black_box(runtime.execute("gridrec", &[&sino]).unwrap());
        });
        runtime.warmup("mlem").unwrap();
        bench.run("xla/mlem", 10, || {
            std::hint::black_box(runtime.execute("mlem", &[&sino]).unwrap());
        });
    } else {
        eprintln!("(artifacts missing — run `make artifacts` for xla benches)");
    }
}
