//! Compute-Units: framework-agnostic task execution (paper §4.2).
//!
//! "A Compute-Unit can be formulated and executed in a framework
//! agnostic [way]" (paper Listing 5):
//!
//! ```python
//! def compute(x): return x*x
//! compute_unit = pilot.submit(compute, 2)
//! compute_unit.wait()
//! ```
//!
//! Here a [`ComputeUnit`] wraps a closure plus lifecycle state and can
//! be submitted to any pilot whose context exposes an execution backend
//! (task-parallel engines directly; micro-batch engines through their
//! executor pool).  The same closure runs unchanged on a Dask-like or a
//! Spark-like pilot — the paper's interoperability claim.

use std::sync::{Arc, Mutex};

use crate::engine::{TaskEngine, TaskFuture};
use crate::error::{Error, Result};
use crate::pilot::{FrameworkContext, Pilot};

/// Lifecycle states of a compute unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeUnitState {
    New,
    Running,
    Done,
    Failed,
}

/// Description of a compute unit (name + placement hints).
#[derive(Debug, Clone, Default)]
pub struct ComputeUnitDescription {
    pub name: String,
    /// Number of cores the unit claims (accounting only).
    pub cores: usize,
}

impl ComputeUnitDescription {
    pub fn new(name: &str) -> Self {
        ComputeUnitDescription {
            name: name.to_string(),
            cores: 1,
        }
    }
}

/// A submitted compute unit with a typed result.
pub struct ComputeUnit<R> {
    description: ComputeUnitDescription,
    state: Arc<Mutex<ComputeUnitState>>,
    future: TaskFuture<R>,
}

impl<R: Send + 'static> ComputeUnit<R> {
    pub fn description(&self) -> &ComputeUnitDescription {
        &self.description
    }

    pub fn state(&self) -> ComputeUnitState {
        *self.state.lock().unwrap()
    }

    /// Block until the unit completes (paper: `compute_unit.wait()`).
    pub fn wait(self) -> Result<R> {
        let result = self.future.wait();
        let mut st = self.state.lock().unwrap();
        *st = if result.is_ok() {
            ComputeUnitState::Done
        } else {
            ComputeUnitState::Failed
        };
        result
    }
}

/// Resolve a pilot's context to a task-execution backend.
fn engine_of(pilot: &Pilot) -> Result<TaskEngine> {
    match pilot.context()? {
        FrameworkContext::TaskPar(e) => Ok(e),
        // A micro-batch engine executes CUs on its executor pool.
        FrameworkContext::MicroBatch(e) => Ok(e.executor_pool()),
        FrameworkContext::Kafka(_) => Err(Error::Engine(
            "kafka pilots broker data; submit compute units to a processing pilot".into(),
        )),
    }
}

/// Submit a closure to any processing pilot (paper Listing 5).
pub fn submit_unit<R, F>(
    pilot: &Pilot,
    description: ComputeUnitDescription,
    f: F,
) -> Result<ComputeUnit<R>>
where
    R: Send + 'static,
    F: FnOnce() -> R + Send + 'static,
{
    let engine = engine_of(pilot)?;
    let state = Arc::new(Mutex::new(ComputeUnitState::Running));
    let future = engine.submit(move |_node| f())?;
    Ok(ComputeUnit {
        description,
        state,
        future,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use crate::pilot::{DaskDescription, PilotComputeService, SparkDescription};

    #[test]
    fn cu_runs_on_dask_pilot() {
        let svc = PilotComputeService::new(Machine::unthrottled(2));
        let (pilot, engine) = svc.start_dask(DaskDescription::new(1)).unwrap();
        let cu = submit_unit(&pilot, ComputeUnitDescription::new("square"), || 2 * 2).unwrap();
        assert_eq!(cu.wait().unwrap(), 4);
        svc.stop_pilot(&pilot).unwrap();
        engine.stop();
    }

    #[test]
    fn same_cu_runs_on_spark_pilot_interoperably() {
        let svc = PilotComputeService::new(Machine::unthrottled(2));
        let (pilot, engine) = svc.start_spark(SparkDescription::new(1)).unwrap();
        // The exact same closure submitted unchanged (paper Listing 5).
        let compute = || 2 * 2;
        let cu = submit_unit(&pilot, ComputeUnitDescription::new("square"), compute).unwrap();
        assert_eq!(cu.state(), ComputeUnitState::Running);
        assert_eq!(cu.wait().unwrap(), 4);
        svc.stop_pilot(&pilot).unwrap();
        engine.stop();
    }

    #[test]
    fn cu_on_kafka_pilot_is_rejected() {
        let svc = PilotComputeService::new(Machine::unthrottled(2));
        let (pilot, _cluster) = svc
            .start_kafka(crate::pilot::KafkaDescription::new(1))
            .unwrap();
        let result = submit_unit(&pilot, ComputeUnitDescription::new("x"), || 1);
        assert!(matches!(result.err(), Some(Error::Engine(_))));
        svc.stop_pilot(&pilot).unwrap();
    }

    #[test]
    fn failed_cu_reports_failure() {
        let svc = PilotComputeService::new(Machine::unthrottled(2));
        let (pilot, engine) = svc.start_dask(DaskDescription::new(1)).unwrap();
        let cu =
            submit_unit::<(), _>(&pilot, ComputeUnitDescription::new("boom"), || {
                panic!("synthetic")
            })
            .unwrap();
        assert!(cu.wait().is_err());
        svc.stop_pilot(&pilot).unwrap();
        engine.stop();
    }
}
