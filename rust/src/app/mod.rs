//! The application layer: declare a whole streaming application —
//! broker, sources, processing stages, autoscaling — as one typed spec.
//!
//! The paper's core contribution is an *application-level* abstraction:
//! Pilot-Streaming lets developers describe brokers, producers,
//! processing frameworks and runtime resource management through one
//! Pilot-API instead of hand-integrating heterogeneous components, and
//! the Mini-App framework makes generators and processors plug-able
//! (§4-5).  This module is that abstraction for the whole repo:
//!
//! * [`StreamingApp::builder`] composes `.broker(...)` / `.source(...)`
//!   / `.stage(...)` / `.autoscale(...)` into a validated spec
//!   ([`spec`]) — topics referenced by stages must exist, partition
//!   counts must fit the broker tier's per-node I/O budget, stage
//!   frameworks must provide a processing engine — *before* anything
//!   launches;
//! * [`StreamingApp::launch`] starts pilots in dependency order
//!   (broker → stages → sources → autoscale loops), wires the
//!   metrics→planner→actuation loop, and returns an [`AppHandle`]
//!   ([`handle`]) with unified `stats()`, `startup_breakdowns()`,
//!   `extend(stage, nodes)` and `drain_and_stop()` (fence sources,
//!   drain consumer lag to zero, then stop jobs and pilots — no more
//!   sleep-and-hope teardown);
//! * two public traits make the algorithm surface plug-able without
//!   touching [`crate::miniapp`]: [`DataSource`] (the MASS side —
//!   [`crate::miniapp::MassConfig`] / [`crate::miniapp::SourceKind`]
//!   are the built-in impls) and [`StreamProcessor`] (the MASA side —
//!   [`crate::miniapp::MasaProcessor`] and any existing
//!   [`BatchProcessor`] adapt to it).
//!
//! See `examples/quickstart.rs` for the ~30-line end-to-end shape, and
//! `pilot-streaming exp app --spec <file.json|file.toml>` to run a
//! spec from a JSON or TOML file.

pub mod dag;
pub mod handle;
pub mod spec;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::broker::Record;
use crate::engine::{BatchProcessor, Emitter, TaskContext};
use crate::error::Result;

pub use dag::{MergeSpec, RelayProcessor, SplitRoute, SplitSpec};
pub use handle::{AppHandle, AppReport, SourceReport, StageReport};
pub use spec::{
    AckMode, AutoscaleSpec, BrokerSpec, ReplicationSpec, ScaleTarget, SourceSpec, StageSpec,
    StreamingApp, StreamingAppBuilder, TopicSpec,
};

/// A plug-able streaming data source (the MASS side of the Mini-App
/// contract, generalized).
///
/// A `DataSource` is the *recipe* shared by every producer of a
/// [`SourceSpec`]; [`open`](DataSource::open) creates the independent
/// per-producer generation state.  The application layer owns pacing
/// (rate limits, [`crate::util::RateSchedule`]s), message counts and
/// fencing — an implementation only decides what bytes message `seq`
/// carries.  Third-party sources implement this pair without touching
/// [`crate::miniapp`]; the built-in impls are
/// [`crate::miniapp::MassConfig`] (full knobs) and
/// [`crate::miniapp::SourceKind`] (paper defaults).
pub trait DataSource: Send + Sync {
    /// Short display name (logs, specs, reports).
    fn name(&self) -> &str;

    /// Open the generation stream for one producer.  `stream` is the
    /// 1-based producer index — implementations fork their RNG off it
    /// so producers emulate the same underlying distribution without
    /// emitting identical bytes.
    fn open(&self, stream: u64) -> Box<dyn SourceStream>;
}

/// One producer's generation state, created by [`DataSource::open`].
pub trait SourceStream: Send {
    /// The wire bytes of message `seq` — exactly what lands as one
    /// broker record.  Called once per message, in order.
    fn next_message(&mut self, seq: u64) -> Vec<u8>;
}

/// A plug-able stream-processing algorithm (the MASA side of the
/// Mini-App contract, generalized): one window of records in, updated
/// state + stats out.
///
/// The micro-batch engine calls
/// [`process_window`](StreamProcessor::process_window) once per
/// partition per window (the paper's one-task-per-partition model),
/// concurrently across partitions — implementations carry state behind
/// `&self` (the built-in [`crate::miniapp::MasaProcessor`] keeps its
/// KMeans model in a mutex).  [`warmup`](StreamProcessor::warmup) runs
/// once before the stage's streaming job starts, on the launching
/// thread — the place to compile artifacts or open models.  Closures
/// of the [`BatchProcessor`] shape implement it automatically, and
/// [`BatchAdapter`] wraps an existing boxed [`BatchProcessor`], so
/// user algorithms plug in without touching [`crate::miniapp`].
pub trait StreamProcessor: Send + Sync {
    /// Short display name (logs, specs, reports).
    fn name(&self) -> &str {
        "processor"
    }

    /// Pre-launch hook: compile/load whatever the processor needs.
    /// A failure here aborts [`StreamingApp::launch`] before any data
    /// flows.
    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// Process one partition's slice of one micro-batch window.
    fn process_window(&self, ctx: &TaskContext, window: &[Record]) -> Result<()>;

    /// Like [`process_window`](StreamProcessor::process_window), but
    /// with an [`Emitter`] for producing derived records to the stage's
    /// downstream topics ([`StageSpec::with_output_topic`], split
    /// branches).  Only called on stages that *have* outputs; the
    /// default ignores the emitter, so sink processors need not change.
    /// Keys passed to [`Emitter::emit`] are hashed through the broker's
    /// [`crate::broker::key_hash`] route, preserving per-key order
    /// across the hop.
    fn process_window_emit(
        &self,
        ctx: &TaskContext,
        window: &[Record],
        out: &mut Emitter,
    ) -> Result<()> {
        let _ = out;
        self.process_window(ctx, window)
    }
}

impl<F> StreamProcessor for F
where
    F: Fn(&TaskContext, &[Record]) -> Result<()> + Send + Sync,
{
    fn process_window(&self, ctx: &TaskContext, window: &[Record]) -> Result<()> {
        self(ctx, window)
    }
}

/// Adapter: run any existing [`BatchProcessor`] as a
/// [`StreamProcessor`] stage, unchanged.
pub struct BatchAdapter {
    name: String,
    inner: Arc<dyn BatchProcessor>,
}

impl BatchAdapter {
    pub fn new(name: &str, inner: Arc<dyn BatchProcessor>) -> Arc<Self> {
        Arc::new(BatchAdapter {
            name: name.to_string(),
            inner,
        })
    }
}

impl StreamProcessor for BatchAdapter {
    fn name(&self) -> &str {
        &self.name
    }

    fn process_window(&self, ctx: &TaskContext, window: &[Record]) -> Result<()> {
        self.inner.process(ctx, window)
    }
}

/// The reverse adapter the launch path uses: a [`StreamProcessor`]
/// driving the engine's [`BatchProcessor`] job interface.
pub(crate) struct AsBatch(pub Arc<dyn StreamProcessor>);

impl BatchProcessor for AsBatch {
    fn process(&self, ctx: &TaskContext, records: &[Record]) -> Result<()> {
        self.0.process_window(ctx, records)
    }

    fn process_emit(&self, ctx: &TaskContext, records: &[Record], out: &mut Emitter) -> Result<()> {
        self.0.process_window_emit(ctx, records, out)
    }
}

/// A dependency-free built-in [`StreamProcessor`]: counts messages and
/// bytes, optionally spending a fixed per-message cost — the stand-in
/// workload for smoke runs, load tests and autoscaling demos when the
/// PJRT compute plane is unavailable.
pub struct CountingProcessor {
    messages: AtomicU64,
    bytes: AtomicU64,
    per_message: Option<Duration>,
}

impl CountingProcessor {
    pub fn new() -> Arc<Self> {
        Arc::new(CountingProcessor {
            messages: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            per_message: None,
        })
    }

    /// A counter that also burns `per_message` of wall-clock per record
    /// (models a fixed-cost analysis kernel).
    pub fn with_cost(per_message: Duration) -> Arc<Self> {
        Arc::new(CountingProcessor {
            messages: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            per_message: Some(per_message),
        })
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

impl StreamProcessor for CountingProcessor {
    fn name(&self) -> &str {
        "count"
    }

    fn process_window(&self, _ctx: &TaskContext, window: &[Record]) -> Result<()> {
        for r in window {
            if let Some(d) = self.per_message {
                std::thread::sleep(d);
            }
            self.messages.fetch_add(1, Ordering::Relaxed);
            self.bytes.fetch_add(r.value.len() as u64, Ordering::Relaxed);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(bytes: &[u8]) -> Record {
        Record {
            offset: 0,
            timestamp_ns: 0,
            value: crate::broker::SharedSlice::from_vec(bytes.to_vec()),
        }
    }

    fn ctx() -> TaskContext {
        TaskContext {
            partition: 0,
            node: 0,
            batch: 0,
        }
    }

    #[test]
    fn counting_processor_counts_messages_and_bytes() {
        let p = CountingProcessor::new();
        p.process_window(&ctx(), &[record(&[1, 2, 3]), record(&[4])]).unwrap();
        assert_eq!(p.messages(), 2);
        assert_eq!(p.bytes(), 4);
        assert_eq!(StreamProcessor::name(&*p), "count");
    }

    #[test]
    fn closures_and_batch_adapters_are_stream_processors() {
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        let closure = move |_: &TaskContext, recs: &[Record]| {
            h.fetch_add(recs.len() as u64, Ordering::Relaxed);
            Ok(())
        };
        let as_stream: Arc<dyn StreamProcessor> = Arc::new(closure.clone());
        as_stream.process_window(&ctx(), &[record(&[9])]).unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);

        // An existing boxed BatchProcessor adapts without changes.
        let as_batch: Arc<dyn BatchProcessor> = Arc::new(closure);
        let adapted = BatchAdapter::new("legacy", as_batch);
        adapted.process_window(&ctx(), &[record(&[9]), record(&[9])]).unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 3);
        assert_eq!(adapted.name(), "legacy");
    }
}
