//! Launching a [`StreamingApp`] and driving it to a clean stop.
//!
//! [`StreamingApp::launch`] starts pilots in dependency order — broker
//! first (everything produces into or consumes from it), then
//! processing stages (consumers are live before the first message
//! lands), then sources, then autoscale loops (last, so a failed
//! launch can never leak policy-driven extension pilots) — and returns
//! an [`AppHandle`].  The handle unifies what the hand-wired examples used
//! to assemble from five subsystems: live [`stats`](AppHandle::stats),
//! per-pilot [`startup_breakdowns`](AppHandle::startup_breakdowns),
//! manual [`extend`](AppHandle::extend) (paper Listing 4) and a real
//! termination protocol, [`drain_and_stop`](AppHandle::drain_and_stop):
//! fence the sources, drain consumer lag to zero, then stop jobs and
//! pilots in reverse dependency order.
//!
//! Stages, splits and merges launch as the [`super::dag`]-lowered node
//! list, in topological order.  The drain in `drain_and_stop` walks the
//! same order: because the engine flushes a node's emissions *before*
//! committing its input offsets, an upstream node reading lag zero on a
//! current topic epoch means everything it derived has already landed
//! downstream — so draining nodes upstream-first drains the whole DAG.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::autoscale::{Autoscaler, AutoscalerConfig};
use crate::broker::{BrokerCluster, Producer, ProducerConfig, Topic};
use crate::engine::{JobStats, MicroBatchEngine, StreamingJobConfig, StreamingJobHandle, TaskEngine};
use crate::error::{Error, Result};
use crate::metrics::{RateMeter, ScalingTimeline};
use crate::pilot::{
    FrameworkContext, FrameworkKind, Pilot, PilotComputeDescription, PilotComputeService,
    StartupBreakdown,
};
use crate::util::RateSchedule;

use super::spec::{ScaleTarget, SourceSpec, StreamingApp};
use super::{AsBatch, DataSource, StreamProcessor};

/// One source's aggregate production report.
#[derive(Debug, Clone)]
pub struct SourceReport {
    pub name: String,
    pub topic: String,
    pub messages: u64,
    pub bytes: u64,
    pub elapsed_secs: f64,
    pub producers: usize,
}

impl SourceReport {
    pub fn msg_rate(&self) -> f64 {
        self.messages as f64 / self.elapsed_secs.max(1e-9)
    }

    pub fn mb_rate(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.elapsed_secs.max(1e-9)
    }
}

/// One stage's processing snapshot (live or terminal).
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub topic: String,
    pub group: String,
    pub processed_messages: u64,
    pub processed_bytes: u64,
    /// Records this node emitted to its downstream topics (0 for
    /// sinks) — with `processed_messages`, the per-hop throughput of a
    /// chained DAG.
    pub emitted_messages: u64,
    pub emitted_bytes: u64,
    pub batches: u64,
    /// Batches whose processing outran the window (backpressure).
    pub behind: u64,
    pub errors: u64,
    /// Consumer lag at snapshot time (zero after a successful drain).
    pub lag: u64,
}

/// Unified application snapshot: live from [`AppHandle::stats`], or the
/// terminal report cached by [`AppHandle::drain_and_stop`].
#[derive(Debug, Clone)]
pub struct AppReport {
    /// True once `drain_and_stop` drove every stage's consumer lag to
    /// zero before the drain timeout; false on live snapshots.
    pub drained: bool,
    pub sources: Vec<SourceReport>,
    pub stages: Vec<StageReport>,
}

impl AppReport {
    /// Messages that actually landed in the broker, across sources.
    pub fn produced_messages(&self) -> u64 {
        self.sources.iter().map(|s| s.messages).sum()
    }

    /// Messages processed across stages.
    pub fn processed_messages(&self) -> u64 {
        self.stages.iter().map(|s| s.processed_messages).sum()
    }

    /// Messages emitted onto downstream topics across stages (chained
    /// DAG hops only; 0 for a flat app).
    pub fn emitted_messages(&self) -> u64 {
        self.stages.iter().map(|s| s.emitted_messages).sum()
    }

    /// Remaining consumer lag summed across stages.
    pub fn terminal_lag(&self) -> u64 {
        self.stages.iter().map(|s| s.lag).sum()
    }
}

struct StageRuntime {
    name: String,
    topic: String,
    group: String,
    window: Duration,
    pilot: Arc<Pilot>,
    #[allow(dead_code)]
    engine: MicroBatchEngine,
    stats: Arc<JobStats>,
    job: Mutex<Option<StreamingJobHandle>>,
    processor: Arc<dyn StreamProcessor>,
}

/// The background thread aggregating one source's producer futures.
type SourceThread = JoinHandle<Result<SourceReport>>;

struct SourceRuntime {
    name: String,
    topic: String,
    producers: usize,
    pilot: Arc<Pilot>,
    meter: Arc<RateMeter>,
    thread: Mutex<Option<SourceThread>>,
    report: Mutex<Option<SourceReport>>,
    error: Mutex<Option<String>>,
}

struct ScalerRuntime {
    name: String,
    timeline: Arc<ScalingTimeline>,
    scaler: Option<Autoscaler>,
}

/// A launched application; see the [module docs](self).
///
/// Call [`drain_and_stop`](AppHandle::drain_and_stop) when done —
/// dropping the handle stops job drivers and autoscale loops but does
/// not release pilot allocations.
pub struct AppHandle {
    service: Arc<PilotComputeService>,
    cluster: BrokerCluster,
    broker_pilot: Arc<Pilot>,
    stages: Vec<StageRuntime>,
    sources: Vec<SourceRuntime>,
    scalers: Mutex<Vec<ScalerRuntime>>,
    manual_extensions: Mutex<Vec<Arc<Pilot>>>,
    fence: Arc<AtomicBool>,
    drain_timeout: Duration,
    report: Mutex<Option<AppReport>>,
}

impl StreamingApp {
    /// Launch the application: pilots start in dependency order and the
    /// returned handle owns the running system.  On a partial failure
    /// every already-started pilot is stopped before the error returns.
    pub fn launch(self, service: &Arc<PilotComputeService>) -> Result<AppHandle> {
        let mut started: Vec<Arc<Pilot>> = Vec::new();
        match launch_inner(self, service, &mut started) {
            Ok(handle) => Ok(handle),
            Err(e) => {
                for pilot in started.iter().rev() {
                    let _ = service.stop_pilot(pilot);
                }
                Err(e)
            }
        }
    }
}

fn launch_inner(
    app: StreamingApp,
    service: &Arc<PilotComputeService>,
    started: &mut Vec<Arc<Pilot>>,
) -> Result<AppHandle> {
    // ---- Broker tier -------------------------------------------------
    let resource = app.broker.description.0.resource.clone();
    let (broker_pilot, cluster) = service.start_kafka(app.broker.description.clone())?;
    started.push(broker_pilot.clone());
    if app.broker.racks > 0 {
        // Label failure domains before any topic exists so every
        // replica set the topics below create is placed rack-aware.
        cluster.set_racks(app.broker.racks);
    }
    for t in &app.broker.topics {
        cluster.create_topic_replicated(&t.name, t.partitions, app.broker.replication)?;
    }

    // ---- Processing nodes (consumers before producers) ---------------
    // Stages, splits and merge legs launch as the lowered DAG node
    // list, in topological order — which is also the order
    // `drain_and_stop` drains them in.
    let dag_nodes = super::dag::lower(&app)?;
    let edges: Vec<(String, String)> = dag_nodes
        .iter()
        .map(|n| (n.topic.clone(), n.group.clone()))
        .collect();
    let mut stages = Vec::new();
    for node in dag_nodes {
        let mut desc = PilotComputeDescription::new(&resource, node.framework, node.nodes);
        if let Some(key) = node.framework.parallelism_key() {
            desc = desc.with_config(key, &node.executors_per_node.to_string());
        }
        let pilot = service.create_pilot(desc)?;
        started.push(pilot.clone());
        // Spark provides the micro-batch engine natively; Dask/Flink
        // serve the same windows through their task-parallel pools.
        let engine = match pilot.context()? {
            FrameworkContext::MicroBatch(e) => e,
            FrameworkContext::TaskPar(pool) => MicroBatchEngine::with_pool(pool),
            FrameworkContext::Kafka(_) => unreachable!("rejected by build()"),
        };
        node.processor.warmup()?;
        let mut job_config = StreamingJobConfig::new(&node.topic, node.window)
            .with_output_topics(node.outputs.clone());
        job_config.group = node.group.clone();
        let job = engine.start_job(
            cluster.clone(),
            job_config,
            Arc::new(AsBatch(node.processor.clone())),
        )?;
        stages.push(StageRuntime {
            name: node.name,
            topic: node.topic,
            group: node.group,
            window: node.window,
            pilot,
            engine,
            stats: job.stats().clone(),
            job: Mutex::new(Some(job)),
            processor: node.processor,
        });
    }

    // ---- Sources -----------------------------------------------------
    let fence = Arc::new(AtomicBool::new(false));
    let mut sources = Vec::new();
    for spec in app.sources {
        let desc = PilotComputeDescription::new(&resource, FrameworkKind::Dask, spec.nodes)
            .with_config("workers_per_node", &spec.workers_per_node.to_string());
        let pilot = service.create_pilot(desc)?;
        started.push(pilot.clone());
        let Some(engine) = pilot.context()?.as_taskpar().cloned() else {
            return Err(Error::App(format!(
                "source '{}': dask pilot has no task engine",
                spec.name
            )));
        };
        let meter = Arc::new(RateMeter::new());
        let thread = spawn_source(&spec, engine, cluster.clone(), meter.clone(), fence.clone())?;
        sources.push(SourceRuntime {
            name: spec.name,
            topic: spec.topic,
            producers: spec.producers,
            pilot,
            meter,
            thread: Mutex::new(Some(thread)),
            report: Mutex::new(None),
            error: Mutex::new(None),
        });
    }

    // ---- Autoscale loops, once every pilot is up ----------------------
    // Started last so a failure earlier in launch can never race a
    // policy-driven extension: the rollback path only has base pilots
    // to release, and extension pilots exist solely under a live
    // AppHandle (whose drain_and_stop releases them).
    let mut scalers = Vec::new();
    for spec in app.autoscalers {
        let stage = stages
            .iter()
            .find(|s| s.name == spec.stage)
            .expect("validated by build()");
        // Every DAG consumer edge rides along in the probe: snapshots
        // carry whole-DAG per-edge lag, so uneven branch load shows up
        // as a per-edge signal on each loop's timeline even though the
        // loop only actuates on its own stage.
        let config = AutoscalerConfig::new(&stage.topic, &stage.group)
            .with_sample_interval(spec.sample_interval)
            .with_max_extension_nodes(spec.max_extension_nodes)
            .with_max_step(spec.max_step)
            .with_window(stage.window)
            .with_planner(spec.planner)
            .with_edges(edges.clone());
        let scaler = match spec.target {
            ScaleTarget::Stage => Autoscaler::spawn_with_broker(
                service.clone(),
                stage.pilot.clone(),
                spec.coschedule_broker.then(|| broker_pilot.clone()),
                cluster.clone(),
                Some(stage.stats.clone()),
                spec.policy,
                config,
            ),
            ScaleTarget::Broker => Autoscaler::spawn(
                service.clone(),
                broker_pilot.clone(),
                cluster.clone(),
                None,
                spec.policy,
                config,
            ),
        };
        scalers.push(ScalerRuntime {
            name: spec.name,
            timeline: scaler.timeline(),
            scaler: Some(scaler),
        });
    }

    Ok(AppHandle {
        service: service.clone(),
        cluster,
        broker_pilot,
        stages,
        sources,
        scalers: Mutex::new(scalers),
        manual_extensions: Mutex::new(Vec::new()),
        fence,
        drain_timeout: app.drain_timeout,
        report: Mutex::new(None),
    })
}

/// Drive one source's producer tasks on its Dask engine.  Producers
/// pace against the spec's schedule or rate limit and check the fence
/// between messages (and inside pacing sleeps), so a drain cuts
/// production short without losing anything already sent.
fn spawn_source(
    spec: &SourceSpec,
    engine: TaskEngine,
    cluster: BrokerCluster,
    meter: Arc<RateMeter>,
    fence: Arc<AtomicBool>,
) -> Result<SourceThread> {
    let name = spec.name.clone();
    let topic = spec.topic.clone();
    let producers = spec.producers;
    let counts: Vec<usize> = (0..producers).map(|i| spec.messages_for(i)).collect();
    let rate_limit = spec.rate_limit;
    let schedule = spec.schedule.clone();
    let source: Arc<dyn DataSource> = spec.source.clone();
    std::thread::Builder::new()
        .name(format!("app-source-{name}"))
        .spawn(move || -> Result<SourceReport> {
            let start = Instant::now();
            let mut futures = Vec::with_capacity(producers);
            for (i, count) in counts.into_iter().enumerate() {
                let cluster = cluster.clone();
                let topic = topic.clone();
                let schedule = schedule.clone();
                let source = source.clone();
                let meter = meter.clone();
                let fence = fence.clone();
                futures.push(engine.submit(move |node| -> Result<(u64, u64)> {
                    run_producer(
                        &*source, i as u64 + 1, count, &cluster, &topic, node, rate_limit,
                        schedule.as_ref(), &meter, &fence,
                    )
                })?);
            }
            let mut messages = 0;
            let mut bytes = 0;
            for f in futures {
                let (m, b) = f.wait()??;
                messages += m;
                bytes += b;
            }
            Ok(SourceReport {
                name,
                topic,
                messages,
                bytes,
                elapsed_secs: start.elapsed().as_secs_f64(),
                producers,
            })
        })
        .map_err(|e| Error::App(format!("spawn source thread: {e}")))
}

/// The one paced-producer loop in the repo: open a [`DataSource`]
/// stream, pace each message against the schedule or fixed rate
/// (fence-responsive in ≤20 ms slices), send through a
/// flush-per-message [`Producer`], and report `(messages, bytes)` that
/// actually landed.  [`crate::miniapp::MassSource::run`] delegates
/// here with a never-set fence, so MASS and the application layer
/// cannot drift apart.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_producer(
    source: &dyn DataSource,
    stream: u64,
    count: usize,
    cluster: &BrokerCluster,
    topic: &str,
    node: crate::cluster::NodeId,
    rate_limit: Option<f64>,
    schedule: Option<&RateSchedule>,
    meter: &RateMeter,
    fence: &AtomicBool,
) -> Result<(u64, u64)> {
    let mut msg_stream = source.open(stream);
    let mut producer = Producer::new(
        cluster.clone(),
        topic,
        node,
        ProducerConfig {
            // PyKafka-style: flush each ~message (they're big), so every
            // counted message has actually landed in the broker.
            batch_bytes: 1,
            ..Default::default()
        },
    )?;
    let interval_secs = rate_limit.map(|r| 1.0 / r.max(1e-9));
    let t0 = Instant::now();
    let mut sent = (0u64, 0u64);
    'messages: for seq in 0..count {
        if fence.load(Ordering::Relaxed) {
            break 'messages;
        }
        // Pace against the variable-rate schedule or the fixed rate,
        // staying fence-responsive while sleeping.
        let due_secs = match (schedule, interval_secs) {
            (Some(s), _) => Some(s.time_for_count(seq as f64)),
            (None, Some(iv)) => Some(iv * seq as f64),
            (None, None) => None,
        };
        if let Some(due) = due_secs {
            if due.is_finite() {
                loop {
                    let elapsed = t0.elapsed().as_secs_f64();
                    if elapsed >= due {
                        break;
                    }
                    if fence.load(Ordering::Relaxed) {
                        break 'messages;
                    }
                    std::thread::sleep(Duration::from_secs_f64((due - elapsed).min(0.02)));
                }
            }
        }
        let bytes = msg_stream.next_message(seq as u64);
        let n = bytes.len();
        producer.send(None, bytes)?;
        meter.record(n);
        sent.0 += 1;
        sent.1 += n as u64;
    }
    producer.flush()?;
    Ok(sent)
}

impl AppHandle {
    pub fn cluster(&self) -> &BrokerCluster {
        &self.cluster
    }

    pub fn service(&self) -> &Arc<PilotComputeService> {
        &self.service
    }

    /// `(pilot id, startup breakdown)` for every base pilot the app
    /// launched — broker, stages, sources — in launch order (paper
    /// Fig 6's queue-wait vs bootstrap decomposition, without touching
    /// any pilot handle directly).
    pub fn startup_breakdowns(&self) -> Vec<(String, StartupBreakdown)> {
        let mut out = Vec::new();
        let mut push = |pilot: &Arc<Pilot>| {
            if let Some(s) = pilot.startup() {
                out.push((pilot.id().to_string(), s));
            }
        };
        push(&self.broker_pilot);
        for s in &self.stages {
            push(&s.pilot);
        }
        for s in &self.sources {
            push(&s.pilot);
        }
        out
    }

    /// A stage's live job statistics.
    pub fn stage_stats(&self, stage: &str) -> Option<Arc<JobStats>> {
        self.stages.iter().find(|s| s.name == stage).map(|s| s.stats.clone())
    }

    /// A stage's processor, for algorithm-specific probes.
    pub fn processor(&self, stage: &str) -> Option<Arc<dyn StreamProcessor>> {
        self.stages.iter().find(|s| s.name == stage).map(|s| s.processor.clone())
    }

    /// A stage's current consumer lag.
    pub fn lag(&self, stage: &str) -> Result<u64> {
        let s = self
            .stages
            .iter()
            .find(|s| s.name == stage)
            .ok_or_else(|| Error::App(format!("unknown stage '{stage}'")))?;
        self.cluster.group_lag(&s.group, &s.topic)
    }

    /// An autoscale loop's scaling timeline, by spec name.
    pub fn timeline(&self, scaler: &str) -> Option<Arc<ScalingTimeline>> {
        self.scalers
            .lock()
            .unwrap()
            .iter()
            .find(|s| s.name == scaler)
            .map(|s| s.timeline.clone())
    }

    /// Extension pilots an autoscale loop currently holds.
    pub fn extension_count(&self, scaler: &str) -> Option<usize> {
        self.scalers
            .lock()
            .unwrap()
            .iter()
            .find(|s| s.name == scaler)
            .and_then(|s| s.scaler.as_ref().map(|sc| sc.extension_count()))
    }

    /// Manually extend a stage's pilot by `nodes` (paper Listing 4);
    /// the extension is tracked and released by
    /// [`drain_and_stop`](Self::drain_and_stop).
    pub fn extend(&self, stage: &str, nodes: usize) -> Result<Arc<Pilot>> {
        let s = self
            .stages
            .iter()
            .find(|s| s.name == stage)
            .ok_or_else(|| Error::App(format!("unknown stage '{stage}'")))?;
        let ext = self.service.extend_pilot(&s.pilot, nodes)?;
        self.manual_extensions.lock().unwrap().push(ext.clone());
        Ok(ext)
    }

    /// Block until every source finished its message budget (no fence);
    /// returns the per-source reports.  Errors if any producer failed.
    pub fn await_sources(&self) -> Result<Vec<SourceReport>> {
        let mut reports = Vec::new();
        for s in &self.sources {
            self.join_source(s);
            if let Some(e) = s.error.lock().unwrap().clone() {
                return Err(Error::App(format!("source '{}': {e}", s.name)));
            }
            if let Some(r) = s.report.lock().unwrap().clone() {
                reports.push(r);
            }
        }
        Ok(reports)
    }

    fn join_source(&self, s: &SourceRuntime) {
        if let Some(handle) = s.thread.lock().unwrap().take() {
            let report = match handle.join() {
                Ok(Ok(r)) => r,
                Ok(Err(e)) => {
                    *s.error.lock().unwrap() = Some(e.to_string());
                    self.meter_report(s)
                }
                Err(_) => {
                    *s.error.lock().unwrap() = Some("source thread panicked".into());
                    self.meter_report(s)
                }
            };
            *s.report.lock().unwrap() = Some(report);
        }
    }

    /// Fallback report from the live meter (what actually landed).
    fn meter_report(&self, s: &SourceRuntime) -> SourceReport {
        SourceReport {
            name: s.name.clone(),
            topic: s.topic.clone(),
            messages: s.meter.messages(),
            bytes: s.meter.bytes(),
            elapsed_secs: s.meter.elapsed_secs(),
            producers: s.producers,
        }
    }

    fn stage_report(&self, s: &StageRuntime, lag: u64) -> StageReport {
        StageReport {
            name: s.name.clone(),
            topic: s.topic.clone(),
            group: s.group.clone(),
            processed_messages: s.stats.processed.messages(),
            processed_bytes: s.stats.processed.bytes(),
            emitted_messages: s.stats.emitted.messages(),
            emitted_bytes: s.stats.emitted.bytes(),
            batches: s.stats.batches.load(Ordering::Relaxed),
            behind: s.stats.behind.load(Ordering::Relaxed),
            errors: s.stats.errors.load(Ordering::Relaxed),
            lag,
        }
    }

    /// Unified snapshot: live counters while running, the cached
    /// terminal report after [`drain_and_stop`](Self::drain_and_stop).
    pub fn stats(&self) -> AppReport {
        if let Some(r) = self.report.lock().unwrap().clone() {
            return r;
        }
        AppReport {
            drained: false,
            sources: self
                .sources
                .iter()
                .map(|s| s.report.lock().unwrap().clone().unwrap_or_else(|| self.meter_report(s)))
                .collect(),
            stages: self
                .stages
                .iter()
                .map(|s| {
                    let lag = self.cluster.group_lag(&s.group, &s.topic).unwrap_or(0);
                    self.stage_report(s, lag)
                })
                .collect(),
        }
    }

    /// Terminate the application cleanly:
    ///
    /// 1. **fence** the sources (producers stop at the next message
    ///    boundary; in-flight sends still land and are counted);
    /// 2. **drain**: wait until every stage's committed offsets reach
    ///    the broker's high watermarks (consumer lag zero), up to the
    ///    builder's drain timeout;
    /// 3. **stop**: autoscale loops (releasing their extension pilots),
    ///    manual extensions, streaming jobs, then pilots in reverse
    ///    dependency order (sources, stages, broker).
    ///
    /// Returns the terminal [`AppReport`]; `report.drained` is false if
    /// the timeout hit first.  A second call is a clean no-op returning
    /// the cached report.
    pub fn drain_and_stop(&self) -> Result<AppReport> {
        if let Some(r) = self.report.lock().unwrap().clone() {
            return Ok(r);
        }
        self.fence.store(true, Ordering::Relaxed);
        for s in &self.sources {
            self.join_source(s);
        }
        let source_reports: Vec<SourceReport> = self
            .sources
            .iter()
            .map(|s| s.report.lock().unwrap().clone().unwrap_or_else(|| self.meter_report(s)))
            .collect();

        // Drain *topologically*: `self.stages` holds the DAG nodes in
        // the topological order `dag::lower` returned, and each node is
        // only waited on after every upstream node already read lag
        // zero.  Because the engine flushes a node's emissions before
        // committing its input offsets, upstream lag zero means all
        // derived records have landed downstream — so by the time we
        // wait on a node, its input topic's high watermark is final.
        //
        // Lag commits advance batch by batch, so poll gently.  A
        // lag-zero reading is trusted only if the partition-set
        // snapshot captured *before* the read is still current: a
        // leader failover or repartition swapping the set mid-read can
        // produce a zero measured against the retired leaders'
        // watermarks (the promoted leader's log is the live truth).
        // Stale reads fall through to the retry arm and re-measure
        // against the new snapshot — an in-flight repartition can never
        // fake a drain.
        let deadline = Instant::now() + self.drain_timeout;
        let mut drained = true;
        for s in &self.stages {
            loop {
                let snapshot = self.cluster.topic(&s.topic).ok();
                match self.cluster.group_lag(&s.group, &s.topic) {
                    Ok(0) if snapshot.as_deref().map_or(true, Topic::is_current) => break,
                    Ok(_) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(20))
                    }
                    Ok(_) => {
                        drained = false;
                        break;
                    }
                    Err(_) => break, // topic gone (shutdown race)
                }
            }
        }

        // Scale-downs first: autoscaler extensions, then manual ones —
        // extension pilots must stop while their parents still run.
        for sr in self.scalers.lock().unwrap().iter_mut() {
            if let Some(scaler) = sr.scaler.take() {
                for pilot in scaler.stop() {
                    let _ = self.service.stop_pilot(&pilot);
                }
            }
        }
        for pilot in std::mem::take(&mut *self.manual_extensions.lock().unwrap()) {
            let _ = self.service.stop_pilot(&pilot);
        }

        // Stop jobs and collect terminal stage reports (lag read while
        // the broker is still up).
        let mut stage_reports = Vec::new();
        for s in &self.stages {
            if let Some(job) = s.job.lock().unwrap().take() {
                job.stop();
            }
            let lag = self.cluster.group_lag(&s.group, &s.topic).unwrap_or(0);
            stage_reports.push(self.stage_report(s, lag));
        }

        // Pilots in reverse dependency order.
        for s in &self.sources {
            let _ = self.service.stop_pilot(&s.pilot);
        }
        for s in &self.stages {
            let _ = self.service.stop_pilot(&s.pilot);
        }
        let _ = self.service.stop_pilot(&self.broker_pilot);

        let report = AppReport {
            drained,
            sources: source_reports,
            stages: stage_reports,
        };
        *self.report.lock().unwrap() = Some(report.clone());
        Ok(report)
    }
}
