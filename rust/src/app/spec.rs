//! Declarative application specs and the [`StreamingApp`] builder.
//!
//! A spec is assembled with [`StreamingApp::builder`] and checked by
//! [`StreamingAppBuilder::build`] *before anything launches*:
//!
//! * every topic a source produces to or a stage consumes from must be
//!   declared on the broker;
//! * per-topic partition counts must fit the broker tier's per-node
//!   I/O budget (the same `partitions_per_broker_node` budget the
//!   autoscale planner co-schedules broker extensions against);
//! * stage frameworks must provide a processing engine — Spark's
//!   micro-batch engine directly, Dask/Flink through their
//!   task-parallel pools; Kafka is the broker tier, not a stage
//!   backend;
//! * names are unique and autoscalers reference existing stages.
//!
//! Specs can also be read from JSON or TOML files
//! ([`StreamingAppBuilder::from_json`] /
//! [`StreamingAppBuilder::from_toml_str`], the `exp app` subcommand)
//! with the built-in source kinds and processors; programmatic builders
//! additionally accept arbitrary [`DataSource`] / [`StreamProcessor`]
//! implementations.  The broker tier's resilience posture —
//! [`ReplicationSpec`]: replica factor, ack mode, minimum in-sync
//! replicas — is part of the spec and validated against the broker
//! fleet size before launch.

use std::sync::Arc;
use std::time::Duration;

use crate::autoscale::{BinPackingPolicy, PlannerConfig, ScalingPolicy, ThresholdPolicy};
use crate::error::{Error, Result};
use crate::miniapp::{MassConfig, SourceKind};
use crate::pilot::{FrameworkKind, KafkaDescription};
use crate::util::{Json, RateSchedule};

pub use crate::broker::{AckMode, ReplicationConfig as ReplicationSpec};

use super::dag::{MergeSpec, RelayProcessor, SplitRoute, SplitSpec};
use super::{CountingProcessor, DataSource, StreamProcessor};

/// One topic on the pilot-managed broker.
#[derive(Debug, Clone)]
pub struct TopicSpec {
    pub name: String,
    pub partitions: usize,
}

/// The broker tier: a Kafka pilot description plus the topics created
/// on it before anything else launches.
#[derive(Clone)]
pub struct BrokerSpec {
    pub description: KafkaDescription,
    pub topics: Vec<TopicSpec>,
    /// Resilience posture for every topic on this tier: replica factor,
    /// ack mode and minimum in-sync replicas
    /// ([`ReplicationSpec::validate`]d against the fleet size by
    /// [`StreamingAppBuilder::build`]).
    pub replication: ReplicationSpec,
    /// Failure domains the broker fleet is striped across (0 = no rack
    /// labels).  Launch assigns brokers round-robin to the domains and
    /// replica placement becomes rack-anti-affine: no two replicas of a
    /// partition share a domain while distinct domains remain.
    /// Validated against the fleet size by
    /// [`StreamingAppBuilder::build`].
    pub racks: usize,
}

/// One data source: `producers` producer tasks on a pilot-managed
/// Dask(-like) engine, each generating messages from a shared
/// [`DataSource`] recipe against the spec's pacing (fixed rate or
/// [`RateSchedule`]) and message budget.
#[derive(Clone)]
pub struct SourceSpec {
    pub name: String,
    pub topic: String,
    /// Producer tasks (the paper runs several producer processes per
    /// Dask node).
    pub producers: usize,
    /// Per-producer message count when `total_messages` is unset.
    pub messages_per_producer: usize,
    /// Total message budget, split near-evenly across producers (the
    /// remainder is distributed, not dropped).
    pub total_messages: Option<u64>,
    /// Fixed per-producer rate limit (messages/sec).
    pub rate_limit: Option<f64>,
    /// Variable-rate schedule (takes precedence over `rate_limit`).
    pub schedule: Option<RateSchedule>,
    /// Nodes for this source's Dask pilot.
    pub nodes: usize,
    pub workers_per_node: usize,
    pub(crate) source: Arc<dyn DataSource>,
}

impl SourceSpec {
    /// A source around any [`DataSource`] implementation.
    pub fn new(name: &str, topic: &str, source: Arc<dyn DataSource>) -> Self {
        SourceSpec {
            name: name.to_string(),
            topic: topic.to_string(),
            producers: 2,
            messages_per_producer: 100,
            total_messages: None,
            rate_limit: None,
            schedule: None,
            nodes: 1,
            workers_per_node: 2,
            source,
        }
    }

    /// A source from a full MASS recipe: topic, pacing, message budget
    /// and payload knobs all come from the [`MassConfig`].
    pub fn mass(config: MassConfig) -> Self {
        SourceSpec {
            name: config.source.name().to_string(),
            topic: config.topic.clone(),
            producers: 2,
            messages_per_producer: config.messages_per_producer,
            total_messages: config.total_messages,
            rate_limit: config.rate_limit,
            schedule: config.schedule.clone(),
            nodes: 1,
            workers_per_node: 2,
            source: Arc::new(config),
        }
    }

    pub fn with_name(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn with_producers(mut self, producers: usize) -> Self {
        self.producers = producers;
        self
    }

    pub fn with_messages_per_producer(mut self, messages: usize) -> Self {
        self.messages_per_producer = messages;
        self
    }

    /// Total message budget across all producers; the remainder of
    /// `total / producers` is distributed, never silently dropped.
    pub fn with_total_messages(mut self, total: u64) -> Self {
        self.total_messages = Some(total);
        self
    }

    pub fn with_rate(mut self, msgs_per_sec: f64) -> Self {
        self.rate_limit = Some(msgs_per_sec);
        self
    }

    pub fn with_schedule(mut self, schedule: RateSchedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_workers_per_node(mut self, workers: usize) -> Self {
        self.workers_per_node = workers;
        self
    }

    /// Message count for one producer (near-even split of the total
    /// budget when one is set).
    pub(crate) fn messages_for(&self, producer: usize) -> usize {
        match self.total_messages {
            Some(total) => crate::util::split_evenly(total, self.producers)[producer],
            None => self.messages_per_producer,
        }
    }
}

/// One processing stage: a [`StreamProcessor`] consuming a topic in
/// micro-batch windows on a pilot-managed engine.
#[derive(Clone)]
pub struct StageSpec {
    pub name: String,
    pub topic: String,
    /// Micro-batch window (paper §6.4 uses 60 s; examples use shorter).
    pub window: Duration,
    /// Processing backend: Spark runs the micro-batch engine natively;
    /// Dask and Flink serve the same windows through their
    /// task-parallel pools.  Kafka is rejected by validation.
    pub framework: FrameworkKind,
    pub nodes: usize,
    pub executors_per_node: usize,
    /// Consumer group for offset commits (default `app-{name}`) — what
    /// lag probes and autoscalers watch.
    pub group: Option<String>,
    /// Downstream topic this stage's [`StreamProcessor`] emissions land
    /// on (stage chaining; `None` = sink).  Emitted records are re-keyed
    /// through the broker's key-hash route and flushed before the
    /// stage's input offsets commit, so draining upstream-first drains
    /// the whole chain (see [`super::dag`]).
    pub output_topic: Option<String>,
    pub(crate) processor: Arc<dyn StreamProcessor>,
}

impl StageSpec {
    pub fn new(name: &str, topic: &str, processor: Arc<dyn StreamProcessor>) -> Self {
        StageSpec {
            name: name.to_string(),
            topic: topic.to_string(),
            window: Duration::from_millis(250),
            framework: FrameworkKind::Spark,
            nodes: 1,
            executors_per_node: 2,
            group: None,
            output_topic: None,
            processor,
        }
    }

    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    pub fn with_framework(mut self, framework: FrameworkKind) -> Self {
        self.framework = framework;
        self
    }

    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_executors_per_node(mut self, executors: usize) -> Self {
        self.executors_per_node = executors;
        self
    }

    pub fn with_group(mut self, group: &str) -> Self {
        self.group = Some(group.to_string());
        self
    }

    /// Chain this stage: its processor's emissions are produced to
    /// `topic` (validated to exist, and to have a consumer, by
    /// [`StreamingAppBuilder::build`]).
    pub fn with_output_topic(mut self, topic: &str) -> Self {
        self.output_topic = Some(topic.to_string());
        self
    }

    /// The consumer group this stage commits offsets under.
    pub fn group_name(&self) -> String {
        self.group
            .clone()
            .unwrap_or_else(|| format!("app-{}", self.name))
    }
}

/// What an autoscale loop actuates on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleTarget {
    /// Extend/shrink the watched stage's processing pilot.
    Stage,
    /// Extend/shrink the broker pilot (watching the stage's signals).
    Broker,
}

/// One closed autoscale loop: a policy watching a stage's signals
/// (consumer lag, rates, window overrun) and actuating — through the
/// cost-aware planner — on the stage's pilot or the broker tier.
pub struct AutoscaleSpec {
    /// Timeline key ([`crate::app::AppHandle::timeline`]); defaults to
    /// the stage name, or `{stage}-broker` for broker targets.
    pub name: String,
    /// The stage whose topic/group/window provide the signals.
    pub stage: String,
    pub target: ScaleTarget,
    pub sample_interval: Duration,
    pub max_extension_nodes: usize,
    pub max_step: usize,
    /// Planner tuning (drain horizon, per-node I/O budgets, broker
    /// co-scheduling).
    pub planner: PlannerConfig,
    /// Stage targets only: hand the broker pilot to the planner so
    /// plans may co-schedule broker extensions with repartitions.
    pub coschedule_broker: bool,
    pub(crate) policy: Box<dyn ScalingPolicy>,
}

impl AutoscaleSpec {
    /// Scale `stage`'s processing pilot with `policy`.
    pub fn for_stage(stage: &str, policy: impl ScalingPolicy + 'static) -> Self {
        AutoscaleSpec {
            name: stage.to_string(),
            stage: stage.to_string(),
            target: ScaleTarget::Stage,
            sample_interval: Duration::from_millis(250),
            max_extension_nodes: 4,
            max_step: 1,
            planner: PlannerConfig::default(),
            coschedule_broker: false,
            policy: Box::new(policy),
        }
    }

    /// Scale the broker pilot with `policy`, watching `stage`'s signals
    /// (a saturated broker slows producers; consumer lag alone would
    /// mis-attribute that to the processing tier).
    pub fn for_broker(stage: &str, policy: impl ScalingPolicy + 'static) -> Self {
        AutoscaleSpec {
            name: format!("{stage}-broker"),
            stage: stage.to_string(),
            target: ScaleTarget::Broker,
            sample_interval: Duration::from_millis(250),
            max_extension_nodes: 1,
            max_step: 1,
            planner: PlannerConfig::default(),
            coschedule_broker: false,
            policy: Box::new(policy),
        }
    }

    pub fn with_name(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    pub fn with_sample_interval(mut self, interval: Duration) -> Self {
        self.sample_interval = interval;
        self
    }

    pub fn with_max_extension_nodes(mut self, nodes: usize) -> Self {
        self.max_extension_nodes = nodes;
        self
    }

    pub fn with_max_step(mut self, nodes: usize) -> Self {
        self.max_step = nodes.max(1);
        self
    }

    pub fn with_planner(mut self, planner: PlannerConfig) -> Self {
        self.planner = planner;
        self
    }

    /// Allow plans to pair broker-extension steps with processing
    /// scale-ups (stage targets only).
    pub fn with_broker_coscheduling(mut self) -> Self {
        self.coschedule_broker = true;
        self
    }
}

/// A validated streaming application, ready to
/// [`launch`](StreamingApp::launch).
pub struct StreamingApp {
    pub(crate) broker: BrokerSpec,
    pub(crate) sources: Vec<SourceSpec>,
    pub(crate) stages: Vec<StageSpec>,
    pub(crate) splits: Vec<SplitSpec>,
    pub(crate) merges: Vec<MergeSpec>,
    pub(crate) autoscalers: Vec<AutoscaleSpec>,
    pub(crate) drain_timeout: Duration,
}

impl StreamingApp {
    pub fn builder() -> StreamingAppBuilder {
        StreamingAppBuilder {
            broker: None,
            replication: None,
            racks: None,
            sources: Vec::new(),
            stages: Vec::new(),
            splits: Vec::new(),
            merges: Vec::new(),
            autoscalers: Vec::new(),
            drain_timeout: Duration::from_secs(600),
        }
    }
}

/// Composable application builder; see the [module docs](self).
pub struct StreamingAppBuilder {
    broker: Option<BrokerSpec>,
    /// `.replication(..)` override; applied to the broker tier at
    /// build time so call order doesn't matter.
    replication: Option<ReplicationSpec>,
    /// `.racks(..)` override; applied like `replication`.
    racks: Option<usize>,
    sources: Vec<SourceSpec>,
    stages: Vec<StageSpec>,
    splits: Vec<SplitSpec>,
    merges: Vec<MergeSpec>,
    autoscalers: Vec<AutoscaleSpec>,
    drain_timeout: Duration,
}

impl StreamingAppBuilder {
    /// The broker tier: a Kafka pilot plus `(topic, partitions)` pairs
    /// created before anything else launches.
    pub fn broker(self, description: KafkaDescription, topics: &[(&str, usize)]) -> Self {
        self.broker_spec(BrokerSpec {
            description,
            topics: topics
                .iter()
                .map(|(name, partitions)| TopicSpec {
                    name: name.to_string(),
                    partitions: *partitions,
                })
                .collect(),
            replication: ReplicationSpec::default(),
            racks: 0,
        })
    }

    pub fn broker_spec(mut self, spec: BrokerSpec) -> Self {
        self.broker = Some(spec);
        self
    }

    /// Replication posture for the broker tier's topics: replica
    /// factor, ack mode and minimum in-sync replicas.  Applied at
    /// [`build`](Self::build) (so it composes with `.broker(..)` in
    /// either order) and validated against the broker fleet size —
    /// factor 0 and factor > broker nodes are rejected before any
    /// pilot launches.
    pub fn replication(mut self, spec: ReplicationSpec) -> Self {
        self.replication = Some(spec);
        self
    }

    /// Failure domains for the broker fleet (0 = unracked).  Launch
    /// stripes brokers round-robin across the domains and replica
    /// placement becomes rack-anti-affine; composes with `.broker(..)`
    /// in either order (applied at [`build`](Self::build)) and a domain
    /// count the fleet can't fill is rejected before any pilot
    /// launches.
    pub fn racks(mut self, racks: usize) -> Self {
        self.racks = Some(racks);
        self
    }

    pub fn source(mut self, spec: SourceSpec) -> Self {
        self.sources.push(spec);
        self
    }

    pub fn stage(mut self, spec: StageSpec) -> Self {
        self.stages.push(spec);
        self
    }

    /// A [`SplitSpec`] branch node: one input topic fanned across N
    /// branch topics by a [`SplitRoute`].
    pub fn split(mut self, spec: SplitSpec) -> Self {
        self.splits.push(spec);
        self
    }

    /// A [`MergeSpec`] fan-in node: N branch topics relayed back into
    /// one output topic.
    pub fn merge(mut self, spec: MergeSpec) -> Self {
        self.merges.push(spec);
        self
    }

    pub fn autoscale(mut self, spec: AutoscaleSpec) -> Self {
        self.autoscalers.push(spec);
        self
    }

    /// Ceiling on how long [`crate::app::AppHandle::drain_and_stop`]
    /// waits for consumer lag to reach zero (default 600 s).
    pub fn drain_timeout(mut self, timeout: Duration) -> Self {
        self.drain_timeout = timeout;
        self
    }

    /// Validate the spec; every cross-reference and budget is checked
    /// here, before any pilot launches.
    pub fn build(self) -> Result<StreamingApp> {
        let err = |m: String| Err(Error::App(m));
        let Some(mut broker) = self.broker else {
            return err("no broker tier: call .broker(KafkaDescription, topics) first".into());
        };
        if let Some(replication) = self.replication {
            broker.replication = replication;
        }
        if let Some(racks) = self.racks {
            broker.racks = racks;
        }
        if broker.topics.is_empty() {
            return err("broker declares no topics".into());
        }
        if self.sources.is_empty()
            && self.stages.is_empty()
            && self.splits.is_empty()
            && self.merges.is_empty()
        {
            return err("app has neither sources nor stages".into());
        }
        let mut topic_names = Vec::new();
        // The same per-broker-node partition budget the planner
        // co-schedules broker extensions against; take the most
        // conservative configured budget.
        let budget = self
            .autoscalers
            .iter()
            .map(|a| a.planner.partitions_per_broker_node)
            .min()
            .unwrap_or(PlannerConfig::default().partitions_per_broker_node)
            .max(1);
        let broker_nodes = broker.description.0.number_of_nodes;
        // Same check topic creation applies, surfaced pre-launch: a
        // replica factor the fleet can't host is a spec error.
        broker.replication.validate(broker_nodes)?;
        // More domains than brokers would leave empty racks — the
        // anti-affinity they promise cannot exist, so reject the spec
        // rather than silently running with hollow failure domains.
        if broker.racks > broker_nodes {
            return err(format!(
                "broker.racks {} exceeds the broker tier's {broker_nodes} node(s) — every \
                 failure domain needs at least one broker",
                broker.racks
            ));
        }
        for t in &broker.topics {
            if t.partitions == 0 {
                return err(format!("topic '{}': zero partitions", t.name));
            }
            if topic_names.contains(&t.name) {
                return err(format!("duplicate topic '{}'", t.name));
            }
            if t.partitions > broker_nodes * budget {
                return err(format!(
                    "topic '{}': {} partitions oversubscribe {broker_nodes} broker node(s) x \
                     {budget} partitions/node I/O budget — add broker nodes or lower partitions",
                    t.name, t.partitions
                ));
            }
            topic_names.push(t.name.clone());
        }
        let mut source_names = Vec::new();
        for s in &self.sources {
            if !topic_names.contains(&s.topic) {
                return err(format!(
                    "source '{}' produces to unknown topic '{}'",
                    s.name, s.topic
                ));
            }
            if s.producers == 0 || s.nodes == 0 || s.workers_per_node == 0 {
                return err(format!("source '{}': producers/nodes must be > 0", s.name));
            }
            if source_names.contains(&s.name) {
                return err(format!("duplicate source '{}'", s.name));
            }
            source_names.push(s.name.clone());
        }
        let mut stage_names = Vec::new();
        for s in &self.stages {
            if !topic_names.contains(&s.topic) {
                return err(format!(
                    "stage '{}' consumes unknown topic '{}'",
                    s.name, s.topic
                ));
            }
            if s.framework == FrameworkKind::Kafka {
                return err(format!(
                    "stage '{}': kafka is the broker tier, not a processing engine \
                     (use spark, dask or flink)",
                    s.name
                ));
            }
            if s.window.is_zero() {
                return err(format!("stage '{}': zero micro-batch window", s.name));
            }
            if s.nodes == 0 || s.executors_per_node == 0 {
                return err(format!("stage '{}': nodes/executors must be > 0", s.name));
            }
            if stage_names.contains(&s.name) {
                return err(format!("duplicate stage '{}'", s.name));
            }
            stage_names.push(s.name.clone());
        }
        let mut scaler_names = Vec::new();
        for a in &self.autoscalers {
            if scaler_names.contains(&a.name) {
                return err(format!("duplicate autoscaler '{}'", a.name));
            }
            if a.target == ScaleTarget::Broker && a.coschedule_broker {
                return err(format!(
                    "autoscaler '{}': broker targets already actuate on the broker pilot",
                    a.name
                ));
            }
            scaler_names.push(a.name.clone());
        }
        let app = StreamingApp {
            broker,
            sources: self.sources,
            stages: self.stages,
            splits: self.splits,
            merges: self.merges,
            autoscalers: self.autoscalers,
            drain_timeout: self.drain_timeout,
        };
        // Lower the dataflow DAG now: unknown output topics, degenerate
        // splits/merges, duplicate node names, dangling edges and cycles
        // are all spec errors, not launch failures.  The lowered node
        // names (stages, splits, `merge:input` legs) are also the
        // namespace autoscalers reference.
        let dag_nodes = super::dag::lower(&app)?;
        for a in &app.autoscalers {
            if !dag_nodes.iter().any(|n| n.name == a.stage) {
                return err(format!(
                    "autoscaler '{}' watches unknown stage '{}'",
                    a.name, a.stage
                ));
            }
        }
        Ok(app)
    }

    // ------------------------------------------------------------------
    // JSON specs (`pilot-streaming exp app --spec file.json`)
    // ------------------------------------------------------------------

    /// Build from a JSON application spec:
    ///
    /// ```json
    /// {
    ///   "broker": { "nodes": 1, "topics": [{"name": "points", "partitions": 4}] },
    ///   "sources": [{ "name": "gen", "topic": "points", "kind": "kmeans-static",
    ///                 "producers": 2, "total_messages": 24 }],
    ///   "stages":  [{ "name": "count", "topic": "points", "processor": "counter",
    ///                 "window_ms": 50 }]
    /// }
    /// ```
    ///
    /// Source kinds: `kmeans-random` (`n_centroids`), `kmeans-static`,
    /// `lightsource` (needs AOT artifacts); payload knobs
    /// `points_per_msg`, `msg_bytes`, `seed`; pacing via `rate`
    /// (msgs/s) or `schedule` (`[[duration_secs, rate], ...]`; the last
    /// segment's rate holds forever).  Processors: `counter` (optional
    /// `work_ms` per-message cost), `relay` (pass-through chain hop:
    /// re-emits records keyed by the leading `key_bytes` value bytes,
    /// optional `work_ms`) or `kmeans`/`gridrec`/`mlem` (need
    /// AOT artifacts).  Stages take an optional `output_topic` (chained
    /// dataflow), and top-level `splits` / `merges` arrays declare
    /// branch/fan-in nodes — see [`crate::app::dag`].  The broker block
    /// takes an optional
    /// `replication` object (`factor` required, `ack_mode`
    /// leader|quorum, `min_insync`, `replica_lag_max`,
    /// `follower_fetch`) and an optional `racks` count (failure
    /// domains the brokers are striped across round-robin, making
    /// replica placement rack-anti-affine); each stage takes an
    /// optional
    /// `autoscale` block (`policy` threshold|bin-packing with its
    /// knobs, `target` stage|broker, `max_extension_nodes`, `max_step`,
    /// `sample_interval_ms`, `coschedule_broker`).
    pub fn from_json(doc: &Json) -> Result<StreamingAppBuilder> {
        // Unknown keys are rejected, mirroring the CLI's strict
        // unknown-flag handling: a typo'd "total_mesages" must be a
        // spec error, not a silent run with defaults.
        check_keys(
            doc,
            "spec",
            &[
                "machine_nodes", "broker", "sources", "stages", "splits", "merges",
                "drain_timeout_secs",
            ],
        )?;
        let mut b = StreamingApp::builder();
        let broker = doc.req("broker")?;
        check_keys(broker, "broker", &["nodes", "topics", "replication", "racks"])?;
        let nodes = broker.get("nodes").and_then(Json::as_usize).unwrap_or(1);
        let racks = broker.get("racks").and_then(Json::as_usize).unwrap_or(0);
        let topics = broker
            .req("topics")?
            .as_arr()
            .ok_or_else(|| Error::Config("broker.topics must be an array".into()))?;
        let mut spec_topics = Vec::new();
        for t in topics {
            check_keys(t, "topic", &["name", "partitions"])?;
            spec_topics.push(TopicSpec {
                name: req_str(t, "name")?,
                partitions: req_usize(t, "partitions")?,
            });
        }
        let replication = match broker.get("replication") {
            Some(r) => replication_from_json(r)?,
            None => ReplicationSpec::default(),
        };
        b = b.broker_spec(BrokerSpec {
            description: KafkaDescription::new(nodes),
            topics: spec_topics,
            replication,
            racks,
        });
        for s in doc.get("sources").and_then(Json::as_arr).unwrap_or(&[]) {
            b = b.source(source_from_json(s)?);
        }
        for s in doc.get("stages").and_then(Json::as_arr).unwrap_or(&[]) {
            let (stage, autoscale) = stage_from_json(s)?;
            b = b.stage(stage);
            if let Some(a) = autoscale {
                b = b.autoscale(a);
            }
        }
        for s in doc.get("splits").and_then(Json::as_arr).unwrap_or(&[]) {
            b = b.split(split_from_json(s)?);
        }
        for m in doc.get("merges").and_then(Json::as_arr).unwrap_or(&[]) {
            b = b.merge(merge_from_json(m)?);
        }
        if let Some(secs) = doc.get("drain_timeout_secs").and_then(Json::as_f64) {
            b = b.drain_timeout(Duration::from_secs_f64(secs.max(0.0)));
        }
        Ok(b)
    }

    /// [`from_json`](Self::from_json) over raw text.
    pub fn from_json_str(text: &str) -> Result<StreamingAppBuilder> {
        Self::from_json(&Json::parse(text)?)
    }

    /// [`from_json`](Self::from_json) over a TOML spec: the TOML is
    /// lowered to the same [`Json`] tree, so both formats share one
    /// schema, one set of defaults, and the same strict unknown-key
    /// rejection (`exp app --spec file.toml` sniffs the extension).
    pub fn from_toml_str(text: &str) -> Result<StreamingAppBuilder> {
        Self::from_json(&crate::util::toml::parse(text)?)
    }
}

/// Reject unknown keys in a spec object — the file-spec analogue of the
/// CLI's strict unknown-flag rejection.
fn check_keys(j: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    let Some(obj) = j.as_obj() else {
        return Err(Error::Config(format!("{what} must be a JSON object")));
    };
    let mut unknown: Vec<&str> = obj
        .keys()
        .map(String::as_str)
        .filter(|k| !allowed.contains(k))
        .collect();
    if unknown.is_empty() {
        return Ok(());
    }
    unknown.sort_unstable();
    Err(Error::Config(format!(
        "unknown {what} key{}: {} (expected: {})",
        if unknown.len() == 1 { "" } else { "s" },
        unknown.join(", "),
        allowed.join(", "),
    )))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    j.req(key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Config(format!("'{key}' must be a string")))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Config(format!("'{key}' must be a number")))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    j.req(key)?
        .as_u64()
        .ok_or_else(|| Error::Config(format!("'{key}' must be a number")))
}

fn source_from_json(j: &Json) -> Result<SourceSpec> {
    check_keys(
        j,
        "source",
        &[
            "name", "topic", "kind", "n_centroids", "points_per_msg", "msg_bytes", "seed",
            "rate", "schedule", "producers", "total_messages", "messages_per_producer",
            "nodes", "workers_per_node",
        ],
    )?;
    let topic = req_str(j, "topic")?;
    let kind = req_str(j, "kind")?;
    let source_kind = match kind.as_str() {
        "kmeans-random" => SourceKind::KmeansRandom {
            n_centroids: j.get("n_centroids").and_then(Json::as_usize).unwrap_or(8),
        },
        "kmeans-static" => SourceKind::KmeansStatic,
        "lightsource" => {
            let rt = crate::runtime::ModelRuntime::load_default()?;
            SourceKind::Lightsource {
                template: Arc::new(rt.read_f32_file("template_sinogram.bin")?),
            }
        }
        other => {
            return Err(Error::Config(format!(
                "unknown source kind '{other}' (expected kmeans-random|kmeans-static|lightsource)"
            )))
        }
    };
    let mut cfg = MassConfig::new(source_kind, &topic);
    if let Some(n) = j.get("points_per_msg").and_then(Json::as_usize) {
        cfg.points_per_msg = n;
    }
    if let Some(n) = j.get("msg_bytes").and_then(Json::as_usize) {
        cfg.target_msg_bytes = Some(n);
    }
    if let Some(n) = j.get("seed").and_then(Json::as_u64) {
        cfg.seed = n;
    }
    if let Some(r) = j.get("rate").and_then(Json::as_f64) {
        cfg.rate_limit = Some(r);
    }
    if let Some(segments) = j.get("schedule").and_then(Json::as_arr) {
        cfg.schedule = Some(schedule_from_json(segments)?);
    }
    let mut spec = SourceSpec::mass(cfg).with_name(&kind);
    if let Some(name) = j.get("name").and_then(Json::as_str) {
        spec = spec.with_name(name);
    }
    if let Some(n) = j.get("producers").and_then(Json::as_usize) {
        spec = spec.with_producers(n);
    }
    if let Some(n) = j.get("total_messages").and_then(Json::as_u64) {
        spec = spec.with_total_messages(n);
    }
    if let Some(n) = j.get("messages_per_producer").and_then(Json::as_usize) {
        spec = spec.with_messages_per_producer(n);
    }
    if let Some(n) = j.get("nodes").and_then(Json::as_usize) {
        spec = spec.with_nodes(n);
    }
    if let Some(n) = j.get("workers_per_node").and_then(Json::as_usize) {
        spec = spec.with_workers_per_node(n);
    }
    Ok(spec)
}

/// Parse a `broker.replication` block: `factor` is required (an
/// implicit factor is exactly the kind of silent resilience downgrade
/// spec files exist to prevent); `ack_mode`, `min_insync`,
/// `replica_lag_max` and `follower_fetch` default like
/// [`ReplicationSpec::new`].
fn replication_from_json(j: &Json) -> Result<ReplicationSpec> {
    check_keys(
        j,
        "broker.replication",
        &["factor", "ack_mode", "min_insync", "replica_lag_max", "follower_fetch"],
    )?;
    let mut spec = ReplicationSpec::new(req_usize(j, "factor")?);
    if let Some(mode) = j.get("ack_mode").and_then(Json::as_str) {
        spec = spec.with_ack_mode(AckMode::parse(mode)?);
    }
    if let Some(n) = j.get("min_insync").and_then(Json::as_usize) {
        spec = spec.with_min_insync(n);
    }
    if let Some(n) = j.get("replica_lag_max").and_then(Json::as_u64) {
        spec = spec.with_replica_lag_max(n);
    }
    if let Some(b) = j.get("follower_fetch").and_then(Json::as_bool) {
        spec = spec.with_follower_fetch(b);
    }
    Ok(spec)
}

fn stage_from_json(j: &Json) -> Result<(StageSpec, Option<AutoscaleSpec>)> {
    check_keys(
        j,
        "stage",
        &[
            "name", "topic", "processor", "work_ms", "key_bytes", "output_topic", "window_ms",
            "framework", "nodes", "executors_per_node", "group", "autoscale",
        ],
    )?;
    let name = req_str(j, "name")?;
    let topic = req_str(j, "topic")?;
    let processor_name = req_str(j, "processor")?;
    let processor: Arc<dyn StreamProcessor> = match processor_name.as_str() {
        "counter" => match j.get("work_ms").and_then(Json::as_f64) {
            Some(ms) => CountingProcessor::with_cost(Duration::from_secs_f64(ms.max(0.0) / 1e3)),
            None => CountingProcessor::new(),
        },
        // Pass-through hop for chained stages: re-emits every record
        // keyed by its leading `key_bytes` value bytes, optionally
        // burning `work_ms` per message.
        "relay" => {
            let key_bytes = j.get("key_bytes").and_then(Json::as_usize).unwrap_or(0);
            match j.get("work_ms").and_then(Json::as_f64) {
                Some(ms) => RelayProcessor::with_cost(
                    key_bytes,
                    Duration::from_secs_f64(ms.max(0.0) / 1e3),
                ),
                None => RelayProcessor::new(key_bytes),
            }
        }
        "kmeans" | "gridrec" | "mlem" => {
            let kind = crate::miniapp::ProcessorKind::parse(&processor_name)?;
            let rt = crate::runtime::ModelRuntime::load_default()?;
            crate::miniapp::MasaProcessor::new(kind, rt)
        }
        other => {
            return Err(Error::Config(format!(
                "unknown processor '{other}' (expected counter|relay|kmeans|gridrec|mlem)"
            )))
        }
    };
    let mut spec = StageSpec::new(&name, &topic, processor);
    if let Some(t) = j.get("output_topic").and_then(Json::as_str) {
        spec = spec.with_output_topic(t);
    }
    if let Some(ms) = j.get("window_ms").and_then(Json::as_f64) {
        spec = spec.with_window(Duration::from_secs_f64(ms.max(0.0) / 1e3));
    }
    if let Some(f) = j.get("framework").and_then(Json::as_str) {
        spec = spec.with_framework(FrameworkKind::parse(f)?);
    }
    if let Some(n) = j.get("nodes").and_then(Json::as_usize) {
        spec = spec.with_nodes(n);
    }
    if let Some(n) = j.get("executors_per_node").and_then(Json::as_usize) {
        spec = spec.with_executors_per_node(n);
    }
    if let Some(g) = j.get("group").and_then(Json::as_str) {
        spec = spec.with_group(g);
    }
    let autoscale = match j.get("autoscale") {
        Some(a) => Some(autoscale_from_json(&name, a)?),
        None => None,
    };
    Ok((spec, autoscale))
}

/// Parse a per-stage `autoscale` block into a closed loop on that
/// stage.  `policy` picks the decision rule — `threshold` (required
/// `up`/`down` lag marks) or `bin-packing` (optional `node_capacity`
/// msgs/s per node) — and `target` picks what it actuates on (`stage`,
/// the default, or `broker`).
fn autoscale_from_json(stage: &str, j: &Json) -> Result<AutoscaleSpec> {
    check_keys(
        j,
        "stage autoscale",
        &[
            "policy", "up", "down", "step", "sustain", "cooldown_secs", "node_capacity",
            "target", "max_extension_nodes", "max_step", "sample_interval_ms",
            "coschedule_broker",
        ],
    )?;
    let policy_name = j.get("policy").and_then(Json::as_str).unwrap_or("threshold");
    let policy: Box<dyn ScalingPolicy> = match policy_name {
        "threshold" => {
            let (up, down) = (req_u64(j, "up")?, req_u64(j, "down")?);
            if down >= up {
                return Err(Error::Config(format!(
                    "autoscale on stage '{stage}': threshold hysteresis band is empty \
                     (up {up} must exceed down {down})"
                )));
            }
            let mut p = ThresholdPolicy::new(up, down);
            if let Some(n) = j.get("step").and_then(Json::as_usize) {
                p = p.with_step(n);
            }
            if let Some(n) = j.get("sustain").and_then(Json::as_usize) {
                p = p.with_sustain(n);
            }
            if let Some(secs) = j.get("cooldown_secs").and_then(Json::as_f64) {
                p = p.with_cooldown_secs(secs.max(0.0));
            }
            Box::new(p)
        }
        "bin-packing" => {
            let mut p = BinPackingPolicy::new();
            if let Some(cap) = j.get("node_capacity").and_then(Json::as_f64) {
                p = p.with_node_capacity(cap);
            }
            if let Some(secs) = j.get("cooldown_secs").and_then(Json::as_f64) {
                p = p.with_cooldown_secs(secs.max(0.0));
            }
            Box::new(p)
        }
        other => {
            return Err(Error::Config(format!(
                "unknown autoscale policy '{other}' (expected threshold|bin-packing)"
            )))
        }
    };
    // Placeholder policy only: `for_stage`/`for_broker` set the
    // name/target/defaults, then the parsed policy replaces it.
    let placeholder = ThresholdPolicy::new(1, 0);
    let mut spec = match j.get("target").and_then(Json::as_str).unwrap_or("stage") {
        "stage" => AutoscaleSpec::for_stage(stage, placeholder),
        "broker" => AutoscaleSpec::for_broker(stage, placeholder),
        other => {
            return Err(Error::Config(format!(
                "unknown autoscale target '{other}' (expected stage|broker)"
            )))
        }
    };
    spec.policy = policy;
    if let Some(n) = j.get("max_extension_nodes").and_then(Json::as_usize) {
        spec = spec.with_max_extension_nodes(n);
    }
    if let Some(n) = j.get("max_step").and_then(Json::as_usize) {
        spec = spec.with_max_step(n);
    }
    if let Some(ms) = j.get("sample_interval_ms").and_then(Json::as_f64) {
        spec = spec.with_sample_interval(Duration::from_secs_f64(ms.max(1.0) / 1e3));
    }
    if j.get("coschedule_broker").and_then(Json::as_bool) == Some(true) {
        spec = spec.with_broker_coscheduling();
    }
    Ok(spec)
}

/// Parse a topic-name array field (`split.branches`, `merge.inputs`).
fn req_str_arr(j: &Json, what: &str, key: &str) -> Result<Vec<String>> {
    let bad = || Error::Config(format!("{what} '{key}' must be an array of topic names"));
    let arr = j.req(key)?.as_arr().ok_or_else(bad)?;
    arr.iter()
        .map(|t| t.as_str().map(str::to_string).ok_or_else(bad))
        .collect()
}

/// Parse a `splits` entry: `route` picks the branch rule — `key-hash`
/// (needs `key_bytes` > 0), `size-threshold` (needs `threshold_bytes`;
/// records at/above it take branch 1) or `round-robin`.  Predicate
/// routes are builder-only (closures don't serialize).
fn split_from_json(j: &Json) -> Result<SplitSpec> {
    check_keys(
        j,
        "split",
        &[
            "name", "topic", "branches", "route", "threshold_bytes", "key_bytes", "window_ms",
            "nodes", "executors_per_node", "group",
        ],
    )?;
    let name = req_str(j, "name")?;
    let topic = req_str(j, "topic")?;
    let branches = req_str_arr(j, "split", "branches")?;
    let route = match j.get("route").and_then(Json::as_str).unwrap_or("key-hash") {
        "key-hash" => SplitRoute::KeyHash,
        "size-threshold" => SplitRoute::SizeThreshold(req_usize(j, "threshold_bytes")?),
        "round-robin" => SplitRoute::RoundRobin,
        other => {
            return Err(Error::Config(format!(
                "unknown split route '{other}' (expected key-hash|size-threshold|round-robin)"
            )))
        }
    };
    let branch_refs: Vec<&str> = branches.iter().map(String::as_str).collect();
    let mut spec = SplitSpec::new(&name, &topic, &branch_refs, route);
    if let Some(n) = j.get("key_bytes").and_then(Json::as_usize) {
        spec = spec.with_key_bytes(n);
    }
    if let Some(ms) = j.get("window_ms").and_then(Json::as_f64) {
        spec = spec.with_window(Duration::from_secs_f64(ms.max(0.0) / 1e3));
    }
    if let Some(n) = j.get("nodes").and_then(Json::as_usize) {
        spec = spec.with_nodes(n);
    }
    if let Some(n) = j.get("executors_per_node").and_then(Json::as_usize) {
        spec = spec.with_executors_per_node(n);
    }
    if let Some(g) = j.get("group").and_then(Json::as_str) {
        spec = spec.with_group(g);
    }
    Ok(spec)
}

/// Parse a `merges` entry: branch `inputs` fanned back into `output`,
/// re-keyed by the leading `key_bytes` value bytes.
fn merge_from_json(j: &Json) -> Result<MergeSpec> {
    check_keys(
        j,
        "merge",
        &[
            "name", "inputs", "output", "key_bytes", "window_ms", "nodes",
            "executors_per_node", "group",
        ],
    )?;
    let name = req_str(j, "name")?;
    let inputs = req_str_arr(j, "merge", "inputs")?;
    let output = req_str(j, "output")?;
    let input_refs: Vec<&str> = inputs.iter().map(String::as_str).collect();
    let mut spec = MergeSpec::new(&name, &input_refs, &output);
    if let Some(n) = j.get("key_bytes").and_then(Json::as_usize) {
        spec = spec.with_key_bytes(n);
    }
    if let Some(ms) = j.get("window_ms").and_then(Json::as_f64) {
        spec = spec.with_window(Duration::from_secs_f64(ms.max(0.0) / 1e3));
    }
    if let Some(n) = j.get("nodes").and_then(Json::as_usize) {
        spec = spec.with_nodes(n);
    }
    if let Some(n) = j.get("executors_per_node").and_then(Json::as_usize) {
        spec = spec.with_executors_per_node(n);
    }
    if let Some(g) = j.get("group").and_then(Json::as_str) {
        spec = spec.with_group(g);
    }
    Ok(spec)
}

fn schedule_from_json(segments: &[Json]) -> Result<RateSchedule> {
    let mut schedule: Option<RateSchedule> = None;
    for seg in segments {
        let bad_pair = || Error::Config("schedule segments must be [secs, rate] pairs".into());
        let pair = seg.as_arr().filter(|p| p.len() == 2).ok_or_else(bad_pair)?;
        let (secs, rate) = (
            pair[0].as_f64().ok_or_else(bad_pair)?,
            pair[1].as_f64().ok_or_else(bad_pair)?,
        );
        schedule = Some(match schedule {
            None => RateSchedule::starting_at(secs, rate),
            Some(s) => s.then(secs, rate),
        });
    }
    schedule.ok_or_else(|| Error::Config("schedule must have at least one segment".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::ThresholdPolicy;

    fn counter_stage(name: &str, topic: &str) -> StageSpec {
        StageSpec::new(name, topic, CountingProcessor::new())
    }

    fn static_source(name: &str, topic: &str) -> SourceSpec {
        SourceSpec::mass(MassConfig::new(SourceKind::KmeansStatic, topic)).with_name(name)
    }

    #[test]
    fn build_validates_a_complete_spec() {
        let app = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 4)])
            .source(static_source("s", "t").with_total_messages(10))
            .stage(counter_stage("c", "t"))
            .autoscale(AutoscaleSpec::for_stage("c", ThresholdPolicy::new(10, 1)))
            .build()
            .unwrap();
        assert_eq!(app.broker.topics[0].partitions, 4);
        assert_eq!(app.sources[0].messages_for(0), 5);
        assert_eq!(app.stages[0].group_name(), "app-c");
        assert_eq!(app.autoscalers[0].name, "c");
    }

    #[test]
    fn build_rejects_unknown_topics_and_duplicates() {
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .stage(counter_stage("c", "other"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown topic 'other'"), "{err}");

        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .source(static_source("s", "missing"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown topic 'missing'"), "{err}");

        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .stage(counter_stage("c", "t"))
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("duplicate stage 'c'"), "{err}");
    }

    #[test]
    fn build_rejects_missing_broker_and_oversubscribed_partitions() {
        let err = StreamingApp::builder().stage(counter_stage("c", "t")).build().unwrap_err();
        assert!(err.to_string().contains("no broker tier"), "{err}");

        // 1 broker node x 12 partitions/node default budget: 13 is over.
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 13)])
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("oversubscribe"), "{err}");

        // Two broker nodes carry the same topic fine.
        StreamingApp::builder()
            .broker(KafkaDescription::new(2), &[("t", 13)])
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap();
    }

    #[test]
    fn build_rejects_incompatible_frameworks_and_bad_autoscalers() {
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .stage(counter_stage("c", "t").with_framework(FrameworkKind::Kafka))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not a processing engine"), "{err}");

        // Dask and Flink are valid stage backends (task-parallel pools).
        for fw in [FrameworkKind::Dask, FrameworkKind::Flink] {
            StreamingApp::builder()
                .broker(KafkaDescription::new(1), &[("t", 1)])
                .stage(counter_stage("c", "t").with_framework(fw))
                .build()
                .unwrap();
        }

        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .stage(counter_stage("c", "t"))
            .autoscale(AutoscaleSpec::for_stage("ghost", ThresholdPolicy::new(10, 1)))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("unknown stage 'ghost'"), "{err}");
    }

    #[test]
    fn json_spec_round_trips_through_the_builder() {
        let text = r#"{
            "machine_nodes": 6,
            "broker": { "nodes": 1, "topics": [ { "name": "points", "partitions": 4 } ] },
            "sources": [ { "name": "gen", "topic": "points", "kind": "kmeans-static",
                           "points_per_msg": 100, "msg_bytes": 0,
                           "producers": 2, "total_messages": 25,
                           "schedule": [[0.5, 100.0], [0.5, 10.0]] } ],
            "stages": [ { "name": "count", "topic": "points", "processor": "counter",
                          "window_ms": 50, "executors_per_node": 2 } ],
            "drain_timeout_secs": 120
        }"#;
        let app = StreamingAppBuilder::from_json_str(text).unwrap().build().unwrap();
        assert_eq!(app.broker.topics[0].name, "points");
        assert_eq!(app.sources[0].name, "gen");
        assert_eq!(app.sources[0].producers, 2);
        assert_eq!(app.sources[0].total_messages, Some(25));
        // 25 over 2 producers: 13 + 12, remainder distributed.
        assert_eq!(app.sources[0].messages_for(0), 13);
        assert_eq!(app.sources[0].messages_for(1), 12);
        assert!(app.sources[0].schedule.is_some());
        assert_eq!(app.stages[0].window, Duration::from_millis(50));
        assert_eq!(app.drain_timeout, Duration::from_secs(120));
    }

    #[test]
    fn json_spec_errors_are_diagnosable() {
        // Missing broker section.
        let err = StreamingAppBuilder::from_json_str(r#"{ "stages": [] }"#).unwrap_err();
        assert!(err.to_string().contains("broker"), "{err}");

        // Unknown source kind.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "sources": [ { "topic": "t", "kind": "storm" } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown source kind 'storm'"), "{err}");

        // Unknown processor.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "stages": [ { "name": "s", "topic": "t", "processor": "wordcount" } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown processor 'wordcount'"), "{err}");

        // Malformed schedule and missing keys.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "sources": [ { "topic": "t", "kind": "kmeans-static", "schedule": [[1.0]] } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("schedule segments"), "{err}");
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "partitions": 1 } ] } }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("missing JSON key 'name'"), "{err}");

        // Not even JSON.
        assert!(StreamingAppBuilder::from_json_str("not json").is_err());
    }

    #[test]
    fn json_spec_rejects_unknown_keys_like_the_cli_rejects_flags() {
        // A typo'd key must be a spec error, not a silent default run.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "sources": [ { "topic": "t", "kind": "kmeans-static",
                                "total_mesages": 10 } ] }"#,
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown source key: total_mesages"), "{msg}");
        assert!(msg.contains("total_messages"), "should list expected keys: {msg}");

        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [], "replicas": 3 } }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown broker key: replicas"), "{err}");

        // "replication" is a valid broker key now, but it must be the
        // structured block, not a bare count.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [], "replication": 3 } }"#,
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("broker.replication must be a JSON object"),
            "{err}"
        );
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [], "replication": { "factor": 2, "acks": "all" } } }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown broker.replication key: acks"), "{err}");

        // Autoscale loops hang off stages, not the top level.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [] }, "autoscale": [] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown spec key: autoscale"), "{err}");
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter",
                               "autoscale": { "up": 100, "down": 10, "cooldown": 5 } } ] }"#,
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("unknown stage autoscale key: cooldown"),
            "{err}"
        );
    }

    #[test]
    fn replication_spec_round_trips_and_is_validated_prelaunch() {
        // Builder surface: .replication composes with .broker in either
        // order (applied at build time).
        let app = StreamingApp::builder()
            .replication(
                ReplicationSpec::new(2)
                    .with_ack_mode(AckMode::Quorum)
                    .with_min_insync(2)
                    .with_replica_lag_max(500)
                    .with_follower_fetch(true),
            )
            .broker(KafkaDescription::new(3), &[("t", 4)])
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap();
        assert_eq!(app.broker.replication.factor, 2);
        assert_eq!(app.broker.replication.ack_mode, AckMode::Quorum);
        assert_eq!(app.broker.replication.min_insync, 2);
        assert_eq!(app.broker.replication.replica_lag_max, 500);
        assert!(app.broker.replication.follower_fetch);

        // Factor 0 and factor > broker nodes are rejected pre-launch.
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .replication(ReplicationSpec::new(0))
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("replication factor must be >= 1"), "{err}");
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(2), &[("t", 1)])
            .replication(ReplicationSpec::new(3))
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("exceeds the broker tier's 2 nodes"), "{err}");

        // JSON surface: same config through the file spec.
        let app = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "nodes": 3,
                             "topics": [ { "name": "t", "partitions": 4 } ],
                             "replication": { "factor": 2, "ack_mode": "quorum",
                                              "min_insync": 2, "replica_lag_max": 500,
                                              "follower_fetch": true } },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter" } ] }"#,
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(app.broker.replication.factor, 2);
        assert_eq!(app.broker.replication.ack_mode, AckMode::Quorum);
        assert_eq!(app.broker.replication.replica_lag_max, 500);
        assert!(app.broker.replication.follower_fetch);
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ],
                             "replication": { "factor": 1, "ack_mode": "always" } } }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown ack_mode 'always'"), "{err}");
    }

    #[test]
    fn racks_round_trip_and_hollow_domains_are_rejected_prelaunch() {
        // Builder surface: .racks composes with .broker in either order.
        let app = StreamingApp::builder()
            .racks(2)
            .broker(KafkaDescription::new(4), &[("t", 4)])
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap();
        assert_eq!(app.broker.racks, 2);
        // Unracked by default.
        let app = StreamingApp::builder()
            .broker(KafkaDescription::new(1), &[("t", 1)])
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap();
        assert_eq!(app.broker.racks, 0);

        // More domains than brokers: rejected before anything launches.
        let err = StreamingApp::builder()
            .broker(KafkaDescription::new(2), &[("t", 1)])
            .racks(3)
            .stage(counter_stage("c", "t"))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("broker.racks 3 exceeds"), "{err}");

        // JSON surface: same knob through the file spec.
        let app = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "nodes": 4, "racks": 2,
                             "topics": [ { "name": "t", "partitions": 4 } ],
                             "replication": { "factor": 2 } },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter" } ] }"#,
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(app.broker.racks, 2);
        assert_eq!(app.broker.replication.factor, 2);

        // TOML lowers to the same schema.
        let app = StreamingAppBuilder::from_toml_str(
            "[broker]\nnodes = 4\nracks = 2\n\n[[broker.topics]]\nname = \"t\"\n\
             partitions = 4\n\n[[stages]]\nname = \"s\"\ntopic = \"t\"\nprocessor = \"counter\"\n",
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(app.broker.racks, 2);

        // A typo'd key stays a spec error.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [], "rakcs": 2 } }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown broker key: rakcs"), "{err}");
    }

    #[test]
    fn per_stage_autoscale_blocks_parse_into_loops() {
        let app = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "nodes": 2, "topics": [ { "name": "t", "partitions": 4 } ] },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter",
                               "autoscale": { "up": 500, "down": 50, "step": 2,
                                              "sustain": 3, "cooldown_secs": 1.5,
                                              "max_extension_nodes": 6, "max_step": 2,
                                              "sample_interval_ms": 100,
                                              "coschedule_broker": true } },
                             { "name": "b", "topic": "t", "processor": "counter",
                               "autoscale": { "policy": "bin-packing",
                                              "node_capacity": 400,
                                              "target": "broker" } } ] }"#,
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(app.autoscalers.len(), 2);
        let stage_loop = &app.autoscalers[0];
        assert_eq!(stage_loop.name, "s");
        assert_eq!(stage_loop.target, ScaleTarget::Stage);
        assert_eq!(stage_loop.max_extension_nodes, 6);
        assert_eq!(stage_loop.max_step, 2);
        assert_eq!(stage_loop.sample_interval, Duration::from_millis(100));
        assert!(stage_loop.coschedule_broker);
        let broker_loop = &app.autoscalers[1];
        assert_eq!(broker_loop.name, "b-broker");
        assert_eq!(broker_loop.target, ScaleTarget::Broker);

        // An empty hysteresis band is a spec error, not a panic.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter",
                               "autoscale": { "up": 10, "down": 10 } } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("hysteresis band is empty"), "{err}");
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "t", "partitions": 1 } ] },
                 "stages": [ { "name": "s", "topic": "t", "processor": "counter",
                               "autoscale": { "policy": "pid", "up": 10, "down": 1 } } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown autoscale policy 'pid'"), "{err}");
    }

    #[test]
    fn dag_specs_round_trip_through_json_and_toml() {
        let app = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "nodes": 1, "topics": [
                     { "name": "raw", "partitions": 2 }, { "name": "hot", "partitions": 2 },
                     { "name": "cold", "partitions": 2 }, { "name": "merged", "partitions": 2 } ] },
                 "stages": [ { "name": "archive", "topic": "merged", "processor": "counter" } ],
                 "splits": [ { "name": "route", "topic": "raw", "branches": ["hot", "cold"],
                               "route": "key-hash", "key_bytes": 1 } ],
                 "merges": [ { "name": "fan-in", "inputs": ["hot", "cold"], "output": "merged",
                               "key_bytes": 1 } ] }"#,
        )
        .unwrap()
        .build()
        .unwrap();
        assert_eq!(app.splits.len(), 1);
        assert_eq!(app.splits[0].branches, vec!["hot", "cold"]);
        assert_eq!(app.splits[0].key_bytes, 1);
        assert_eq!(app.merges[0].output, "merged");

        // output_topic chains a relay stage; TOML lowers identically.
        let toml = r#"
            [broker]
            nodes = 1

            [[broker.topics]]
            name = "raw"
            partitions = 1

            [[broker.topics]]
            name = "out"
            partitions = 1

            [[stages]]
            name = "reconstruct"
            topic = "raw"
            processor = "relay"
            key_bytes = 1
            output_topic = "out"

            [[stages]]
            name = "archive"
            topic = "out"
            processor = "counter"
        "#;
        let app = StreamingAppBuilder::from_toml_str(toml).unwrap().build().unwrap();
        assert_eq!(app.stages[0].output_topic.as_deref(), Some("out"));
        assert_eq!(app.stages[0].processor.name(), "relay");

        // Cycle/dangling validation fires from the file path too.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "a", "partitions": 1 },
                                         { "name": "b", "partitions": 1 } ] },
                 "stages": [ { "name": "s", "topic": "a", "processor": "relay",
                               "output_topic": "b" } ] }"#,
        )
        .unwrap()
        .build()
        .unwrap_err();
        assert!(err.to_string().contains("dangling"), "{err}");

        // Unknown routes and typo'd keys stay spec errors.
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [ { "name": "a", "partitions": 1 },
                                         { "name": "b", "partitions": 1 },
                                         { "name": "c", "partitions": 1 } ] },
                 "splits": [ { "name": "s", "topic": "a", "branches": ["b", "c"],
                               "route": "random" } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown split route 'random'"), "{err}");
        let err = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "topics": [] },
                 "merges": [ { "name": "m", "inputs": ["a", "b"], "output": "c",
                               "keybytes": 1 } ] }"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown merge key: keybytes"), "{err}");

        // Autoscalers may watch split nodes and merge legs by name.
        let app = StreamingAppBuilder::from_json_str(
            r#"{ "broker": { "nodes": 1, "topics": [
                     { "name": "raw", "partitions": 2 }, { "name": "hot", "partitions": 2 },
                     { "name": "cold", "partitions": 2 }, { "name": "merged", "partitions": 2 } ] },
                 "stages": [ { "name": "archive", "topic": "merged", "processor": "counter" } ],
                 "splits": [ { "name": "route", "topic": "raw", "branches": ["hot", "cold"],
                               "route": "round-robin" } ],
                 "merges": [ { "name": "fan-in", "inputs": ["hot", "cold"],
                               "output": "merged" } ] }"#,
        )
        .unwrap()
        .autoscale(AutoscaleSpec::for_stage("fan-in:hot", ThresholdPolicy::new(10, 1)))
        .build()
        .unwrap();
        assert_eq!(app.autoscalers[0].stage, "fan-in:hot");
    }

    #[test]
    fn toml_specs_lower_to_the_same_schema_as_json() {
        let toml = r#"
            machine_nodes = 6
            drain_timeout_secs = 120

            [broker]
            nodes = 2

            [[broker.topics]]
            name = "points"
            partitions = 4

            [broker.replication]
            factor = 2
            ack_mode = "quorum"
            min_insync = 2
            replica_lag_max = 500
            follower_fetch = true

            [[sources]]
            name = "gen"
            topic = "points"
            kind = "kmeans-static"
            producers = 2
            total_messages = 25

            [[stages]]
            name = "count"
            topic = "points"
            processor = "counter"
            window_ms = 50

            [stages.autoscale]
            up = 500
            down = 50
            coschedule_broker = true
        "#;
        let app = StreamingAppBuilder::from_toml_str(toml).unwrap().build().unwrap();
        assert_eq!(app.broker.topics[0].name, "points");
        assert_eq!(app.broker.replication.factor, 2);
        assert_eq!(app.broker.replication.ack_mode, AckMode::Quorum);
        assert_eq!(app.broker.replication.replica_lag_max, 500);
        assert!(app.broker.replication.follower_fetch);
        assert_eq!(app.sources[0].total_messages, Some(25));
        assert_eq!(app.stages[0].window, Duration::from_millis(50));
        assert_eq!(app.autoscalers.len(), 1);
        assert!(app.autoscalers[0].coschedule_broker);
        assert_eq!(app.drain_timeout, Duration::from_secs(120));

        // Strict unknown-key rejection flows through the TOML path too.
        let err = StreamingAppBuilder::from_toml_str(
            "[broker]\nreplicas = 3\n\n[[broker.topics]]\nname = \"t\"\npartitions = 1\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown broker key: replicas"), "{err}");
    }
}
