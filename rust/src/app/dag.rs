//! Dataflow DAGs: chained, branching, re-merging pipelines as specs.
//!
//! The paper's headline use case — light-source reconstruction feeding
//! compression feeding archival — is a *multi-stage* pipeline, but a
//! [`super::StageSpec`] historically consumed one topic and terminated
//! there.  This module makes the spec a DAG:
//!
//! * stages grow an `output_topic` (stage chaining): a stage's
//!   [`super::StreamProcessor`] emits derived records through an
//!   [`crate::engine::Emitter`], re-keyed through the broker's
//!   [`crate::broker::key_hash`] route, and the engine flushes those
//!   emissions *before* committing the stage's input offsets — the
//!   invariant topological drain rests on;
//! * [`SplitSpec`] routes one topic's records across N branch topics by
//!   a [`SplitRoute`] (key hash, size threshold, round-robin, or a user
//!   predicate over the record bytes);
//! * [`MergeSpec`] fans branch topics back into one output topic.
//!
//! [`lower`] validates the whole graph pre-launch — every referenced
//! topic must exist, every produced edge must have a consumer (dangling
//! edges are configuration bugs that silently strand records), and the
//! graph must be acyclic — and returns the runtime nodes in topological
//! order.  [`super::AppHandle::drain_and_stop`] drains in exactly that
//! order: sources are fenced first, then each node is drained only
//! after all of its upstream nodes report zero lag on a *current* topic
//! epoch ([`crate::broker::Topic::is_current`]), so an in-flight
//! repartition can never fake a drain.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::broker::{jump_hash, key_hash, Record};
use crate::engine::{Emitter, TaskContext};
use crate::error::{Error, Result};
use crate::pilot::FrameworkKind;

use super::spec::StreamingApp;
use super::StreamProcessor;

/// How a [`SplitSpec`] routes each record to a branch.
#[derive(Clone)]
pub enum SplitRoute {
    /// Jump-consistent hash of the record's key prefix
    /// ([`SplitSpec::with_key_bytes`]) over the branch list: equal keys
    /// always take the same branch, so per-key order survives the
    /// split *and* the downstream merge.
    KeyHash,
    /// Records at or above the byte threshold take branch 1, smaller
    /// ones branch 0 (the classic small/large payload split).
    SizeThreshold(usize),
    /// Rotate across branches (load balancing; per-key order across a
    /// later merge is not preserved).
    RoundRobin,
    /// User predicate over the record bytes → branch index (clamped to
    /// the branch count).
    Predicate(Arc<dyn Fn(&[u8]) -> usize + Send + Sync>),
}

impl std::fmt::Debug for SplitRoute {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SplitRoute::KeyHash => write!(f, "KeyHash"),
            SplitRoute::SizeThreshold(b) => write!(f, "SizeThreshold({b})"),
            SplitRoute::RoundRobin => write!(f, "RoundRobin"),
            SplitRoute::Predicate(_) => write!(f, "Predicate(..)"),
        }
    }
}

/// A split node: consume `topic`, route every record to one of
/// `branches` by the [`SplitRoute`].
#[derive(Clone)]
pub struct SplitSpec {
    pub name: String,
    /// Input topic.
    pub topic: String,
    /// Branch output topics (≥ 2).
    pub branches: Vec<String>,
    pub route: SplitRoute,
    /// Leading value bytes that form the record key (0 = unkeyed;
    /// required > 0 for [`SplitRoute::KeyHash`]).
    pub key_bytes: usize,
    pub window: Duration,
    pub nodes: usize,
    pub executors_per_node: usize,
    pub group: Option<String>,
}

impl SplitSpec {
    pub fn new(name: &str, topic: &str, branches: &[&str], route: SplitRoute) -> Self {
        SplitSpec {
            name: name.to_string(),
            topic: topic.to_string(),
            branches: branches.iter().map(|b| b.to_string()).collect(),
            route,
            key_bytes: 0,
            window: Duration::from_millis(250),
            nodes: 1,
            executors_per_node: 2,
            group: None,
        }
    }

    pub fn with_key_bytes(mut self, n: usize) -> Self {
        self.key_bytes = n;
        self
    }

    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_executors_per_node(mut self, executors: usize) -> Self {
        self.executors_per_node = executors;
        self
    }

    pub fn with_group(mut self, group: &str) -> Self {
        self.group = Some(group.to_string());
        self
    }

    pub fn group_name(&self) -> String {
        self.group.clone().unwrap_or_else(|| format!("app-{}", self.name))
    }
}

/// A merge node: fan `inputs` back into `output` (one relay job per
/// input topic, all sharing the node's executor pool).
#[derive(Debug, Clone)]
pub struct MergeSpec {
    pub name: String,
    /// Input branch topics (≥ 2).
    pub inputs: Vec<String>,
    /// Merged output topic.
    pub output: String,
    /// Leading value bytes that form the record key (0 = unkeyed).
    pub key_bytes: usize,
    pub window: Duration,
    pub nodes: usize,
    pub executors_per_node: usize,
    pub group: Option<String>,
}

impl MergeSpec {
    pub fn new(name: &str, inputs: &[&str], output: &str) -> Self {
        MergeSpec {
            name: name.to_string(),
            inputs: inputs.iter().map(|i| i.to_string()).collect(),
            output: output.to_string(),
            key_bytes: 0,
            window: Duration::from_millis(250),
            nodes: 1,
            executors_per_node: 2,
            group: None,
        }
    }

    pub fn with_key_bytes(mut self, n: usize) -> Self {
        self.key_bytes = n;
        self
    }

    pub fn with_window(mut self, window: Duration) -> Self {
        self.window = window;
        self
    }

    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes;
        self
    }

    pub fn with_executors_per_node(mut self, executors: usize) -> Self {
        self.executors_per_node = executors;
        self
    }

    pub fn with_group(mut self, group: &str) -> Self {
        self.group = Some(group.to_string());
        self
    }

    pub fn group_name(&self) -> String {
        self.group.clone().unwrap_or_else(|| format!("app-{}", self.name))
    }
}

/// Key prefix of a record's value under a `key_bytes` framing
/// (None when the node is unkeyed).
fn key_of(value: &[u8], key_bytes: usize) -> Option<&[u8]> {
    if key_bytes == 0 {
        None
    } else {
        Some(&value[..key_bytes.min(value.len())])
    }
}

/// Pass-through processor for chain hops and merge legs: re-emits every
/// record, keyed by its leading `key_bytes` value bytes, optionally
/// burning a fixed per-message cost (models a compression/archival
/// kernel; the knob the hot-branch autoscaling demos lean on).  The
/// spec-file name is `"relay"`.
pub struct RelayProcessor {
    key_bytes: usize,
    per_message: Option<Duration>,
    messages: AtomicU64,
}

impl RelayProcessor {
    pub fn new(key_bytes: usize) -> Arc<Self> {
        Arc::new(RelayProcessor {
            key_bytes,
            per_message: None,
            messages: AtomicU64::new(0),
        })
    }

    pub fn with_cost(key_bytes: usize, per_message: Duration) -> Arc<Self> {
        Arc::new(RelayProcessor {
            key_bytes,
            per_message: Some(per_message),
            messages: AtomicU64::new(0),
        })
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
}

impl StreamProcessor for RelayProcessor {
    fn name(&self) -> &str {
        "relay"
    }

    fn process_window(&self, _ctx: &TaskContext, window: &[Record]) -> Result<()> {
        // Sink position (no output topic): count only.
        self.messages.fetch_add(window.len() as u64, Ordering::Relaxed);
        if let Some(d) = self.per_message {
            std::thread::sleep(d * window.len() as u32);
        }
        Ok(())
    }

    fn process_window_emit(
        &self,
        _ctx: &TaskContext,
        window: &[Record],
        out: &mut Emitter,
    ) -> Result<()> {
        for r in window {
            if let Some(d) = self.per_message {
                std::thread::sleep(d);
            }
            self.messages.fetch_add(1, Ordering::Relaxed);
            out.emit(key_of(&r.value, self.key_bytes), r.value.to_vec());
        }
        Ok(())
    }
}

/// The router behind a [`SplitSpec`]: emits each record to the branch
/// its [`SplitRoute`] picks, keyed by the node's `key_bytes` framing.
pub(crate) struct SplitProcessor {
    route: SplitRoute,
    key_bytes: usize,
    n_branches: usize,
    rr_next: AtomicUsize,
}

impl SplitProcessor {
    pub(crate) fn new(spec: &SplitSpec) -> Arc<Self> {
        Arc::new(SplitProcessor {
            route: spec.route.clone(),
            key_bytes: spec.key_bytes,
            n_branches: spec.branches.len(),
            rr_next: AtomicUsize::new(0),
        })
    }

    fn branch_for(&self, value: &[u8]) -> usize {
        match &self.route {
            SplitRoute::KeyHash => {
                let key = key_of(value, self.key_bytes).unwrap_or(value);
                jump_hash(key_hash(key), self.n_branches)
            }
            SplitRoute::SizeThreshold(bytes) => usize::from(value.len() >= *bytes),
            SplitRoute::RoundRobin => {
                self.rr_next.fetch_add(1, Ordering::Relaxed) % self.n_branches
            }
            SplitRoute::Predicate(f) => f(value).min(self.n_branches - 1),
        }
    }
}

impl StreamProcessor for SplitProcessor {
    fn name(&self) -> &str {
        "split"
    }

    fn process_window(&self, _ctx: &TaskContext, _window: &[Record]) -> Result<()> {
        // A split is never a sink; the engine always hands it outputs.
        Err(Error::App("split node launched without output topics".into()))
    }

    fn process_window_emit(
        &self,
        _ctx: &TaskContext,
        window: &[Record],
        out: &mut Emitter,
    ) -> Result<()> {
        for r in window {
            let branch = self.branch_for(&r.value);
            out.emit_to(branch, key_of(&r.value, self.key_bytes), r.value.to_vec());
        }
        Ok(())
    }
}

/// One lowered runtime node of the DAG — what [`super::AppHandle`]
/// actually launches.  Stages lower 1:1; a split lowers to one
/// multi-output node; a merge lowers to one relay leg per input topic
/// (all legs share the merge's group name, so per-leg lag is the
/// per-edge signal).
pub(crate) struct DagNode {
    pub name: String,
    /// Input topic.
    pub topic: String,
    /// Downstream topics (`Emitter` branch order).  Empty for sinks.
    pub outputs: Vec<String>,
    pub processor: Arc<dyn StreamProcessor>,
    pub window: Duration,
    pub framework: FrameworkKind,
    pub nodes: usize,
    pub executors_per_node: usize,
    pub group: String,
}

/// Lower the app's stages/splits/merges into runtime nodes, validate
/// the graph (unknown topics, degenerate splits/merges, dangling
/// produced edges, cycles), and return the nodes in topological order
/// — the launch *and* drain order.
pub(crate) fn lower(app: &StreamingApp) -> Result<Vec<DagNode>> {
    let err = |m: String| Err(Error::App(m));
    let topic_exists = |t: &str| app.broker.topics.iter().any(|x| x.name == t);

    let mut nodes: Vec<DagNode> = Vec::new();
    for s in &app.stages {
        if let Some(out) = &s.output_topic {
            if !topic_exists(out) {
                return err(format!(
                    "stage '{}' outputs to unknown topic '{out}'",
                    s.name
                ));
            }
        }
        nodes.push(DagNode {
            name: s.name.clone(),
            topic: s.topic.clone(),
            outputs: s.output_topic.iter().cloned().collect(),
            processor: s.processor.clone(),
            window: s.window,
            framework: s.framework,
            nodes: s.nodes,
            executors_per_node: s.executors_per_node,
            group: s.group_name(),
        });
    }
    for s in &app.splits {
        if s.branches.len() < 2 {
            return err(format!(
                "split '{}' needs at least 2 branches (has {})",
                s.name,
                s.branches.len()
            ));
        }
        if matches!(s.route, SplitRoute::KeyHash) && s.key_bytes == 0 {
            return err(format!(
                "split '{}' routes by key hash but key_bytes is 0",
                s.name
            ));
        }
        for t in std::iter::once(&s.topic).chain(&s.branches) {
            if !topic_exists(t) {
                return err(format!("split '{}' references unknown topic '{t}'", s.name));
            }
        }
        if s.window.is_zero() || s.nodes == 0 || s.executors_per_node == 0 {
            return err(format!("split '{}' has a zero window/nodes/executors", s.name));
        }
        nodes.push(DagNode {
            name: s.name.clone(),
            topic: s.topic.clone(),
            outputs: s.branches.clone(),
            processor: SplitProcessor::new(s),
            window: s.window,
            // Routers are light pass-through jobs; run them on the
            // futures engine rather than a full micro-batch pilot.
            framework: FrameworkKind::Dask,
            nodes: s.nodes,
            executors_per_node: s.executors_per_node,
            group: s.group_name(),
        });
    }
    for m in &app.merges {
        if m.inputs.len() < 2 {
            return err(format!(
                "merge '{}' needs at least 2 inputs (has {})",
                m.name,
                m.inputs.len()
            ));
        }
        for t in m.inputs.iter().chain(std::iter::once(&m.output)) {
            if !topic_exists(t) {
                return err(format!("merge '{}' references unknown topic '{t}'", m.name));
            }
        }
        if m.window.is_zero() || m.nodes == 0 || m.executors_per_node == 0 {
            return err(format!("merge '{}' has a zero window/nodes/executors", m.name));
        }
        for input in &m.inputs {
            nodes.push(DagNode {
                name: format!("{}:{input}", m.name),
                topic: input.clone(),
                outputs: vec![m.output.clone()],
                processor: RelayProcessor::new(m.key_bytes),
                window: m.window,
                framework: FrameworkKind::Dask,
                nodes: m.nodes,
                executors_per_node: m.executors_per_node,
                group: m.group_name(),
            });
        }
    }

    // Node names are the report/autoscale namespace: one name, one node.
    for (i, a) in nodes.iter().enumerate() {
        if nodes.iter().skip(i + 1).any(|b| b.name == a.name) {
            return err(format!("duplicate DAG node name '{}'", a.name));
        }
    }

    // Dangling produced edges: a topic a node emits to that nothing
    // consumes strands records silently — reject pre-launch.  (Inputs
    // without in-spec producers stay legal: external producers feed
    // them, exactly like single-stage apps today.)
    for n in &nodes {
        for out in &n.outputs {
            if !nodes.iter().any(|c| c.topic == *out) {
                return err(format!(
                    "node '{}' emits to topic '{out}' but no stage/split/merge consumes it \
                     (dangling edge)",
                    n.name
                ));
            }
        }
    }

    // Kahn's algorithm over topic edges: node A precedes node B when B
    // consumes a topic A produces.  Anything left unsorted is a cycle.
    let mut indegree: Vec<usize> = nodes
        .iter()
        .map(|n| {
            nodes
                .iter()
                .filter(|u| u.outputs.contains(&n.topic))
                .count()
        })
        .collect();
    let mut order: Vec<usize> = Vec::with_capacity(nodes.len());
    let mut ready: Vec<usize> =
        (0..nodes.len()).filter(|&i| indegree[i] == 0).collect();
    while let Some(i) = ready.pop() {
        order.push(i);
        for (j, n) in nodes.iter().enumerate() {
            if nodes[i].outputs.contains(&n.topic) {
                indegree[j] -= 1;
                if indegree[j] == 0 {
                    ready.push(j);
                }
            }
        }
    }
    if order.len() != nodes.len() {
        let stuck: Vec<&str> = (0..nodes.len())
            .filter(|i| !order.contains(i))
            .map(|i| nodes[i].name.as_str())
            .collect();
        return err(format!("DAG contains a cycle through: {}", stuck.join(", ")));
    }
    // `order` indexes in topo order, but Vec::swap_remove would scramble
    // it; drain by mapping into Options instead.
    let mut slots: Vec<Option<DagNode>> = nodes.into_iter().map(Some).collect();
    Ok(order
        .into_iter()
        .map(|i| slots[i].take().expect("topo order visits each node once"))
        .collect())
}

/// The DAG's consumer edges, in the same topological order as
/// [`lower`]: one `(node name, topic, group)` per node — what the
/// per-edge autoscale probes watch.
pub(crate) fn edges(nodes: &[DagNode]) -> Vec<(String, String, String)> {
    nodes
        .iter()
        .map(|n| (n.name.clone(), n.topic.clone(), n.group.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{CountingProcessor, StreamingApp};
    use crate::broker::SharedSlice;

    fn record(bytes: &[u8]) -> Record {
        Record {
            offset: 0,
            timestamp_ns: 0,
            value: SharedSlice::from_vec(bytes.to_vec()),
        }
    }

    fn ctx() -> TaskContext {
        TaskContext {
            partition: 0,
            node: 0,
            batch: 0,
        }
    }

    /// A broker spec holding every named topic (1 partition each).
    fn base(topics: &[&str]) -> crate::app::StreamingAppBuilder {
        let pairs: Vec<(&str, usize)> = topics.iter().map(|t| (*t, 1)).collect();
        StreamingApp::builder().broker(crate::pilot::KafkaDescription::new(1), &pairs)
    }

    #[test]
    fn chain_lowers_in_topological_order() {
        let app = base(&["raw", "mid", "out"])
            // Declared sink-first on purpose: lowering must reorder.
            .stage(
                crate::app::StageSpec::new("archive", "out", CountingProcessor::new()),
            )
            .stage(
                crate::app::StageSpec::new("reconstruct", "raw", RelayProcessor::new(1))
                    .with_output_topic("mid"),
            )
            .stage(
                crate::app::StageSpec::new("compress", "mid", RelayProcessor::new(1))
                    .with_output_topic("out"),
            )
            .build()
            .unwrap();
        let nodes = lower(&app).unwrap();
        let names: Vec<&str> = nodes.iter().map(|n| n.name.as_str()).collect();
        assert_eq!(names, vec!["reconstruct", "compress", "archive"]);
        assert_eq!(nodes[0].outputs, vec!["mid".to_string()]);
        assert!(nodes[2].outputs.is_empty());
    }

    #[test]
    fn split_and_merge_lower_around_branch_stages() {
        let app = base(&["in", "hot", "cold", "merged"])
            .split(SplitSpec::new(
                "route",
                "in",
                &["hot", "cold"],
                SplitRoute::SizeThreshold(64),
            ))
            .merge(MergeSpec::new("fan-in", &["hot", "cold"], "merged"))
            .stage(crate::app::StageSpec::new(
                "archive",
                "merged",
                CountingProcessor::new(),
            ))
            .build()
            .unwrap();
        let nodes = lower(&app).unwrap();
        let names: Vec<&str> = nodes.iter().map(|n| n.name.as_str()).collect();
        let pos = |n: &str| names.iter().position(|x| *x == n).unwrap();
        assert_eq!(names.len(), 4, "split + 2 merge legs + archive: {names:?}");
        assert!(pos("route") < pos("fan-in:hot"));
        assert!(pos("route") < pos("fan-in:cold"));
        assert!(pos("fan-in:hot") < pos("archive"));
        assert!(pos("fan-in:cold") < pos("archive"));
        // Both merge legs share one group: per-leg lag is per-edge lag.
        assert_eq!(nodes[pos("fan-in:hot")].group, nodes[pos("fan-in:cold")].group);
    }

    #[test]
    fn cycles_and_dangling_edges_are_rejected() {
        // a → b → a is a cycle.
        let cycle = base(&["a", "b"])
            .stage(
                crate::app::StageSpec::new("s1", "a", RelayProcessor::new(0))
                    .with_output_topic("b"),
            )
            .stage(
                crate::app::StageSpec::new("s2", "b", RelayProcessor::new(0))
                    .with_output_topic("a"),
            )
            .build();
        let msg = format!("{}", cycle.err().unwrap());
        assert!(msg.contains("cycle"), "{msg}");

        // An output topic no node consumes is a dangling edge.
        let dangling = base(&["a", "b"])
            .stage(
                crate::app::StageSpec::new("s1", "a", RelayProcessor::new(0))
                    .with_output_topic("b"),
            )
            .build();
        let msg = format!("{}", dangling.err().unwrap());
        assert!(msg.contains("dangling"), "{msg}");

        // Unknown output topic.
        let unknown = base(&["a"])
            .stage(
                crate::app::StageSpec::new("s1", "a", RelayProcessor::new(0))
                    .with_output_topic("ghost"),
            )
            .build();
        let msg = format!("{}", unknown.err().unwrap());
        assert!(msg.contains("unknown topic 'ghost'"), "{msg}");
    }

    #[test]
    fn degenerate_splits_and_merges_are_rejected() {
        let one_branch = base(&["a", "b"])
            .split(SplitSpec::new("s", "a", &["b"], SplitRoute::RoundRobin))
            .build();
        assert!(format!("{}", one_branch.err().unwrap()).contains("at least 2 branches"));

        let keyless = base(&["a", "b", "c"])
            .split(SplitSpec::new("s", "a", &["b", "c"], SplitRoute::KeyHash))
            .stage(crate::app::StageSpec::new("x", "b", CountingProcessor::new()))
            .stage(crate::app::StageSpec::new("y", "c", CountingProcessor::new()))
            .build();
        assert!(format!("{}", keyless.err().unwrap()).contains("key_bytes"));

        let one_input = base(&["a", "b"])
            .merge(MergeSpec::new("m", &["a"], "b"))
            .build();
        assert!(format!("{}", one_input.err().unwrap()).contains("at least 2 inputs"));

        let dup = base(&["a", "b", "c"])
            .stage(crate::app::StageSpec::new("same", "a", CountingProcessor::new()))
            .split(
                SplitSpec::new("same", "a", &["b", "c"], SplitRoute::RoundRobin)
                    .with_key_bytes(1),
            )
            .stage(crate::app::StageSpec::new("x", "b", CountingProcessor::new()))
            .stage(crate::app::StageSpec::new("y", "c", CountingProcessor::new()))
            .build();
        assert!(format!("{}", dup.err().unwrap()).contains("duplicate DAG node name"));
    }

    #[test]
    fn split_routes_are_deterministic_and_key_stable() {
        let spec = SplitSpec::new("s", "a", &["b", "c"], SplitRoute::KeyHash).with_key_bytes(1);
        let p = SplitProcessor::new(&spec);
        // Same key prefix, different payload tails: one branch.
        assert_eq!(p.branch_for(&[7, 1, 2]), p.branch_for(&[7, 9, 9, 9]));

        let spec = SplitSpec::new("s", "a", &["b", "c"], SplitRoute::SizeThreshold(3));
        let p = SplitProcessor::new(&spec);
        assert_eq!(p.branch_for(&[1, 2]), 0);
        assert_eq!(p.branch_for(&[1, 2, 3]), 1);

        let route = SplitRoute::Predicate(Arc::new(|v: &[u8]| v[0] as usize));
        let spec = SplitSpec::new("s", "a", &["b", "c"], route);
        let p = SplitProcessor::new(&spec);
        assert_eq!(p.branch_for(&[0]), 0);
        assert_eq!(p.branch_for(&[1]), 1);
        assert_eq!(p.branch_for(&[200]), 1, "predicate clamps to branch count");
    }

    #[test]
    fn split_emitter_fans_records_across_branches() {
        let spec = SplitSpec::new("s", "a", &["b", "c"], SplitRoute::RoundRobin);
        let p = SplitProcessor::new(&spec);
        let mut out = Emitter::default();
        p.process_window_emit(&ctx(), &[record(&[1]), record(&[2]), record(&[3])], &mut out)
            .unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn relay_re_emits_with_key_framing() {
        let relay = RelayProcessor::new(2);
        let mut out = Emitter::default();
        relay
            .process_window_emit(&ctx(), &[record(&[1, 2, 3, 4])], &mut out)
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(relay.messages(), 1);
        assert_eq!(StreamProcessor::name(&*relay), "relay");
    }
}
