//! Crate-wide error type.
//!
//! Every fallible public API in the coordinator returns
//! [`Result<T>`](Result) with this error; XLA runtime errors, config
//! errors and coordination failures (e.g. producing to a stopped broker)
//! are all unified here so the CLI and examples can `?` freely.
//!
//! The offline dependency set has no `thiserror` (DESIGN.md
//! §Substitutions), so `Display`/`Error`/`From` are implemented by hand.

/// Unified error type for the Pilot-Streaming coordinator.
#[derive(Debug)]
pub enum Error {
    /// Underlying XLA/PJRT failure (compile, execute, literal marshal).
    Xla(String),

    /// I/O failure (artifact loading, CSV emit, config read).
    Io(std::io::Error),

    /// Malformed configuration or experiment description.
    Config(String),

    /// Artifact manifest problems (missing artifact, shape mismatch).
    Artifact(String),

    /// Broker-side failures (unknown topic/partition, offset out of range,
    /// produce to a stopped cluster).
    Broker(String),

    /// A quorum-acked produce was rejected because the partition's ISR
    /// had shrunk below `min_insync` — the write was refused rather
    /// than accepted at reduced durability.  Typed (unlike the general
    /// [`Error::Broker`] bag) so producer/app retry loops can match on
    /// it and back off until the ISR re-expands; the `Display` text is
    /// byte-identical to the stringly form it replaced.
    NotEnoughInSyncReplicas {
        topic: String,
        partition: usize,
        /// In-sync replica count observed at the produce.
        isr: usize,
        /// The replica set's configured quorum floor.
        min_insync: usize,
    },

    /// A produce raced a topic repartition: the caller routed the record
    /// under a partition-set epoch that was sealed before the append
    /// could land.  Producers recover by refreshing their routing table
    /// and re-sending (see `broker::Producer`).
    StaleEpoch(String),

    /// A blocking fetch outlived the bounded wait on a quiesced data-
    /// plane shard (a repartition was sealing the shard's partitions
    /// and never resumed it).  Transient by design: consumers retry on
    /// their next poll, by which time the shard has resumed — see
    /// `broker::shard`.
    ShardQuiesced(String),

    /// Stream-engine failures (job not running, processor panic).
    Engine(String),

    /// Pilot lifecycle violations (extend a non-running pilot, unknown
    /// framework plugin, resource exhaustion on the machine).
    Pilot(String),

    /// Malformed wire message on the data plane.
    Wire(String),

    /// Streaming-application spec violations (stage referencing an
    /// unknown topic, oversubscribed broker I/O budget, incompatible
    /// stage framework) and application-lifecycle misuse.
    App(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Broker(m) => write!(f, "broker: {m}"),
            Error::NotEnoughInSyncReplicas {
                topic,
                partition,
                isr,
                min_insync,
            } => write!(
                f,
                "broker: {topic}/{partition}: not enough in-sync replicas ({isr} of min_insync {min_insync})"
            ),
            Error::StaleEpoch(m) => write!(f, "stale epoch: {m}"),
            Error::ShardQuiesced(m) => write!(f, "shard quiesced: {m}"),
            Error::Engine(m) => write!(f, "engine: {m}"),
            Error::Pilot(m) => write!(f, "pilot: {m}"),
            Error::Wire(m) => write!(f, "wire: {m}"),
            Error::App(m) => write!(f, "app: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_layer() {
        assert_eq!(Error::Broker("x".into()).to_string(), "broker: x");
        assert_eq!(
            Error::NotEnoughInSyncReplicas {
                topic: "t".into(),
                partition: 3,
                isr: 1,
                min_insync: 2,
            }
            .to_string(),
            "broker: t/3: not enough in-sync replicas (1 of min_insync 2)"
        );
        assert_eq!(Error::Pilot("y".into()).to_string(), "pilot: y");
        assert_eq!(Error::App("z".into()).to_string(), "app: z");
        assert_eq!(
            Error::ShardQuiesced("s".into()).to_string(),
            "shard quiesced: s"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().starts_with("io: "));
        assert!(std::error::Error::source(&io).is_some());
        assert!(std::error::Error::source(&Error::Xla("z".into())).is_none());
    }
}
