//! Crate-wide error type.
//!
//! Every fallible public API in the coordinator returns
//! [`Result<T>`](Result) with this error; XLA runtime errors, config
//! errors and coordination failures (e.g. producing to a stopped broker)
//! are all unified here so the CLI and examples can `?` freely.

use thiserror::Error;

/// Unified error type for the Pilot-Streaming coordinator.
#[derive(Error, Debug)]
pub enum Error {
    /// Underlying XLA/PJRT failure (compile, execute, literal marshal).
    #[error("xla: {0}")]
    Xla(String),

    /// I/O failure (artifact loading, CSV emit, config read).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed configuration or experiment description.
    #[error("config: {0}")]
    Config(String),

    /// Artifact manifest problems (missing artifact, shape mismatch).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Broker-side failures (unknown topic/partition, offset out of range,
    /// produce to a stopped cluster).
    #[error("broker: {0}")]
    Broker(String),

    /// Stream-engine failures (job not running, processor panic).
    #[error("engine: {0}")]
    Engine(String),

    /// Pilot lifecycle violations (extend a non-running pilot, unknown
    /// framework plugin, resource exhaustion on the machine).
    #[error("pilot: {0}")]
    Pilot(String),

    /// Malformed wire message on the data plane.
    #[error("wire: {0}")]
    Wire(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
