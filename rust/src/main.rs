//! `pilot-streaming` — the coordinator CLI (paper Listing 3).
//!
//! ```text
//! pilot-streaming start --framework kafka --nodes 4     # boot a cluster
//! pilot-streaming demo  --processor gridrec             # mini pipeline
//! pilot-streaming exp fig6|fig7|fig8|fig9|table1|headline|elastic|all
//! pilot-streaming calibrate                             # cost model
//! pilot-streaming artifacts                             # list artifacts
//! ```
//!
//! Argument parsing is hand-rolled (offline environment: no clap in the
//! vendored dependency set).

use std::collections::HashMap;
use std::time::Duration;

use pilot_streaming::cluster::Machine;
use pilot_streaming::config::{CostPreset, ExperimentConfig};
use pilot_streaming::exp;
use pilot_streaming::miniapp::{
    MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind,
};
use pilot_streaming::pilot::{FrameworkKind, PilotComputeDescription, PilotComputeService};
use pilot_streaming::runtime::ModelRuntime;
use pilot_streaming::sim::CostModel;
use pilot_streaming::util::Json;
use pilot_streaming::{Error, Result};

const USAGE: &str = "\
pilot-streaming — stream processing framework for HPC (HPDC'18 reproduction)

USAGE:
  pilot-streaming start --framework <kafka|spark|dask|flink> --nodes <n>
                        [--machine-nodes <n>] [--extend <n>]
  pilot-streaming demo  [--processor <kmeans|gridrec|mlem>] [--messages <n>]
  pilot-streaming exp   <fig6|fig7|fig8|fig9|table1|headline|elastic|dag|all>
                        [--preset <calibrated|paper-era|rackfail>] [--out <dir>]
                        [--config <file.json>]
  pilot-streaming exp   app --spec <app.json|app.toml>

  pilot-streaming calibrate [--reps <n>]
  pilot-streaming artifacts
  pilot-streaming bench-gate --current <run.json> --baseline <committed.json>
                        --name <bench-name> [--max-ratio <r>] [--stat <mean|p50|p95>]
                        [--metric <workload-metric>]  (gate a workload throughput
                        metric, higher-is-better; --stat is ignored)
";

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                out.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Reject flags the command does not read.  Silently ignoring an
/// unknown `--flag` turns typos (`--perset calibrated`) into runs with
/// default settings that *look* like the requested experiment — a usage
/// error is the honest answer.
fn check_flags(cmd: &str, flags: &HashMap<String, String>, allowed: &[&str]) -> Result<()> {
    let mut unknown: Vec<&str> = flags
        .keys()
        .map(String::as_str)
        .filter(|k| !allowed.contains(k))
        .collect();
    if unknown.is_empty() {
        return Ok(());
    }
    unknown.sort_unstable();
    let expected = if allowed.is_empty() {
        "the command takes no flags".to_string()
    } else {
        format!(
            "expected: {}",
            allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
        )
    };
    Err(Error::Config(format!(
        "unknown flag{} for '{cmd}': {} ({expected})\n{USAGE}",
        if unknown.len() == 1 { "" } else { "s" },
        unknown.iter().map(|u| format!("--{u}")).collect::<Vec<_>>().join(", "),
    )))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{USAGE}");
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "start" => {
            check_flags("start", &flags, &["framework", "nodes", "machine-nodes", "extend"])?;
            cmd_start(&flags)
        }
        "demo" => {
            check_flags("demo", &flags, &["processor", "messages"])?;
            cmd_demo(&flags)
        }
        "exp" if args.get(1).map(String::as_str) == Some("app") => {
            check_flags("exp app", &flags, &["spec"])?;
            cmd_app(&flags)
        }
        "exp" => {
            check_flags("exp", &flags, &["preset", "out", "config"])?;
            cmd_exp(args.get(1).map(|s| s.as_str()).unwrap_or(""), &flags)
        }
        "calibrate" => {
            check_flags("calibrate", &flags, &["reps"])?;
            cmd_calibrate(&flags)
        }
        "artifacts" => {
            check_flags("artifacts", &flags, &[])?;
            cmd_artifacts()
        }
        "bench-gate" => {
            check_flags(
                "bench-gate",
                &flags,
                &["current", "baseline", "name", "max-ratio", "stat", "metric"],
            )?;
            cmd_bench_gate(&flags)
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}'\n{USAGE}"))),
    }
}

/// Boot a pilot-managed framework cluster (paper Listing 2/3).
fn cmd_start(flags: &HashMap<String, String>) -> Result<()> {
    let framework =
        FrameworkKind::parse(flags.get("framework").map(|s| s.as_str()).unwrap_or("spark"))?;
    let nodes: usize = flags
        .get("nodes")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let machine_nodes: usize = flags
        .get("machine-nodes")
        .and_then(|s| s.parse().ok())
        .unwrap_or((nodes * 2).max(4));
    let extend: usize = flags
        .get("extend")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);

    let machine = Machine::wrangler(machine_nodes);
    let service = PilotComputeService::new(machine);
    println!("submitting pilot: framework={framework} nodes={nodes} (resource slurm://wrangler)");
    let pilot = service.create_pilot(PilotComputeDescription::new(
        "slurm://wrangler",
        framework,
        nodes,
    ))?;
    let s = pilot.startup().expect("running pilot has startup record");
    println!(
        "pilot {} RUNNING on nodes {:?}\n  queue wait    {:>8.1} s (modeled)\n  bootstrap     {:>8.1} s (modeled)\n  total startup {:>8.1} s",
        pilot.id(),
        pilot.nodes(),
        s.queue_wait_secs,
        s.bootstrap_secs,
        s.total_secs()
    );
    for (k, v) in pilot.config_data() {
        println!("  {k} = {v}");
    }
    if extend > 0 {
        println!("extending by {extend} nodes (paper Listing 4)...");
        let ext = service.extend_pilot(&pilot, extend)?;
        println!("extension pilot {} RUNNING on {:?}", ext.id(), ext.nodes());
        service.stop_pilot(&ext)?;
        println!("extension stopped; cluster resized back");
    }
    service.stop_pilot(&pilot)?;
    println!("pilot stopped, nodes released");
    Ok(())
}

/// Run a small MASS -> Kafka -> MASA pipeline on the real plane.
fn cmd_demo(flags: &HashMap<String, String>) -> Result<()> {
    let kind =
        ProcessorKind::parse(flags.get("processor").map(|s| s.as_str()).unwrap_or("gridrec"))?;
    let messages: usize = flags
        .get("messages")
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let runtime = ModelRuntime::load_default()?;

    let machine = Machine::unthrottled(4);
    let service = PilotComputeService::new(machine);
    let (kafka_pilot, cluster) =
        service.start_kafka(pilot_streaming::pilot::KafkaDescription::new(1))?;
    let (dask_pilot, producers) = service.start_dask(
        pilot_streaming::pilot::DaskDescription::new(1).with_config("workers_per_node", "2"),
    )?;
    let (spark_pilot, engine) = service.start_spark(
        pilot_streaming::pilot::SparkDescription::new(1).with_config("executors_per_node", "2"),
    )?;
    cluster.create_topic("demo", 4)?;

    let source = match kind {
        ProcessorKind::KMeans => SourceKind::KmeansRandom {
            n_centroids: runtime.manifest().kmeans.k,
        },
        _ => SourceKind::Lightsource {
            template: std::sync::Arc::new(runtime.read_f32_file("template_sinogram.bin")?),
        },
    };
    let masa = MasaApp::new(
        MasaConfig::new(kind, "demo", Duration::from_millis(200)),
        runtime,
    );
    println!("warming up XLA executables ({})...", kind.artifact());
    masa.processor.warmup()?;
    let job = masa.start(&engine, cluster.clone())?;

    let mut cfg = MassConfig::new(source, "demo");
    cfg.messages_per_producer = messages.div_ceil(2);
    let mass = MassSource::new(cfg);
    println!("producing {messages} messages...");
    let report = mass.run(&producers, &cluster, 2)?;
    println!(
        "produced {} msgs / {:.1} MB at {:.1} msg/s ({:.1} MB/s)",
        report.messages,
        report.bytes as f64 / 1e6,
        report.msg_rate(),
        report.mb_rate()
    );

    let deadline = std::time::Instant::now() + Duration::from_secs(300);
    while job.stats().processed.messages() < report.messages
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(100));
    }
    let stats = job.stop();
    println!(
        "processed {} msgs, exec {:.1} ms/msg (p50), e2e latency p50 {:.3} s",
        stats.processed.messages(),
        masa.processor.stats.exec_secs.p50_secs() * 1e3,
        masa.processor.stats.e2e_latency.p50_secs(),
    );
    if kind == ProcessorKind::KMeans {
        let model = masa.processor.model();
        println!(
            "kmeans model: {} updates, inertia {:.1}",
            model.updates, model.last_inertia
        );
    }
    service.stop_pilot(&spark_pilot)?;
    service.stop_pilot(&dask_pilot)?;
    service.stop_pilot(&kafka_pilot)?;
    Ok(())
}

/// Run a declarative `StreamingApp` spec from a JSON or TOML file:
/// launch the whole application (broker, sources, stages, autoscale
/// loops), wait for the sources to finish their budget, drain consumer
/// lag to zero and stop everything.  The format is sniffed from the
/// extension (`.toml` → TOML, anything else → JSON); both lower to the
/// same schema.
fn cmd_app(flags: &HashMap<String, String>) -> Result<()> {
    let path = flags
        .get("spec")
        .ok_or_else(|| Error::Config(format!("exp app requires --spec <file.json|.toml>\n{USAGE}")))?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("read {path}: {e}")))?;
    let doc = if std::path::Path::new(path).extension().is_some_and(|e| e == "toml") {
        pilot_streaming::util::toml::parse(&text)?
    } else {
        Json::parse(&text)?
    };
    let machine_nodes = doc.get("machine_nodes").and_then(Json::as_usize).unwrap_or(8);
    let app = pilot_streaming::app::StreamingAppBuilder::from_json(&doc)?.build()?;

    let machine = Machine::unthrottled(machine_nodes);
    let service = std::sync::Arc::new(PilotComputeService::new(machine));
    let handle = app.launch(&service)?;
    for (pilot, s) in handle.startup_breakdowns() {
        println!(
            "pilot {pilot:<16} startup {:.1}s (queue {:.1} + bootstrap {:.1}, modeled)",
            s.total_secs(),
            s.queue_wait_secs,
            s.bootstrap_secs
        );
    }
    let produced = handle.await_sources()?;
    for r in &produced {
        println!(
            "source {:<12} -> {:<12} {:>6} msgs  {:>8.2} MB  {:>7.1} msg/s",
            r.name,
            r.topic,
            r.messages,
            r.bytes as f64 / 1e6,
            r.msg_rate()
        );
    }
    let report = handle.drain_and_stop()?;
    for s in &report.stages {
        println!(
            "stage  {:<12} <- {:<12} {:>6} msgs  {:>6} emitted  {:>5} batches  {:>3} behind  lag {}",
            s.name, s.topic, s.processed_messages, s.emitted_messages, s.batches, s.behind, s.lag
        );
    }
    if !report.drained {
        return Err(Error::App(format!(
            "drain timed out with {} messages of lag outstanding",
            report.terminal_lag()
        )));
    }
    println!(
        "app drained cleanly: {} produced / {} processed",
        report.produced_messages(),
        report.processed_messages()
    );
    Ok(())
}

/// Regenerate paper tables/figures.
fn cmd_exp(which: &str, flags: &HashMap<String, String>) -> Result<()> {
    let mut config = match flags.get("config") {
        Some(path) => ExperimentConfig::from_json_file(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    // `rackfail` is an elastic-only scenario preset riding on the
    // calibrated cost model, not a third cost preset.
    let mut rackfail = false;
    if let Some(preset) = flags.get("preset") {
        config.preset = match preset.as_str() {
            "paper-era" => CostPreset::PaperEra,
            "calibrated" => CostPreset::Calibrated,
            "rackfail" => {
                rackfail = true;
                CostPreset::Calibrated
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown preset '{other}' (expected calibrated|paper-era|rackfail)"
                )))
            }
        };
    }
    let out_dir = flags.get("out").cloned();
    let costs = exp::resolve_costs(&config, true);

    let run_one = |id: &str| -> Result<()> {
        if rackfail && id != "elastic" {
            return Err(Error::Config(format!(
                "preset 'rackfail' applies to the 'elastic' experiment only (got '{id}')"
            )));
        }
        println!("=== {id} (preset: {:?}) ===", config.preset);
        let rec = match id {
            "fig6" => exp::fig6(&config),
            "fig7" => exp::fig7(&config, &costs),
            "fig8" => exp::fig8(&config, &costs),
            "fig9" => exp::fig9(&config, &costs),
            "headline" => exp::headline(&config, &costs),
            "elastic" if rackfail => exp::elasticity_rackfail(&config, &costs),
            "elastic" => exp::elasticity(&config, &costs),
            "dag" => exp::dag(&config)?,
            "table1" => {
                let runtime = ModelRuntime::load_default()?;
                exp::table1(&runtime)?
            }
            other => return Err(Error::Config(format!("unknown experiment '{other}'"))),
        };
        println!("{}", rec.to_table());
        if let Some(dir) = &out_dir {
            let path = std::path::Path::new(dir).join(format!("{id}.csv"));
            rec.write_csv(&path)?;
            println!("wrote {}", path.display());
        }
        Ok(())
    };

    match which {
        "all" => {
            for id in ["fig6", "fig7", "fig8", "fig9", "table1", "headline", "elastic", "dag"] {
                run_one(id)?;
            }
            Ok(())
        }
        "" => Err(Error::Config(format!("exp: missing experiment id\n{USAGE}"))),
        id => run_one(id),
    }
}

/// Measure the real-plane cost model.
fn cmd_calibrate(flags: &HashMap<String, String>) -> Result<()> {
    let reps: usize = flags.get("reps").and_then(|s| s.parse().ok()).unwrap_or(10);
    let runtime = ModelRuntime::load_default()?;
    println!("calibrating cost model ({reps} reps per artifact)...");
    let m = CostModel::calibrate(&runtime, reps)?;
    println!("gen kmeans-random : {:>10.1} µs/msg", m.gen_random_secs * 1e6);
    println!("gen kmeans-static : {:>10.1} µs/msg", m.gen_static_secs * 1e6);
    println!("gen lightsource   : {:>10.1} µs/msg", m.gen_lightsource_secs * 1e6);
    println!("proc kmeans       : {:>10.2} ms/msg", m.proc_kmeans_secs * 1e3);
    println!("proc gridrec      : {:>10.2} ms/msg", m.proc_gridrec_secs * 1e3);
    println!("proc mlem         : {:>10.2} ms/msg", m.proc_mlem_secs * 1e3);
    Ok(())
}

/// List loaded artifacts and their signatures.
fn cmd_artifacts() -> Result<()> {
    let runtime = ModelRuntime::load_default()?;
    let m = runtime.manifest();
    println!(
        "kmeans: n={} d={} k={} decay={}",
        m.kmeans.n_points, m.kmeans.dim, m.kmeans.k, m.kmeans.decay
    );
    println!(
        "tomo: angles={} det={} image={}x{} mlem_iters={}",
        m.tomo.n_angles, m.tomo.n_det, m.tomo.img_h, m.tomo.img_w, m.tomo.mlem_iters
    );
    for name in runtime.artifact_names() {
        let meta = runtime.meta(&name)?;
        let sig = |sigs: &[pilot_streaming::runtime::TensorSig]| {
            sigs.iter()
                .map(|s| format!("{:?}:{}", s.shape, s.dtype))
                .collect::<Vec<_>>()
                .join(", ")
        };
        println!(
            "  {name:<14} {} -> {}",
            sig(&meta.inputs),
            sig(&meta.outputs)
        );
    }
    Ok(())
}

/// Perf smoke gate: fail if a named hotpath measurement in `--current`
/// (a `cargo bench -- --json` document) regressed more than
/// `--max-ratio` versus the committed `--baseline` (`BENCH_pr*.json`).
/// Coarse by design — it catches "someone reintroduced the memcpy", not
/// single-digit-percent drift.
///
/// Two gate shapes:
/// * default: compare a `results[]` stat (`--stat`, seconds,
///   lower-is-better, ratio = current/baseline);
/// * `--metric <k>`: compare `workloads[].metrics[k]` (a throughput
///   figure, higher-is-better, ratio = baseline/current) — this is how
///   the contended produce/fetch scaling workloads are gated, since
///   their wall-clock alone says nothing about per-thread throughput.
fn cmd_bench_gate(flags: &HashMap<String, String>) -> Result<()> {
    let need = |key: &str| {
        flags
            .get(key)
            .cloned()
            .ok_or_else(|| Error::Config(format!("bench-gate requires --{key}\n{USAGE}")))
    };
    let current_path = need("current")?;
    let baseline_path = need("baseline")?;
    let name = need("name")?;
    let max_ratio: f64 = flags
        .get("max-ratio")
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| Error::Config(format!("--max-ratio '{s}' is not a number")))
        })
        .transpose()?
        .unwrap_or(2.0);
    let stat = flags.get("stat").map(String::as_str).unwrap_or("p50");
    let stat_key = match stat {
        "mean" => "mean_secs",
        "p50" => "p50_secs",
        "p95" => "p95_secs",
        other => {
            return Err(Error::Config(format!(
                "--stat must be mean|p50|p95, got '{other}'"
            )))
        }
    };

    let load = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {path}: {e}")))?;
        Json::parse(&text)
    };
    if let Some(metric) = flags.get("metric") {
        let current = workload_metric(&load(&current_path)?, &name, metric).ok_or_else(|| {
            Error::Config(format!("{current_path}: no '{name}' workload with metric {metric}"))
        })?;
        let baseline = workload_metric(&load(&baseline_path)?, &name, metric).ok_or_else(|| {
            Error::Config(format!("{baseline_path}: no '{name}' workload with metric {metric}"))
        })?;
        // Throughput metrics: higher is better, so the regression ratio
        // inverts relative to the latency path below.
        let ratio = baseline / current.max(1e-12);
        println!(
            "bench-gate: {name} {metric} current={current:.3e} baseline={baseline:.3e} \
             ratio={ratio:.2} (max {max_ratio})"
        );
        if ratio > max_ratio {
            return Err(Error::Config(format!(
                "perf gate failed: {name} {metric} regressed {ratio:.2}x > {max_ratio}x vs baseline"
            )));
        }
        return Ok(());
    }
    let current = bench_result(&load(&current_path)?, &name, stat_key).ok_or_else(|| {
        Error::Config(format!("{current_path}: no '{name}' measurement with {stat_key}"))
    })?;
    let baseline = bench_result(&load(&baseline_path)?, &name, stat_key).ok_or_else(|| {
        Error::Config(format!("{baseline_path}: no '{name}' measurement with {stat_key}"))
    })?;
    let ratio = current / baseline.max(1e-12);
    println!(
        "bench-gate: {name} {stat} current={current:.3e}s baseline={baseline:.3e}s \
         ratio={ratio:.2} (max {max_ratio})"
    );
    if ratio > max_ratio {
        return Err(Error::Config(format!(
            "perf gate failed: {name} regressed {ratio:.2}x > {max_ratio}x vs baseline"
        )));
    }
    Ok(())
}

/// Find measurement `name`'s `stat_key` in a bench JSON document —
/// top-level `results` first, then an embedded `baseline` document (so
/// a trajectory file works as either side of the gate).
fn bench_result(doc: &Json, name: &str, stat_key: &str) -> Option<f64> {
    let find = |doc: &Json| -> Option<f64> {
        doc.get("results")?
            .as_arr()?
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|r| r.get(stat_key))
            .and_then(Json::as_f64)
    };
    find(doc).or_else(|| doc.get("baseline").and_then(find))
}

/// Find workload `name`'s `metrics[metric]` in a bench JSON document —
/// top-level `workloads` first, then an embedded `baseline` document
/// (same two-sided shape as [`bench_result`]).
fn workload_metric(doc: &Json, name: &str, metric: &str) -> Option<f64> {
    let find = |doc: &Json| -> Option<f64> {
        doc.get("workloads")?
            .as_arr()?
            .iter()
            .find(|w| w.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|w| w.get("metrics"))
            .and_then(|m| m.get(metric))
            .and_then(Json::as_f64)
    };
    find(doc).or_else(|| doc.get("baseline").and_then(find))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_reads_pairs_and_bools() {
        let f = parse_flags(&args(&["elastic", "--preset", "calibrated", "--quick"]));
        assert_eq!(f.get("preset").unwrap(), "calibrated");
        assert_eq!(f.get("quick").unwrap(), "true");
        assert_eq!(f.len(), 2, "positional args are not flags");
    }

    #[test]
    fn check_flags_accepts_known_and_rejects_unknown() {
        let f = parse_flags(&args(&["--preset", "calibrated", "--out", "dir"]));
        assert!(check_flags("exp", &f, &["preset", "out", "config"]).is_ok());
        // A typo'd flag is a usage error, not a silently-defaulted run.
        let f = parse_flags(&args(&["--perset", "calibrated"]));
        let err = check_flags("exp", &f, &["preset", "out", "config"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--perset"), "{msg}");
        assert!(msg.contains("--preset"), "should list expected flags: {msg}");
        assert!(msg.contains("USAGE"), "should print usage: {msg}");
    }

    #[test]
    fn check_flags_rejects_any_flag_for_bare_commands() {
        let f = parse_flags(&args(&["--verbose"]));
        let err = check_flags("artifacts", &f, &[]).unwrap_err();
        assert!(err.to_string().contains("takes no flags"));
    }

    #[test]
    fn run_rejects_unknown_exp_flag_end_to_end() {
        let err = run(&args(&["exp", "elastic", "--perset", "calibrated"])).unwrap_err();
        assert!(err.to_string().contains("unknown flag"), "{err}");
        let err = run(&args(&["start", "--nodse", "4"])).unwrap_err();
        assert!(err.to_string().contains("--nodse"), "{err}");
    }

    #[test]
    fn exp_rackfail_preset_is_elastic_only() {
        // The scenario preset runs end-to-end through the CLI path...
        run(&args(&["exp", "elastic", "--preset", "rackfail"])).unwrap();
        // ...but is not a cost preset the other experiments accept.
        let err = run(&args(&["exp", "fig6", "--preset", "rackfail"])).unwrap_err();
        assert!(err.to_string().contains("'elastic'"), "{err}");
        let err = run(&args(&["exp", "elastic", "--preset", "rakfail"])).unwrap_err();
        assert!(err.to_string().contains("unknown preset"), "{err}");
    }

    #[test]
    fn exp_unknown_preset_error_lists_the_valid_presets() {
        // The rejection names every accepted value, not just the bad one.
        let err = run(&args(&["exp", "fig6", "--preset", "wat"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown preset 'wat'"), "{msg}");
        for p in ["calibrated", "paper-era", "rackfail"] {
            assert!(msg.contains(p), "should list preset {p}: {msg}");
        }
    }

    #[test]
    fn exp_app_rejects_unknown_flags_and_requires_spec() {
        // Strict flag rejection, same as every other subcommand.
        let err = run(&args(&["exp", "app", "--sepc", "x.json"])).unwrap_err();
        assert!(err.to_string().contains("--sepc"), "{err}");
        assert!(err.to_string().contains("--spec"), "should list expected flags: {err}");
        let err = run(&args(&["exp", "app"])).unwrap_err();
        assert!(err.to_string().contains("requires --spec"), "{err}");
        let err = run(&args(&["exp", "app", "--spec", "/nonexistent/app.json"])).unwrap_err();
        assert!(err.to_string().contains("read /nonexistent/app.json"), "{err}");
    }

    #[test]
    fn exp_app_runs_a_minimal_spec_end_to_end() {
        let dir = std::env::temp_dir().join(format!("exp-app-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("app.json");
        std::fs::write(
            &spec,
            r#"{
              "machine_nodes": 4,
              "broker": { "nodes": 1, "topics": [ { "name": "t", "partitions": 2 } ] },
              "sources": [ { "name": "gen", "topic": "t", "kind": "kmeans-static",
                             "points_per_msg": 50, "msg_bytes": 0,
                             "producers": 2, "total_messages": 7 } ],
              "stages": [ { "name": "count", "topic": "t", "processor": "counter",
                            "window_ms": 30 } ]
            }"#,
        )
        .unwrap();
        run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap();
        // A malformed spec surfaces as a config error, not a launch.
        std::fs::write(&spec, r#"{ "stages": [] }"#).unwrap();
        let err = run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("broker"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exp_app_runs_a_replicated_toml_spec_end_to_end() {
        // The committed examples/app_spec.toml shape: a .toml spec with
        // a broker replication block and a per-stage autoscale block
        // launches end-to-end through the same path as JSON.
        let dir = std::env::temp_dir().join(format!("exp-app-toml-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("app.toml");
        std::fs::write(
            &spec,
            r#"
machine_nodes = 4

[broker]
nodes = 2
racks = 2

[[broker.topics]]
name = "t"
partitions = 2

[broker.replication]
factor = 2
ack_mode = "quorum"
min_insync = 2
replica_lag_max = 4
follower_fetch = true

[[sources]]
name = "gen"
topic = "t"
kind = "kmeans-static"
points_per_msg = 50
msg_bytes = 0
producers = 2
total_messages = 7

[[stages]]
name = "count"
topic = "t"
processor = "counter"
window_ms = 30

[stages.autoscale]
up = 1000000
down = 10
cooldown_secs = 60.0
"#,
        )
        .unwrap();
        run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap();
        // TOML typos get the same strict rejection as JSON keys.
        std::fs::write(&spec, "[broker]\nreplicas = 2\ntopics = []\n").unwrap();
        let err = run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("unknown broker key: replicas"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn exp_app_runs_a_dag_toml_spec_end_to_end() {
        // The committed examples/app_dag.toml shape: a chained relay
        // stage feeding a split/merge branch, drained topologically.
        let dir = std::env::temp_dir().join(format!("exp-app-dag-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec = dir.join("dag.toml");
        std::fs::write(
            &spec,
            r#"
machine_nodes = 12

[broker]
nodes = 1

[[broker.topics]]
name = "raw"
partitions = 2

[[broker.topics]]
name = "frames"
partitions = 2

[[broker.topics]]
name = "hot"
partitions = 2

[[broker.topics]]
name = "cold"
partitions = 2

[[broker.topics]]
name = "merged"
partitions = 2

[[sources]]
name = "gen"
topic = "raw"
kind = "kmeans-static"
points_per_msg = 50
msg_bytes = 0
producers = 2
total_messages = 12

[[stages]]
name = "reconstruct"
topic = "raw"
processor = "relay"
key_bytes = 1
output_topic = "frames"
window_ms = 30

[[splits]]
name = "route"
topic = "frames"
branches = ["hot", "cold"]
route = "key-hash"
key_bytes = 1
window_ms = 30

[[merges]]
name = "fan-in"
inputs = ["hot", "cold"]
output = "merged"
key_bytes = 1
window_ms = 30

[[stages]]
name = "archive"
topic = "merged"
processor = "counter"
window_ms = 30
"#,
        )
        .unwrap();
        run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap();
        // A dangling produced edge is rejected before launch.
        let text = std::fs::read_to_string(&spec).unwrap();
        std::fs::write(&spec, text.replace("topic = \"merged\"", "topic = \"frames\"")).unwrap();
        let err = run(&args(&["exp", "app", "--spec", spec.to_str().unwrap()])).unwrap_err();
        assert!(err.to_string().contains("merged"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn bench_doc(name: &str, p50: f64) -> Json {
        Json::obj().set(
            "results",
            Json::Arr(vec![Json::obj()
                .set("name", name)
                .set("iters", 10usize)
                .set("mean_secs", p50)
                .set("p50_secs", p50)
                .set("p95_secs", p50)]),
        )
    }

    #[test]
    fn bench_result_reads_top_level_and_embedded_baseline() {
        let doc = bench_doc("log/read-8x320k", 2e-6);
        assert_eq!(bench_result(&doc, "log/read-8x320k", "p50_secs"), Some(2e-6));
        assert_eq!(bench_result(&doc, "missing", "p50_secs"), None);
        // A trajectory file: current results wrap an embedded baseline.
        let wrapped = bench_doc("other", 1.0).set("baseline", bench_doc("log/read-8x320k", 5e-4));
        assert_eq!(
            bench_result(&wrapped, "log/read-8x320k", "p50_secs"),
            Some(5e-4)
        );
    }

    fn workload_doc(name: &str, metric: &str, value: f64) -> Json {
        Json::obj().set(
            "workloads",
            Json::Arr(vec![Json::obj()
                .set("name", name)
                .set("secs", 1.0)
                .set("metrics", Json::obj().set(metric, value))]),
        )
    }

    #[test]
    fn workload_metric_reads_top_level_and_embedded_baseline() {
        let doc = workload_doc("broker/contended-produce-fetch-16x16", "fetch_msgs_per_sec", 9e4);
        assert_eq!(
            workload_metric(&doc, "broker/contended-produce-fetch-16x16", "fetch_msgs_per_sec"),
            Some(9e4)
        );
        assert_eq!(workload_metric(&doc, "missing", "fetch_msgs_per_sec"), None);
        assert_eq!(
            workload_metric(&doc, "broker/contended-produce-fetch-16x16", "missing"),
            None
        );
        let wrapped = Json::obj().set(
            "baseline",
            workload_doc("broker/contended-produce-fetch-16x16", "fetch_msgs_per_sec", 5e4),
        );
        assert_eq!(
            workload_metric(&wrapped, "broker/contended-produce-fetch-16x16", "fetch_msgs_per_sec"),
            Some(5e4)
        );
    }

    #[test]
    fn bench_gate_metric_path_is_higher_is_better() {
        let dir = std::env::temp_dir().join(format!("bench-gate-metric-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let current = dir.join("current.json");
        let baseline = dir.join("baseline.json");
        let name = "broker/contended-produce-fetch-16x16";
        // Throughput halved: ratio 2.0 sits exactly at the default gate.
        std::fs::write(&current, workload_doc(name, "fetch_msgs_per_sec", 5e4).to_string())
            .unwrap();
        std::fs::write(&baseline, workload_doc(name, "fetch_msgs_per_sec", 1e5).to_string())
            .unwrap();
        let gate = |ratio: &str| {
            run(&args(&[
                "bench-gate",
                "--current",
                current.to_str().unwrap(),
                "--baseline",
                baseline.to_str().unwrap(),
                "--name",
                name,
                "--metric",
                "fetch_msgs_per_sec",
                "--max-ratio",
                ratio,
            ]))
        };
        assert!(gate("2.0").is_ok(), "a 2x throughput drop fits under max-ratio 2");
        let err = gate("1.5").unwrap_err();
        assert!(err.to_string().contains("perf gate failed"), "{err}");
        // A throughput *gain* always passes the inverted ratio.
        std::fs::write(&current, workload_doc(name, "fetch_msgs_per_sec", 4e5).to_string())
            .unwrap();
        assert!(gate("1.1").is_ok());
        // Missing metric is a usage error, not a silent pass.
        let err = run(&args(&[
            "bench-gate",
            "--current",
            current.to_str().unwrap(),
            "--baseline",
            baseline.to_str().unwrap(),
            "--name",
            name,
            "--metric",
            "nope",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("no '"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_gate_passes_and_fails_on_ratio() {
        let dir = std::env::temp_dir().join(format!("bench-gate-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let current = dir.join("current.json");
        let baseline = dir.join("baseline.json");
        std::fs::write(&current, bench_doc("log/read-8x320k", 3e-6).to_string()).unwrap();
        std::fs::write(&baseline, bench_doc("log/read-8x320k", 2e-6).to_string()).unwrap();
        let gate = |ratio: &str| {
            run(&args(&[
                "bench-gate",
                "--current",
                current.to_str().unwrap(),
                "--baseline",
                baseline.to_str().unwrap(),
                "--name",
                "log/read-8x320k",
                "--max-ratio",
                ratio,
            ]))
        };
        assert!(gate("2.0").is_ok(), "1.5x fits under 2x");
        let err = gate("1.2").unwrap_err();
        assert!(err.to_string().contains("perf gate failed"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
