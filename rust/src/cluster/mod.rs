//! Simulated HPC machine: nodes, allocations, and NIC throttling.
//!
//! This is the *real plane*'s stand-in for the paper's Wrangler testbed
//! (DESIGN.md §3): node boundaries are logical (everything runs in one
//! process), but resource accounting is enforced — pilots allocate whole
//! nodes from a finite pool, and per-node NIC token buckets throttle the
//! broker data plane so saturation behaviour is observable even
//! in-process.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::MachineConfig;
use crate::error::{Error, Result};

/// Identifier of a node within a [`Machine`].
pub type NodeId = usize;

/// A token-bucket byte throttle (one per NIC direction per node).
///
/// `acquire(bytes)` blocks until the bucket has refilled enough tokens,
/// enforcing a long-run rate of `rate_bytes_per_sec`.  A `None` rate is
/// unthrottled (used by unit tests and the pure-compute paths).
#[derive(Debug)]
pub struct Throttle {
    rate_bytes_per_sec: Option<f64>,
    /// Cumulative bytes ever acquired through this throttle — the raw
    /// counter behind the broker-tier saturation gauges (finite
    /// differences against [`Throttle::rate`] give utilization).
    acquired: AtomicU64,
    state: Mutex<ThrottleState>,
}

#[derive(Debug)]
struct ThrottleState {
    last_refill: Instant,
    available: f64,
    burst: f64,
}

impl Throttle {
    pub fn new(rate_bytes_per_sec: Option<f64>) -> Self {
        let burst = rate_bytes_per_sec.map(|r| r * 0.05).unwrap_or(f64::MAX);
        Throttle {
            rate_bytes_per_sec,
            acquired: AtomicU64::new(0),
            state: Mutex::new(ThrottleState {
                last_refill: Instant::now(),
                available: burst,
                burst,
            }),
        }
    }

    /// Unlimited throttle.
    pub fn unlimited() -> Self {
        Self::new(None)
    }

    pub fn rate(&self) -> Option<f64> {
        self.rate_bytes_per_sec
    }

    /// Cumulative bytes acquired since construction (counted whether or
    /// not the throttle enforces a rate).
    pub fn acquired_bytes(&self) -> u64 {
        self.acquired.load(Ordering::Relaxed)
    }

    /// Consume `bytes` tokens, sleeping until available.
    pub fn acquire(&self, bytes: usize) {
        self.acquired.fetch_add(bytes as u64, Ordering::Relaxed);
        let Some(rate) = self.rate_bytes_per_sec else {
            return;
        };
        loop {
            let wait = {
                let mut st = self.state.lock().unwrap();
                let now = Instant::now();
                let elapsed = now.duration_since(st.last_refill).as_secs_f64();
                st.last_refill = now;
                let burst = st.burst;
                st.available = (st.available + elapsed * rate).min(burst.max(bytes as f64));
                if st.available >= bytes as f64 {
                    st.available -= bytes as f64;
                    None
                } else {
                    Some(Duration::from_secs_f64(
                        ((bytes as f64 - st.available) / rate).clamp(1e-6, 1.0),
                    ))
                }
            };
            match wait {
                None => return,
                Some(d) => std::thread::sleep(d),
            }
        }
    }
}

/// A node of the simulated machine.
#[derive(Debug)]
pub struct Node {
    pub id: NodeId,
    pub cores: usize,
    pub mem_gb: usize,
    /// NIC egress throttle (bytes leaving this node).
    pub egress: Throttle,
    /// NIC ingress throttle (bytes entering this node).
    pub ingress: Throttle,
    /// Local SSD throttle (broker log appends).
    pub disk: Throttle,
}

/// Who holds a node allocation (for diagnostics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub pilot_id: String,
    pub nodes: Vec<NodeId>,
}

struct MachineState {
    free: BTreeSet<NodeId>,
    allocations: Vec<Allocation>,
}

/// The simulated HPC machine shared by every component of a deployment.
///
/// Cloneable handle (Arc inside); pilots allocate whole nodes, mirroring
/// the paper's Pilot-Jobs which hold node-granular SLURM allocations.
#[derive(Clone)]
pub struct Machine {
    config: MachineConfig,
    nodes: Arc<Vec<Node>>,
    state: Arc<Mutex<MachineState>>,
}

impl std::fmt::Debug for Machine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Machine")
            .field("name", &self.config.name)
            .field("nodes", &self.nodes.len())
            .field("free", &self.free_nodes())
            .finish()
    }
}

impl Machine {
    pub fn new(config: MachineConfig) -> Result<Self> {
        config.validate()?;
        let nodes: Vec<Node> = (0..config.nodes)
            .map(|id| Node {
                id,
                cores: config.cores_per_node,
                mem_gb: config.mem_gb_per_node,
                egress: Throttle::new(Some(config.nic_mbps * 1e6)),
                ingress: Throttle::new(Some(config.nic_mbps * 1e6)),
                disk: Throttle::new(Some(config.ssd_mbps * 1e6)),
            })
            .collect();
        Ok(Machine {
            state: Arc::new(Mutex::new(MachineState {
                free: (0..config.nodes).collect(),
                allocations: Vec::new(),
            })),
            nodes: Arc::new(nodes),
            config,
        })
    }

    /// Wrangler-shaped machine with `nodes` nodes (paper testbed).
    pub fn wrangler(nodes: usize) -> Self {
        Self::new(MachineConfig::wrangler(nodes)).expect("wrangler config is valid")
    }

    /// Small unthrottled machine for tests (bandwidth limits off).
    pub fn unthrottled(nodes: usize) -> Self {
        let mut cfg = MachineConfig::localhost(nodes);
        cfg.name = "test".into();
        let machine = Self::new(cfg).unwrap();
        // Replace throttles with unlimited ones.
        let nodes: Vec<Node> = machine
            .nodes
            .iter()
            .map(|n| Node {
                id: n.id,
                cores: n.cores,
                mem_gb: n.mem_gb,
                egress: Throttle::unlimited(),
                ingress: Throttle::unlimited(),
                disk: Throttle::unlimited(),
            })
            .collect();
        Machine {
            config: machine.config.clone(),
            nodes: Arc::new(nodes),
            state: machine.state.clone(),
        }
    }

    pub fn config(&self) -> &MachineConfig {
        &self.config
    }

    pub fn total_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn free_nodes(&self) -> usize {
        self.state.lock().unwrap().free.len()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Allocate `n` whole nodes for `pilot_id`.
    pub fn allocate(&self, pilot_id: &str, n: usize) -> Result<Vec<NodeId>> {
        let mut st = self.state.lock().unwrap();
        if st.free.len() < n {
            return Err(Error::Pilot(format!(
                "machine {}: requested {} nodes, only {} free",
                self.config.name,
                n,
                st.free.len()
            )));
        }
        let ids: Vec<NodeId> = st.free.iter().take(n).copied().collect();
        for id in &ids {
            st.free.remove(id);
        }
        st.allocations.push(Allocation {
            pilot_id: pilot_id.to_string(),
            nodes: ids.clone(),
        });
        Ok(ids)
    }

    /// Release every node held by `pilot_id`.
    pub fn release(&self, pilot_id: &str) {
        let mut st = self.state.lock().unwrap();
        let drained: Vec<Allocation> = std::mem::take(&mut st.allocations);
        let mut kept = Vec::new();
        for alloc in drained {
            if alloc.pilot_id == pilot_id {
                for id in alloc.nodes {
                    st.free.insert(id);
                }
            } else {
                kept.push(alloc);
            }
        }
        st.allocations = kept;
    }

    /// Release specific nodes held by `pilot_id` (pilot shrink).
    pub fn release_nodes(&self, pilot_id: &str, nodes: &[NodeId]) {
        let mut st = self.state.lock().unwrap();
        let mut freed = Vec::new();
        for alloc in st.allocations.iter_mut() {
            if alloc.pilot_id == pilot_id {
                alloc.nodes.retain(|id| {
                    if nodes.contains(id) {
                        freed.push(*id);
                        false
                    } else {
                        true
                    }
                });
            }
        }
        for id in freed {
            st.free.insert(id);
        }
        st.allocations.retain(|a| !a.nodes.is_empty());
    }

    /// Current allocations (diagnostics / tests).
    pub fn allocations(&self) -> Vec<Allocation> {
        self.state.lock().unwrap().allocations.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_and_release_roundtrip() {
        let m = Machine::unthrottled(4);
        assert_eq!(m.free_nodes(), 4);
        let a = m.allocate("p1", 3).unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(m.free_nodes(), 1);
        assert!(m.allocate("p2", 2).is_err());
        m.release("p1");
        assert_eq!(m.free_nodes(), 4);
    }

    #[test]
    fn release_nodes_partial() {
        let m = Machine::unthrottled(4);
        let a = m.allocate("p1", 4).unwrap();
        m.release_nodes("p1", &a[..2]);
        assert_eq!(m.free_nodes(), 2);
        let allocs = m.allocations();
        assert_eq!(allocs.len(), 1);
        assert_eq!(allocs[0].nodes.len(), 2);
        m.release("p1");
        assert_eq!(m.free_nodes(), 4);
    }

    #[test]
    fn allocations_disjoint() {
        let m = Machine::unthrottled(6);
        let a = m.allocate("p1", 3).unwrap();
        let b = m.allocate("p2", 3).unwrap();
        for id in &a {
            assert!(!b.contains(id), "node {id} double-allocated");
        }
    }

    #[test]
    fn throttle_enforces_rate() {
        // 10 MB/s: moving 1 MB (beyond the 0.5 MB burst) must take
        // noticeable time.
        let t = Throttle::new(Some(10e6));
        let start = Instant::now();
        t.acquire(1_000_000);
        t.acquire(1_000_000);
        let secs = start.elapsed().as_secs_f64();
        // 2 MB at 10 MB/s = 200 ms minus the 0.5 MB burst => >= ~100 ms.
        assert!(secs > 0.1, "throttle too fast: {secs}s");
    }

    #[test]
    fn unlimited_throttle_is_instant() {
        let t = Throttle::unlimited();
        let start = Instant::now();
        t.acquire(1_000_000_000);
        assert!(start.elapsed().as_secs_f64() < 0.05);
    }

    #[test]
    fn throttle_counts_acquired_bytes() {
        // Counted for both unlimited and rate-limited throttles, so
        // saturation gauges work on every machine shape.
        let t = Throttle::unlimited();
        assert_eq!(t.acquired_bytes(), 0);
        t.acquire(1_000);
        t.acquire(500);
        assert_eq!(t.acquired_bytes(), 1_500);
        let limited = Throttle::new(Some(10e6));
        limited.acquire(1_000);
        assert_eq!(limited.acquired_bytes(), 1_000);
    }

    #[test]
    fn wrangler_machine_shape() {
        let m = Machine::wrangler(2);
        assert_eq!(m.total_nodes(), 2);
        assert_eq!(m.node(0).cores, 24);
        assert!(m.node(0).egress.rate().unwrap() > 1e9);
    }
}
