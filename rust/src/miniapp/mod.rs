//! The Streaming Mini-App framework (paper §5).
//!
//! "The core of the framework consists of two main components: (i) the
//! MASS (Mini-App for Stream Source) can emulate a streaming data
//! source ... (ii) the MASA (Mini-App for Streaming Analysis) provides
//! a framework for evaluating different forms of stream data
//! processing."
//!
//! * [`wire`] — the message framing both apps share (payload sizes
//!   padded to the paper's 0.32 MB / 2 MB workloads);
//! * [`mass`] — pluggable data-production functions (`cluster` random
//!   source, static source, light-source `template` source) driven by
//!   Dask-like producer tasks;
//! * [`masa`] — pluggable processors (streaming KMeans, GridRec, ML-EM)
//!   running on the Spark-like micro-batch engine, executing the AOT
//!   compute artifacts through PJRT.

pub mod masa;
pub mod mass;
pub mod wire;

pub use masa::{KmeansModel, MasaApp, MasaConfig, MasaProcessor, ProcessorKind, ProcessorStats};
pub use mass::{MassConfig, MassReport, MassSource, MassStream, PayloadGenerator, SourceKind};
pub use wire::{Message, MessageView, PayloadKind};
