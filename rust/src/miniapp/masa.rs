//! MASA — Mini-App for Streaming Analysis (paper §5).
//!
//! "Provides a framework for evaluating different forms of stream data
//! processing" with pluggable algorithms: streaming KMeans (MLlib
//! analogue) and light-source reconstruction (TomoPy GridRec / ML-EM
//! analogues).  Each processor decodes Mini-App messages and executes
//! the corresponding AOT artifact through the PJRT [`ModelRuntime`] —
//! the L1/L2 compute plane.  The KMeans processor carries model state
//! (centroids + weights) across batches and applies the streaming
//! update after each scored message, matching MLlib's
//! `StreamingKMeans`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::broker::{BrokerCluster, Record};
use crate::engine::{BatchProcessor, MicroBatchEngine, StreamingJobConfig, StreamingJobHandle, TaskContext};
use crate::error::{Error, Result};
use crate::metrics::{Histogram, RateMeter};
use crate::runtime::ModelRuntime;
use crate::util::Rng;

use super::wire::{now_ns, Message, MessageView, PayloadKind};

/// Processing algorithm kinds (paper §6.4 evaluates exactly these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcessorKind {
    /// Streaming KMeans: score + model update per message.
    KMeans,
    /// GridRec-style filtered backprojection (fast, direct).
    GridRec,
    /// ML-EM iterative reconstruction (slow, higher fidelity).
    MlEm,
}

impl ProcessorKind {
    pub fn name(self) -> &'static str {
        match self {
            ProcessorKind::KMeans => "kmeans",
            ProcessorKind::GridRec => "gridrec",
            ProcessorKind::MlEm => "mlem",
        }
    }

    /// The AOT artifact executed per message.
    pub fn artifact(self) -> &'static str {
        match self {
            ProcessorKind::KMeans => "kmeans_score",
            ProcessorKind::GridRec => "gridrec",
            ProcessorKind::MlEm => "mlem",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "kmeans" => Ok(ProcessorKind::KMeans),
            "gridrec" => Ok(ProcessorKind::GridRec),
            "mlem" | "ml-em" => Ok(ProcessorKind::MlEm),
            other => Err(Error::Engine(format!("unknown processor '{other}'"))),
        }
    }
}

/// Streaming KMeans model state.
#[derive(Debug, Clone)]
pub struct KmeansModel {
    pub centroids: Vec<f32>,
    pub weights: Vec<f32>,
    pub k: usize,
    pub dim: usize,
    /// Cumulative inertia (model-quality probe).
    pub last_inertia: f32,
    /// Inertia of the very first scored batch (learning baseline).
    pub first_inertia: f32,
    pub updates: u64,
}

impl KmeansModel {
    fn random(k: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let mut centroids = vec![0.0f32; k * dim];
        for c in centroids.iter_mut() {
            *c = rng.range_f64(-50.0, 50.0) as f32;
        }
        KmeansModel {
            centroids,
            weights: vec![0.0; k],
            k,
            dim,
            last_inertia: 0.0,
            first_inertia: 0.0,
            updates: 0,
        }
    }
}

/// Probe metrics every MASA processor records (paper §5: "standard
/// profiling probes ... production and consumption rate").
#[derive(Debug, Default)]
pub struct ProcessorStats {
    /// Messages/bytes consumed.
    pub consumed: RateMeter,
    /// Per-message XLA execution time.
    pub exec_secs: Histogram,
    /// Producer-timestamp -> processing-done latency.
    pub e2e_latency: Histogram,
    /// Messages that failed to decode/execute.
    pub errors: AtomicU64,
}

/// A MASA processor: decodes messages, runs the artifact, updates state.
pub struct MasaProcessor {
    kind: ProcessorKind,
    runtime: ModelRuntime,
    model: Mutex<KmeansModel>,
    pub stats: Arc<ProcessorStats>,
    /// Last reconstruction output (examples read it for error checks).
    last_image: Mutex<Vec<f32>>,
}

impl MasaProcessor {
    pub fn new(kind: ProcessorKind, runtime: ModelRuntime) -> Arc<Self> {
        let km = runtime.manifest().kmeans.clone();
        Arc::new(MasaProcessor {
            kind,
            runtime,
            model: Mutex::new(KmeansModel::random(km.k, km.dim, 7)),
            stats: Arc::new(ProcessorStats::default()),
            last_image: Mutex::new(Vec::new()),
        })
    }

    pub fn kind(&self) -> ProcessorKind {
        self.kind
    }

    /// Pre-compile the artifacts on the calling thread.
    pub fn warmup(&self) -> Result<()> {
        self.runtime.warmup(self.kind.artifact())?;
        if self.kind == ProcessorKind::KMeans {
            self.runtime.warmup("kmeans_update")?;
        }
        Ok(())
    }

    /// Current KMeans model snapshot.
    pub fn model(&self) -> KmeansModel {
        self.model.lock().unwrap().clone()
    }

    /// Last reconstructed image (GridRec/MLEM).
    pub fn last_image(&self) -> Vec<f32> {
        self.last_image.lock().unwrap().clone()
    }

    /// Process one decoded message.
    pub fn process_message(&self, msg: &Message) -> Result<()> {
        let t0 = Instant::now();
        match (self.kind, msg.kind) {
            (ProcessorKind::KMeans, PayloadKind::KmeansPoints) => {
                let expect = {
                    let m = self.runtime.manifest();
                    m.kmeans.n_points * m.kmeans.dim
                };
                if msg.values.len() != expect {
                    return Err(Error::Wire(format!(
                        "kmeans message has {} values, artifact expects {expect}",
                        msg.values.len()
                    )));
                }
                // First batch: seed centroids from the data (MLlib's
                // kmeans|| analogue) — random far-away centers would
                // leave clusters permanently empty.
                {
                    let mut m = self.model.lock().unwrap();
                    if m.updates == 0 && m.weights.iter().all(|w| *w == 0.0) {
                        let (k, dim) = (m.k, m.dim);
                        let n_points = msg.values.len() / dim;
                        for c in 0..k {
                            let p = c * n_points / k;
                            m.centroids[c * dim..(c + 1) * dim]
                                .copy_from_slice(&msg.values[p * dim..(p + 1) * dim]);
                        }
                    }
                }
                // Score: assignments + batch statistics (one fused call).
                let (centroids, weights) = {
                    let m = self.model.lock().unwrap();
                    (m.centroids.clone(), m.weights.clone())
                };
                let outs = self
                    .runtime
                    .execute("kmeans_score", &[&msg.values, &centroids])?;
                let counts = outs[1].as_f32()?.to_vec();
                let sums = outs[2].as_f32()?.to_vec();
                let inertia = outs[3].as_f32()?[0];
                // Model update (streaming, decayed).
                let outs = self
                    .runtime
                    .execute("kmeans_update", &[&centroids, &weights, &sums, &counts])?;
                let mut m = self.model.lock().unwrap();
                m.centroids = outs[0].as_f32()?.to_vec();
                m.weights = outs[1].as_f32()?.to_vec();
                m.last_inertia = inertia;
                if m.updates == 0 {
                    m.first_inertia = inertia;
                }
                m.updates += 1;
            }
            (ProcessorKind::GridRec, PayloadKind::Sinogram)
            | (ProcessorKind::MlEm, PayloadKind::Sinogram) => {
                let expect = {
                    let m = self.runtime.manifest();
                    m.tomo.n_angles * m.tomo.n_det
                };
                if msg.values.len() != expect {
                    return Err(Error::Wire(format!(
                        "sinogram has {} values, artifact expects {expect}",
                        msg.values.len()
                    )));
                }
                let outs = self
                    .runtime
                    .execute(self.kind.artifact(), &[&msg.values])?;
                *self.last_image.lock().unwrap() = outs[0].as_f32()?.to_vec();
            }
            (kind, payload) => {
                return Err(Error::Wire(format!(
                    "processor {kind:?} cannot handle payload {payload:?}"
                )));
            }
        }
        self.stats.exec_secs.record_secs(t0.elapsed().as_secs_f64());
        Ok(())
    }
}

impl MasaProcessor {
    /// Process a borrowed-payload view: kind and tensor-shape checks
    /// run against the 26-byte header, so a mismatched or misrouted
    /// message is rejected *before* the tensor is materialized.  Only a
    /// view that will actually reach compute pays the one f32 copy the
    /// PJRT execute boundary needs.
    pub fn process_view(&self, view: &MessageView<'_>) -> Result<()> {
        let expect = match (self.kind, view.kind) {
            (ProcessorKind::KMeans, PayloadKind::KmeansPoints) => {
                let m = self.runtime.manifest();
                m.kmeans.n_points * m.kmeans.dim
            }
            (ProcessorKind::GridRec, PayloadKind::Sinogram)
            | (ProcessorKind::MlEm, PayloadKind::Sinogram) => {
                let m = self.runtime.manifest();
                m.tomo.n_angles * m.tomo.n_det
            }
            (kind, payload) => {
                return Err(Error::Wire(format!(
                    "processor {kind:?} cannot handle payload {payload:?}"
                )));
            }
        };
        if view.n_values() != expect {
            return Err(Error::Wire(format!(
                "message has {} values, artifact expects {expect}",
                view.n_values()
            )));
        }
        self.process_message(&view.to_message())
    }
}

/// MASA processors are the built-in [`crate::app::StreamProcessor`]s:
/// an application stage runs them directly
/// (`StageSpec::new("recon", topic, MasaProcessor::new(kind, rt))`),
/// with artifact compilation happening in `warmup` before the stage's
/// streaming job starts.
impl crate::app::StreamProcessor for MasaProcessor {
    fn name(&self) -> &str {
        self.kind.name()
    }

    fn warmup(&self) -> Result<()> {
        MasaProcessor::warmup(self)
    }

    fn process_window(&self, ctx: &TaskContext, window: &[Record]) -> Result<()> {
        <Self as BatchProcessor>::process(self, ctx, window)
    }
}

impl BatchProcessor for MasaProcessor {
    fn process(&self, _ctx: &TaskContext, records: &[Record]) -> Result<()> {
        for r in records {
            // Borrowed-payload decode straight out of the log slab: the
            // record value is a zero-copy view, and decode_view parses
            // only the header — stats and latency stamps never touch
            // the tensor bytes.
            match Message::decode_view(&r.value) {
                Ok(view) => {
                    if let Err(e) = self.process_view(&view) {
                        self.stats.errors.fetch_add(1, Ordering::Relaxed);
                        return Err(e);
                    }
                    self.stats.consumed.record(r.value.len());
                    let now = now_ns();
                    self.stats
                        .e2e_latency
                        .record_ns(now.saturating_sub(view.produced_ns));
                }
                Err(e) => {
                    self.stats.errors.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

/// MASA job configuration.
#[derive(Debug, Clone)]
pub struct MasaConfig {
    pub kind: ProcessorKind,
    pub topic: String,
    /// Micro-batch window (paper §6.4: 60 s; examples use shorter).
    pub window: Duration,
}

impl MasaConfig {
    pub fn new(kind: ProcessorKind, topic: &str, window: Duration) -> Self {
        MasaConfig {
            kind,
            topic: topic.to_string(),
            window,
        }
    }
}

/// The MASA app: wires a processor into a streaming job.
pub struct MasaApp {
    pub processor: Arc<MasaProcessor>,
    config: MasaConfig,
}

impl MasaApp {
    pub fn new(config: MasaConfig, runtime: ModelRuntime) -> Self {
        MasaApp {
            processor: MasaProcessor::new(config.kind, runtime),
            config,
        }
    }

    /// Consumer group the streaming job commits offsets under (what
    /// lag probes and autoscalers should watch).
    pub fn group(&self) -> String {
        format!("masa-{}", self.config.kind.name())
    }

    /// Start the streaming job on `engine`, consuming from `cluster`.
    pub fn start(
        &self,
        engine: &MicroBatchEngine,
        cluster: BrokerCluster,
    ) -> Result<StreamingJobHandle> {
        let mut job = StreamingJobConfig::new(&self.config.topic, self.config.window);
        job.group = self.group();
        engine.start_job(cluster, job, self.processor.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        // Artifact-dependent tests are skipped when artifacts are absent
        // (built by `make artifacts`) or PJRT is compiled out (no `xla`
        // feature); the integration suite covers them.
        let rt = ModelRuntime::load_default().ok()?;
        rt.warmup("gridrec").ok()?;
        Some(rt)
    }

    #[test]
    fn kmeans_processor_updates_model() {
        let Some(rt) = runtime() else { return };
        let km = rt.manifest().kmeans.clone();
        let proc = MasaProcessor::new(ProcessorKind::KMeans, rt);
        let n = km.n_points * km.dim;
        let mut rng = Rng::seed_from(3);
        let mut values = vec![0.0f32; n];
        rng.fill_gauss_f32(&mut values);
        let before = proc.model();
        proc.process_message(&Message::new(PayloadKind::KmeansPoints, 0, now_ns(), values))
            .unwrap();
        let after = proc.model();
        assert_eq!(after.updates, before.updates + 1);
        assert!(after.weights.iter().sum::<f32>() > 0.0);
        assert_ne!(after.centroids, before.centroids);
        assert!(after.last_inertia > 0.0);
    }

    #[test]
    fn gridrec_processor_reconstructs_template() {
        let Some(rt) = runtime() else { return };
        let tomo = rt.manifest().tomo.clone();
        let sino = rt.read_f32_file("template_sinogram.bin").unwrap();
        let phantom = rt.read_f32_file("phantom.bin").unwrap();
        let proc = MasaProcessor::new(ProcessorKind::GridRec, rt);
        proc.process_message(&Message::new(PayloadKind::Sinogram, 0, now_ns(), sino))
            .unwrap();
        let img = proc.last_image();
        assert_eq!(img.len(), tomo.img_h * tomo.img_w);
        // Central-region RMSE vs the phantom must be small (FBP quality).
        let (h, w) = (tomo.img_h, tomo.img_w);
        let mut se = 0.0f64;
        let mut n = 0usize;
        for i in 16..h - 16 {
            for j in 16..w - 16 {
                let d = (img[i * w + j] - phantom[i * w + j]) as f64;
                se += d * d;
                n += 1;
            }
        }
        let rmse = (se / n as f64).sqrt();
        assert!(rmse < 0.12, "gridrec rmse {rmse}");
    }

    #[test]
    fn wrong_payload_kind_is_rejected() {
        let Some(rt) = runtime() else { return };
        let proc = MasaProcessor::new(ProcessorKind::GridRec, rt);
        let msg = Message::new(PayloadKind::KmeansPoints, 0, 0, vec![0.0; 30]);
        assert!(proc.process_message(&msg).is_err());
        assert!(ProcessorKind::parse("gridrec").is_ok());
        assert!(ProcessorKind::parse("storm").is_err());
    }
}
