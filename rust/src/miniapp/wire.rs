//! Wire format for Mini-App messages.
//!
//! The paper's MASS app serializes batches of points (PyKafka strings,
//! ~0.32 MB for 5,000 3-D points) and APS-format light-source frames
//! (~2 MB).  We use a compact binary framing and *pad* each message to
//! the paper's serialized sizes, so the broker and network layers see
//! byte volumes identical to the paper's workloads while the compute
//! layer reads exactly the f32 tensor it needs:
//!
//! ```text
//! | magic "PSMA" | ver u8 | type u8 | seq u64 | produced_ns u64 |
//! | n_values u32 | pad u32 | values f32-LE ... | zero padding ... |
//! ```

use crate::error::{Error, Result};

const MAGIC: &[u8; 4] = b"PSMA";
const VERSION: u8 = 1;
const HEADER_LEN: usize = 4 + 1 + 1 + 8 + 8 + 4 + 4;

/// Message payload kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PayloadKind {
    /// A mini-batch of KMeans points (`n_points * dim` f32 values).
    KmeansPoints = 1,
    /// One light-source sinogram (`n_angles * n_det` f32 values).
    Sinogram = 2,
}

impl PayloadKind {
    fn from_u8(v: u8) -> Result<Self> {
        match v {
            1 => Ok(PayloadKind::KmeansPoints),
            2 => Ok(PayloadKind::Sinogram),
            other => Err(Error::Wire(format!("unknown payload kind {other}"))),
        }
    }
}

/// A decoded Mini-App message.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub kind: PayloadKind,
    /// Producer-assigned sequence number.
    pub seq: u64,
    /// Producer wall-clock timestamp (ns) for end-to-end latency probes.
    pub produced_ns: u64,
    /// The f32 tensor payload.
    pub values: Vec<f32>,
}

impl Message {
    pub fn new(kind: PayloadKind, seq: u64, produced_ns: u64, values: Vec<f32>) -> Self {
        Message {
            kind,
            seq,
            produced_ns,
            values,
        }
    }

    /// Encoded size without padding.
    pub fn natural_size(&self) -> usize {
        HEADER_LEN + self.values.len() * 4
    }

    /// Encode, padding with zeros up to `target_bytes` (if larger than
    /// the natural size).  Padding models the paper's verbose
    /// serialization formats (PyKafka strings / raw APS frames).
    pub fn encode(&self, target_bytes: usize) -> Vec<u8> {
        let natural = self.natural_size();
        let total = natural.max(target_bytes);
        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        out.push(self.kind as u8);
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&self.produced_ns.to_le_bytes());
        out.extend_from_slice(&(self.values.len() as u32).to_le_bytes());
        out.extend_from_slice(&((total - natural) as u32).to_le_bytes());
        for v in &self.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.resize(total, 0);
        out
    }

    /// Decode from bytes (padding ignored), materializing the tensor.
    /// Hot consumers that only need the header — or that want to defer
    /// the tensor copy until compute actually runs — should use
    /// [`Message::decode_view`] instead.
    pub fn decode(bytes: &[u8]) -> Result<Message> {
        Ok(Message::decode_view(bytes)?.to_message())
    }

    /// Borrowed-payload decode: validates the frame and returns a view
    /// whose tensor bytes still live in `bytes` (for broker records,
    /// inside the log slab).  Nothing is copied — on a 0.32 MB MASS
    /// message this is ~3 orders of magnitude cheaper than [`decode`],
    /// which collects 15k f32s per call.
    pub fn decode_view(bytes: &[u8]) -> Result<MessageView<'_>> {
        if bytes.len() < HEADER_LEN {
            return Err(Error::Wire(format!("short message: {} bytes", bytes.len())));
        }
        if &bytes[0..4] != MAGIC {
            return Err(Error::Wire("bad magic".into()));
        }
        if bytes[4] != VERSION {
            return Err(Error::Wire(format!("unsupported version {}", bytes[4])));
        }
        let kind = PayloadKind::from_u8(bytes[5])?;
        let seq = u64::from_le_bytes(bytes[6..14].try_into().unwrap());
        let produced_ns = u64::from_le_bytes(bytes[14..22].try_into().unwrap());
        let n_values = u32::from_le_bytes(bytes[22..26].try_into().unwrap()) as usize;
        let need = HEADER_LEN + n_values * 4;
        if bytes.len() < need {
            return Err(Error::Wire(format!(
                "truncated payload: {} < {}",
                bytes.len(),
                need
            )));
        }
        Ok(MessageView {
            kind,
            seq,
            produced_ns,
            raw_values: &bytes[HEADER_LEN..need],
        })
    }
}

/// A decoded message whose tensor payload is *borrowed* from the
/// encoded bytes — the zero-copy companion to [`Message`].  Header
/// fields are parsed eagerly (they are 26 bytes); the f32 tensor stays
/// as LE bytes until a caller materializes it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MessageView<'a> {
    pub kind: PayloadKind,
    /// Producer-assigned sequence number.
    pub seq: u64,
    /// Producer wall-clock timestamp (ns) for end-to-end latency probes.
    pub produced_ns: u64,
    /// Tensor payload as f32-LE bytes (length = 4 × n_values).
    raw_values: &'a [u8],
}

impl<'a> MessageView<'a> {
    /// Number of f32 values in the tensor.
    pub fn n_values(&self) -> usize {
        self.raw_values.len() / 4
    }

    /// Decode one tensor element.
    pub fn value(&self, i: usize) -> f32 {
        let c = &self.raw_values[i * 4..i * 4 + 4];
        f32::from_le_bytes([c[0], c[1], c[2], c[3]])
    }

    /// Iterate the tensor without materializing it.
    pub fn values_iter(&self) -> impl Iterator<Item = f32> + 'a {
        self.raw_values
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }

    /// Materialize the tensor (the one copy compute layers need).
    pub fn to_values(&self) -> Vec<f32> {
        self.values_iter().collect()
    }

    /// Materialize the whole message (header + tensor).
    pub fn to_message(&self) -> Message {
        Message {
            kind: self.kind,
            seq: self.seq,
            produced_ns: self.produced_ns,
            values: self.to_values(),
        }
    }
}

/// Wall-clock ns helper shared by producers/probes.
pub fn now_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_without_padding() {
        let m = Message::new(PayloadKind::KmeansPoints, 7, 123, vec![1.0, -2.5, 3.25]);
        let bytes = m.encode(0);
        assert_eq!(bytes.len(), m.natural_size());
        assert_eq!(Message::decode(&bytes).unwrap(), m);
    }

    #[test]
    fn roundtrip_with_padding_to_paper_sizes() {
        // KMeans: 5000x3 f32 padded to 0.32 MB.
        let values = vec![0.5f32; 15000];
        let m = Message::new(PayloadKind::KmeansPoints, 1, 9, values);
        let bytes = m.encode(crate::config::messages::KMEANS_MSG_BYTES);
        assert_eq!(bytes.len(), 320_000);
        let back = Message::decode(&bytes).unwrap();
        assert_eq!(back.values.len(), 15000);
        // Light source: 96x192 sinogram padded to 2 MB.
        let m = Message::new(PayloadKind::Sinogram, 2, 9, vec![1.0f32; 96 * 192]);
        let bytes = m.encode(crate::config::messages::LIGHTSOURCE_MSG_BYTES);
        assert_eq!(bytes.len(), 2_000_000);
        assert_eq!(Message::decode(&bytes).unwrap().values.len(), 96 * 192);
    }

    #[test]
    fn view_matches_owned_decode() {
        let m = Message::new(PayloadKind::KmeansPoints, 3, 11, vec![1.0, 2.0, 3.0, 4.0]);
        let bytes = m.encode(256);
        let view = Message::decode_view(&bytes).unwrap();
        assert_eq!(view.kind, m.kind);
        assert_eq!(view.seq, m.seq);
        assert_eq!(view.produced_ns, m.produced_ns);
        assert_eq!(view.n_values(), 4);
        assert_eq!(view.value(2), 3.0);
        assert_eq!(view.to_values(), m.values);
        assert_eq!(view.to_message(), m);
        assert_eq!(view.values_iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Message::decode(b"tiny").is_err());
        let m = Message::new(PayloadKind::Sinogram, 0, 0, vec![1.0; 4]);
        let mut bytes = m.encode(0);
        bytes[0] = b'X';
        assert!(Message::decode(&bytes).is_err(), "bad magic");
        let mut bytes = m.encode(0);
        bytes[5] = 99;
        assert!(Message::decode(&bytes).is_err(), "bad kind");
        let bytes = m.encode(0);
        assert!(Message::decode(&bytes[..bytes.len() - 2]).is_err(), "truncated");
    }
}
