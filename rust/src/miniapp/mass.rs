//! MASS — Mini-App for Stream Source (paper §5).
//!
//! Emulates streaming data sources with pluggable production functions:
//!
//! * `cluster` source — "generates random data points following certain
//!   structures ... for evaluation of streaming cluster analysis
//!   algorithms" → [`SourceKind::KmeansRandom`];
//! * a static variant of the same message (the paper's KMeans-static
//!   scenario, §6.3) → [`SourceKind::KmeansStatic`];
//! * `template` source — "produces an unbounded stream based on a
//!   static template dataset ... can be used to emulate important
//!   applications, such as light sources" → [`SourceKind::Lightsource`].
//!
//! Producers run as tasks on a Dask-like [`TaskEngine`] (the paper runs
//! "8 producer processes in Dask" per node), each with its own RNG
//! stream and a PyKafka-style batching [`crate::broker::Producer`]
//! (the shared paced loop in [`crate::app::handle::run_producer`]).

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use crate::broker::BrokerCluster;
use crate::config::messages;
use crate::engine::TaskEngine;
use crate::error::Result;
use crate::metrics::RateMeter;
use crate::util::{RateSchedule, Rng};

use super::wire::{now_ns, Message, PayloadKind};

/// Data production function kinds.
#[derive(Debug, Clone)]
pub enum SourceKind {
    /// Random 3-D points around `n_centroids` cluster centers (fresh
    /// RNG draw per message — the paper's RNG-bound scenario).
    KmeansRandom { n_centroids: usize },
    /// The same message payload reused every send (paper: "produces a
    /// static message at a configured rate", 1.6x faster than random).
    KmeansStatic,
    /// APS-format light-source frame from a template sinogram.
    Lightsource { template: Arc<Vec<f32>> },
}

impl SourceKind {
    pub fn name(&self) -> &'static str {
        match self {
            SourceKind::KmeansRandom { .. } => "kmeans-random",
            SourceKind::KmeansStatic => "kmeans-static",
            SourceKind::Lightsource { .. } => "lightsource",
        }
    }

    pub fn payload_kind(&self) -> PayloadKind {
        match self {
            SourceKind::Lightsource { .. } => PayloadKind::Sinogram,
            _ => PayloadKind::KmeansPoints,
        }
    }

    pub fn target_bytes(&self) -> usize {
        match self {
            SourceKind::Lightsource { .. } => messages::LIGHTSOURCE_MSG_BYTES,
            _ => messages::KMEANS_MSG_BYTES,
        }
    }
}

/// MASS configuration (paper: "data rates, message sizes etc. can be
/// controlled via simple configuration options").
#[derive(Debug, Clone)]
pub struct MassConfig {
    pub source: SourceKind,
    pub topic: String,
    /// Points per KMeans message (paper: 5,000).
    pub points_per_msg: usize,
    pub point_dim: usize,
    /// Messages each producer sends (ignored when `total_messages` is
    /// set).
    pub messages_per_producer: usize,
    /// Total message budget across all producers, split near-evenly —
    /// the remainder of `total / producers` is distributed one message
    /// per producer, never silently dropped (callers used to compute
    /// `total / producers` by hand and lose it).
    pub total_messages: Option<u64>,
    /// Optional per-producer rate limit (messages/sec) — Fig 7 uses a
    /// fixed 100 msg/s aggregate rate.
    pub rate_limit: Option<f64>,
    /// Optional per-producer variable-rate schedule (takes precedence
    /// over `rate_limit`) — the bursty sources the autoscaler reacts to.
    pub schedule: Option<RateSchedule>,
    /// Override the padded message size (None = paper defaults).
    pub target_msg_bytes: Option<usize>,
    pub seed: u64,
}

impl MassConfig {
    pub fn new(source: SourceKind, topic: &str) -> Self {
        MassConfig {
            source,
            topic: topic.to_string(),
            points_per_msg: 5000,
            point_dim: 3,
            messages_per_producer: 100,
            total_messages: None,
            rate_limit: None,
            schedule: None,
            target_msg_bytes: None,
            seed: 42,
        }
    }

    /// Set the total message budget across all producers;
    /// [`messages_for`](Self::messages_for) splits it near-evenly with
    /// the remainder distributed, not dropped.
    pub fn with_total_messages(mut self, total: u64) -> Self {
        self.total_messages = Some(total);
        self
    }

    /// Message count for producer `producer` of `producers`: the even
    /// split of `total_messages` when set, else `messages_per_producer`.
    pub fn messages_for(&self, producer: usize, producers: usize) -> usize {
        match self.total_messages {
            Some(total) => crate::util::split_evenly(total, producers)[producer],
            None => self.messages_per_producer,
        }
    }
}

/// Aggregate production report.
#[derive(Debug, Clone)]
pub struct MassReport {
    pub messages: u64,
    pub bytes: u64,
    pub elapsed_secs: f64,
    pub producers: usize,
}

impl MassReport {
    pub fn msg_rate(&self) -> f64 {
        self.messages as f64 / self.elapsed_secs.max(1e-9)
    }

    pub fn mb_rate(&self) -> f64 {
        self.bytes as f64 / 1e6 / self.elapsed_secs.max(1e-9)
    }
}

/// One producer's generation state (public so the simulation plane can
/// calibrate real generation costs from the same code path).
pub struct PayloadGenerator {
    kind: SourceKind,
    rng: Rng,
    points_per_msg: usize,
    dim: usize,
    /// Cluster centers for the random source.
    centers: Vec<f32>,
    /// Cached payload for static/template sources.
    cached: Option<Vec<f32>>,
}

impl PayloadGenerator {
    pub fn new(config: &MassConfig, stream: u64) -> Self {
        let mut rng = Rng::seed_from(config.seed).fork(stream);
        let (centers, cached) = match &config.source {
            SourceKind::KmeansRandom { n_centroids } => {
                // Cluster centers depend only on the experiment seed, so
                // every producer process emulates the *same* underlying
                // cluster structure (one ground truth per experiment);
                // the per-point noise comes from the forked stream.
                let mut center_rng = Rng::seed_from(config.seed);
                let mut centers = vec![0.0f32; n_centroids * config.point_dim];
                for c in centers.iter_mut() {
                    *c = center_rng.range_f64(-50.0, 50.0) as f32;
                }
                (centers, None)
            }
            SourceKind::KmeansStatic => {
                let mut payload = vec![0.0f32; config.points_per_msg * config.point_dim];
                rng.fill_gauss_f32(&mut payload);
                (Vec::new(), Some(payload))
            }
            SourceKind::Lightsource { template } => (Vec::new(), Some((**template).clone())),
        };
        PayloadGenerator {
            kind: config.source.clone(),
            rng,
            points_per_msg: config.points_per_msg,
            dim: config.point_dim,
            centers,
            cached,
        }
    }

    /// Produce the payload values for one message.
    pub fn generate(&mut self) -> Vec<f32> {
        match &self.kind {
            SourceKind::KmeansRandom { n_centroids } => {
                let mut out = vec![0.0f32; self.points_per_msg * self.dim];
                for p in 0..self.points_per_msg {
                    let c = self.rng.below(*n_centroids);
                    for d in 0..self.dim {
                        out[p * self.dim + d] = self.centers[c * self.dim + d]
                            + 0.5 * self.rng.gauss() as f32;
                    }
                }
                out
            }
            SourceKind::KmeansStatic | SourceKind::Lightsource { .. } => {
                self.cached.as_ref().expect("cached payload").clone()
            }
        }
    }
}

/// The MASS app: drives producers on a task engine.
pub struct MassSource {
    config: MassConfig,
    pub metrics: Arc<RateMeter>,
}

impl MassSource {
    pub fn new(config: MassConfig) -> Self {
        MassSource {
            config,
            metrics: Arc::new(RateMeter::new()),
        }
    }

    pub fn config(&self) -> &MassConfig {
        &self.config
    }

    /// Run `producers` producer tasks on `engine`, each sending its
    /// share of the message budget to `cluster`.  Blocks until all
    /// producers finish; returns the aggregate report.
    ///
    /// The per-producer loop is the application layer's shared
    /// [`crate::app::handle::run_producer`] (with a never-set fence),
    /// so MASS pacing and the `StreamingApp` source driver are one
    /// code path.
    pub fn run(
        &self,
        engine: &TaskEngine,
        cluster: &BrokerCluster,
        producers: usize,
    ) -> Result<MassReport> {
        let start = Instant::now();
        let never_fenced = Arc::new(AtomicBool::new(false));
        let mut futures = Vec::with_capacity(producers);
        for i in 0..producers {
            let config = self.config.clone();
            let messages = config.messages_for(i, producers);
            let cluster = cluster.clone();
            let metrics = self.metrics.clone();
            let fence = never_fenced.clone();
            futures.push(engine.submit(move |node| -> Result<(u64, u64)> {
                crate::app::handle::run_producer(
                    &config,
                    i as u64 + 1,
                    messages,
                    &cluster,
                    &config.topic,
                    node,
                    config.rate_limit,
                    config.schedule.as_ref(),
                    &metrics,
                    &fence,
                )
            })?);
        }
        let mut messages = 0;
        let mut bytes = 0;
        for f in futures {
            let (m, b) = f.wait()??;
            messages += m;
            bytes += b;
        }
        Ok(MassReport {
            messages,
            bytes,
            elapsed_secs: start.elapsed().as_secs_f64(),
            producers,
        })
    }
}

// ---------------------------------------------------------------------
// Application-layer plug-in surface
// ---------------------------------------------------------------------

/// The built-in per-producer stream behind the [`crate::app::DataSource`]
/// impls: a [`PayloadGenerator`] whose values are framed as wire
/// messages (padded to the paper's message sizes).
pub struct MassStream {
    generator: PayloadGenerator,
    kind: PayloadKind,
    target_bytes: usize,
}

impl crate::app::SourceStream for MassStream {
    fn next_message(&mut self, seq: u64) -> Vec<u8> {
        Message::new(self.kind, seq, now_ns(), self.generator.generate()).encode(self.target_bytes)
    }
}

/// A [`MassConfig`] is a complete production recipe, so it is the
/// full-knob built-in [`crate::app::DataSource`]: payload kind, points
/// per message, seed and padded message size all come from the config
/// (pacing and message counts are owned by the application layer's
/// [`crate::app::SourceSpec`]).
impl crate::app::DataSource for MassConfig {
    fn name(&self) -> &str {
        self.source.name()
    }

    fn open(&self, stream: u64) -> Box<dyn crate::app::SourceStream> {
        Box::new(MassStream {
            generator: PayloadGenerator::new(self, stream),
            kind: self.source.payload_kind(),
            target_bytes: self
                .target_msg_bytes
                .unwrap_or_else(|| self.source.target_bytes()),
        })
    }
}

/// A bare [`SourceKind`] is the paper-defaults built-in
/// [`crate::app::DataSource`] (5,000-point messages, paper padding).
impl crate::app::DataSource for SourceKind {
    fn name(&self) -> &str {
        SourceKind::name(self)
    }

    fn open(&self, stream: u64) -> Box<dyn crate::app::SourceStream> {
        crate::app::DataSource::open(&MassConfig::new(self.clone(), ""), stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn setup() -> (Machine, BrokerCluster, TaskEngine) {
        let m = Machine::unthrottled(3);
        let c = BrokerCluster::new(m.clone(), vec![0]);
        c.create_topic("t", 3).unwrap();
        let e = TaskEngine::new(m.clone(), vec![1, 2], 2);
        (m, c, e)
    }

    fn small(source: SourceKind) -> MassConfig {
        let mut cfg = MassConfig::new(source, "t");
        cfg.points_per_msg = 100;
        cfg.messages_per_producer = 5;
        cfg.target_msg_bytes = Some(0); // no padding in unit tests
        cfg
    }

    #[test]
    fn produces_expected_message_count() {
        let (_m, c, e) = setup();
        let mass = MassSource::new(small(SourceKind::KmeansRandom { n_centroids: 4 }));
        let report = mass.run(&e, &c, 3).unwrap();
        assert_eq!(report.messages, 15);
        let total: u64 = (0..3).map(|p| c.end_offset("t", p).unwrap()).sum();
        assert_eq!(total, 15, "all messages landed in the broker");
        assert!(report.msg_rate() > 0.0);
        e.stop();
    }

    #[test]
    fn total_messages_distributes_the_remainder() {
        // 25 over 4 producers: 7+6+6+6, nothing silently dropped (the
        // old callers' `total / producers` would send 24).
        let cfg = small(SourceKind::KmeansStatic).with_total_messages(25);
        assert_eq!(
            (0..4).map(|i| cfg.messages_for(i, 4)).collect::<Vec<_>>(),
            vec![7, 6, 6, 6]
        );
        let (_m, c, e) = setup();
        let mass = MassSource::new(cfg);
        let report = mass.run(&e, &c, 4).unwrap();
        assert_eq!(report.messages, 25, "full budget produced");
        let total: u64 = (0..3).map(|p| c.end_offset("t", p).unwrap()).sum();
        assert_eq!(total, 25);
        e.stop();
    }

    #[test]
    fn mass_config_is_a_data_source() {
        use crate::app::DataSource;
        let cfg = small(SourceKind::KmeansRandom { n_centroids: 2 });
        assert_eq!(DataSource::name(&cfg), "kmeans-random");
        let mut a = cfg.open(1);
        let mut b = cfg.open(2);
        let (m1, m2) = (a.next_message(0), b.next_message(0));
        let d1 = Message::decode(&m1).unwrap();
        assert_eq!(d1.kind, PayloadKind::KmeansPoints);
        assert_eq!(d1.values.len(), 100 * 3);
        assert_ne!(m1, m2, "producer streams fork the RNG");
        // A bare SourceKind works with paper defaults (5,000 points).
        let mut s = DataSource::open(&SourceKind::KmeansStatic, 1);
        let d = Message::decode(&s.next_message(0)).unwrap();
        assert_eq!(d.values.len(), 5000 * 3);
    }

    #[test]
    fn random_messages_decode_with_right_shape() {
        let (_m, c, e) = setup();
        let mass = MassSource::new(small(SourceKind::KmeansRandom { n_centroids: 2 }));
        mass.run(&e, &c, 1).unwrap();
        let recs = c
            .fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(100))
            .unwrap();
        assert!(!recs.is_empty());
        let msg = Message::decode(&recs[0].value).unwrap();
        assert_eq!(msg.kind, PayloadKind::KmeansPoints);
        assert_eq!(msg.values.len(), 100 * 3);
        e.stop();
    }

    #[test]
    fn static_source_repeats_payload() {
        let (_m, c, e) = setup();
        let mut cfg = small(SourceKind::KmeansStatic);
        cfg.messages_per_producer = 2;
        let mass = MassSource::new(cfg);
        mass.run(&e, &c, 1).unwrap();
        let mut all = Vec::new();
        for p in 0..3 {
            all.extend(
                c.fetch("t", p, 0, usize::MAX, 1, Duration::from_millis(50))
                    .unwrap(),
            );
        }
        assert_eq!(all.len(), 2);
        let a = Message::decode(&all[0].value).unwrap();
        let b = Message::decode(&all[1].value).unwrap();
        assert_eq!(a.values, b.values, "static payload identical");
        e.stop();
    }

    #[test]
    fn template_source_round_trips_sinogram() {
        let (_m, c, e) = setup();
        let template = Arc::new(vec![1.5f32; 96]);
        let mut cfg = small(SourceKind::Lightsource { template });
        cfg.messages_per_producer = 1;
        let mass = MassSource::new(cfg);
        mass.run(&e, &c, 1).unwrap();
        let mut found = None;
        for p in 0..3 {
            let recs = c
                .fetch("t", p, 0, usize::MAX, 1, Duration::from_millis(50))
                .unwrap();
            if !recs.is_empty() {
                found = Some(recs[0].clone());
            }
        }
        let msg = Message::decode(&found.unwrap().value).unwrap();
        assert_eq!(msg.kind, PayloadKind::Sinogram);
        assert_eq!(msg.values, vec![1.5f32; 96]);
        e.stop();
    }

    #[test]
    fn schedule_paces_burst_then_trickle() {
        let (_m, c, e) = setup();
        let mut cfg = small(SourceKind::KmeansStatic);
        cfg.messages_per_producer = 8;
        // 6 messages land immediately (fast burst), the last 2 at 20/s.
        cfg.schedule = Some(RateSchedule::starting_at(0.012, 500.0).then(f64::INFINITY, 20.0));
        let mass = MassSource::new(cfg);
        let report = mass.run(&e, &c, 1).unwrap();
        assert_eq!(report.messages, 8);
        // The last message (seq 7) is due at 0.012 + 1/20 = 0.062 s.
        assert!(
            report.elapsed_secs >= 0.05,
            "schedule pacing too fast: {}",
            report.elapsed_secs
        );
        e.stop();
    }

    #[test]
    fn rate_limit_paces_production() {
        let (_m, c, e) = setup();
        let mut cfg = small(SourceKind::KmeansStatic);
        cfg.messages_per_producer = 5;
        cfg.rate_limit = Some(50.0); // 5 msgs at 50/s => >= 80 ms
        let mass = MassSource::new(cfg);
        let report = mass.run(&e, &c, 1).unwrap();
        assert!(
            report.elapsed_secs >= 0.07,
            "rate limiting too fast: {}",
            report.elapsed_secs
        );
        let _ = c;
        e.stop();
    }
}
