//! PJRT runtime: load AOT artifacts, execute them on the hot path.
//!
//! The compile path (``python/compile/aot.py``) lowers every L2 JAX
//! model to HLO *text* (see /opt/xla-example/README.md for why text, not
//! serialized protos) plus a ``manifest.json`` describing input/output
//! signatures.  This module is the serving-side half: it parses the
//! manifest, compiles each artifact once per OS thread on a PJRT CPU
//! client, and exposes a typed `execute` API the stream engines call per
//! micro-batch.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so clients and compiled executables live in thread-local storage —
//! each engine executor thread lazily builds its own client + executable
//! cache on first use and reuses it for the life of the thread.  The
//! cloneable [`ModelRuntime`] handle itself is `Send + Sync`.
//!
//! The `xla` crate is not part of the offline dependency set, so the
//! PJRT executor is gated behind the `xla` cargo feature.  Without it,
//! manifest parsing and raw data artifacts still work (the simulation
//! plane and the coordination layer need nothing else) and
//! `warmup`/`execute` return `Error::Xla`.

#[cfg(feature = "xla")]
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::util::Json;

/// Tensor signature from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("sig.shape: expected array".into()))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| Error::Artifact("sig.shape: expected ints".into()))
            })
            .collect::<Result<Vec<usize>>>()?;
        let dtype = j
            .req("dtype")?
            .as_str()
            .ok_or_else(|| Error::Artifact("sig.dtype: expected string".into()))?
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Artifact(format!("{key}: expected int")))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.req(key)?
        .as_f64()
        .ok_or_else(|| Error::Artifact(format!("{key}: expected number")))
}

fn sig_list(j: &Json, key: &str) -> Result<Vec<TensorSig>> {
    j.req(key)?
        .as_arr()
        .ok_or_else(|| Error::Artifact(format!("{key}: expected array")))?
        .iter()
        .map(TensorSig::from_json)
        .collect()
}

/// Per-artifact manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// KMeans model parameters (mirrors python/compile/params.py).
#[derive(Debug, Clone)]
pub struct KmeansParams {
    pub n_points: usize,
    pub dim: usize,
    pub k: usize,
    pub decay: f64,
    pub block: usize,
}

/// Tomography parameters (mirrors python/compile/params.py).
#[derive(Debug, Clone)]
pub struct TomoParams {
    pub n_angles: usize,
    pub n_det: usize,
    pub img_h: usize,
    pub img_w: usize,
    pub n_ray: usize,
    pub mlem_iters: usize,
    pub angle_block: usize,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub kmeans: KmeansParams,
    pub tomo: TomoParams,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let km = j.req("kmeans")?;
        let tm = j.req("tomo")?;
        let kmeans = KmeansParams {
            n_points: req_usize(km, "n_points")?,
            dim: req_usize(km, "dim")?,
            k: req_usize(km, "k")?,
            decay: req_f64(km, "decay")?,
            block: req_usize(km, "block")?,
        };
        let tomo = TomoParams {
            n_angles: req_usize(tm, "n_angles")?,
            n_det: req_usize(tm, "n_det")?,
            img_h: req_usize(tm, "img_h")?,
            img_w: req_usize(tm, "img_w")?,
            n_ray: req_usize(tm, "n_ray")?,
            mlem_iters: req_usize(tm, "mlem_iters")?,
            angle_block: req_usize(tm, "angle_block")?,
        };
        let mut artifacts = HashMap::new();
        for (name, a) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("artifacts: expected object".into()))?
        {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: a
                        .req("file")?
                        .as_str()
                        .ok_or_else(|| Error::Artifact("file: expected string".into()))?
                        .to_string(),
                    inputs: sig_list(a, "inputs")?,
                    outputs: sig_list(a, "outputs")?,
                },
            );
        }
        Ok(Manifest {
            kmeans,
            tomo,
            artifacts,
        })
    }
}

/// A host tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            Tensor::I32(_) => Err(Error::Artifact("expected f32 tensor, got i32".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            Tensor::F32(_) => Err(Error::Artifact("expected i32 tensor, got f32".into())),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(feature = "xla")]
thread_local! {
    /// Per-thread PJRT state: one CPU client + executables keyed by
    /// (artifact dir, artifact name).
    static TLS: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

#[cfg(feature = "xla")]
struct ThreadCtx {
    client: xla::PjRtClient,
    executables: HashMap<(PathBuf, String), xla::PjRtLoadedExecutable>,
}

/// Cloneable, thread-safe handle to the AOT artifact set.
#[derive(Clone)]
pub struct ModelRuntime {
    dir: PathBuf,
    manifest: Arc<Manifest>,
}

impl std::fmt::Debug for ModelRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ModelRuntime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}

impl ModelRuntime {
    /// Load the manifest from an artifacts directory (built by
    /// ``make artifacts``).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        // Silence TfrtCpuClient created/destroyed chatter before the
        // first client exists.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
        }
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} (run `make artifacts` first)",
                manifest_path.display()
            ))
        })?;
        let manifest = Manifest::parse(&text)?;
        Ok(ModelRuntime {
            dir,
            manifest: Arc::new(manifest),
        })
    }

    /// Locate the default artifacts directory: `$PILOT_ARTIFACTS`, else
    /// `artifacts/` relative to the crate root (works from `cargo run`
    /// / `cargo test` / `cargo bench`).
    pub fn load_default() -> Result<Self> {
        if let Some(dir) = std::env::var_os("PILOT_ARTIFACTS") {
            return Self::load(dir);
        }
        let candidates = [
            PathBuf::from("artifacts"),
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        ];
        for c in &candidates {
            if c.join("manifest.json").exists() {
                return Self::load(c);
            }
        }
        Err(Error::Artifact(
            "artifacts/manifest.json not found; run `make artifacts`".into(),
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        names.sort();
        names
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name}")))
    }

    /// Read a raw f32 data artifact (phantom.bin, template_sinogram.bin,
    /// testvectors/*).
    pub fn read_f32_file(&self, rel: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(rel))?;
        if bytes.len() % 4 != 0 {
            return Err(Error::Artifact(format!("{rel}: not a multiple of 4 bytes")));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a raw i32 data artifact (testvectors with int outputs).
    pub fn read_i32_file(&self, rel: &str) -> Result<Vec<i32>> {
        let bytes = std::fs::read(self.dir.join(rel))?;
        if bytes.len() % 4 != 0 {
            return Err(Error::Artifact(format!("{rel}: not a multiple of 4 bytes")));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    #[cfg(feature = "xla")]
    fn with_executable<R>(
        &self,
        name: &str,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<R>,
    ) -> Result<R> {
        let meta = self.meta(name)?;
        let key = (self.dir.clone(), name.to_string());
        TLS.with(|tls| {
            let mut slot = tls.borrow_mut();
            if slot.is_none() {
                *slot = Some(ThreadCtx {
                    client: xla::PjRtClient::cpu()?,
                    executables: HashMap::new(),
                });
            }
            let ctx = slot.as_mut().unwrap();
            if !ctx.executables.contains_key(&key) {
                let path = self.dir.join(&meta.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| Error::Artifact("bad path".into()))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = ctx.client.compile(&comp)?;
                ctx.executables.insert(key.clone(), exe);
            }
            f(ctx.executables.get(&key).unwrap())
        })
    }

    /// Pre-compile an artifact on the calling thread (so first-message
    /// latency on the hot path excludes XLA compilation).
    #[cfg(feature = "xla")]
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.with_executable(name, |_| Ok(()))
    }

    /// Stub without the `xla` feature: validates the artifact name, then
    /// reports that the PJRT executor is unavailable.
    #[cfg(not(feature = "xla"))]
    pub fn warmup(&self, name: &str) -> Result<()> {
        self.meta(name)?;
        Err(Error::Xla(format!(
            "{name}: built without the `xla` feature; PJRT execution unavailable"
        )))
    }

    /// Execute artifact `name` with host `inputs`.
    ///
    /// Inputs must match the manifest signature (f32 tensors with the
    /// right element counts); outputs come back as typed [`Tensor`]s.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Artifact(format!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (sig, data)) in meta.inputs.iter().zip(inputs).enumerate() {
            if sig.dtype != "float32" {
                return Err(Error::Artifact(format!(
                    "{name}: input {i} dtype {} unsupported via f32 API",
                    sig.dtype
                )));
            }
            if sig.elements() != data.len() {
                return Err(Error::Artifact(format!(
                    "{name}: input {i} expects {} elements, got {}",
                    sig.elements(),
                    data.len()
                )));
            }
        }

        self.execute_validated(name, &meta, inputs)
    }

    #[cfg(not(feature = "xla"))]
    fn execute_validated(
        &self,
        name: &str,
        _meta: &ArtifactMeta,
        _inputs: &[&[f32]],
    ) -> Result<Vec<Tensor>> {
        Err(Error::Xla(format!(
            "{name}: built without the `xla` feature; PJRT execution unavailable"
        )))
    }

    #[cfg(feature = "xla")]
    fn execute_validated(
        &self,
        name: &str,
        meta: &ArtifactMeta,
        inputs: &[&[f32]],
    ) -> Result<Vec<Tensor>> {
        self.with_executable(name, |exe| {
            let mut literals = Vec::with_capacity(inputs.len());
            for (sig, data) in meta.inputs.iter().zip(inputs) {
                let dims: Vec<i64> = sig.shape.iter().map(|d| *d as i64).collect();
                let lit = if dims.len() == 1 || dims.is_empty() {
                    xla::Literal::vec1(data)
                } else {
                    xla::Literal::vec1(data).reshape(&dims)?
                };
                literals.push(lit);
            }
            let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: always a tuple.
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for (i, part) in parts.into_iter().enumerate() {
                let sig = meta.outputs.get(i).ok_or_else(|| {
                    Error::Artifact(format!("{name}: more outputs than manifest"))
                })?;
                let t = match sig.dtype.as_str() {
                    "float32" => Tensor::F32(part.to_vec::<f32>()?),
                    "int32" => Tensor::I32(part.to_vec::<i32>()?),
                    other => {
                        return Err(Error::Artifact(format!(
                            "{name}: output {i} dtype {other} unsupported"
                        )))
                    }
                };
                if t.len() != sig.elements() {
                    return Err(Error::Artifact(format!(
                        "{name}: output {i} has {} elements, manifest says {}",
                        t.len(),
                        sig.elements()
                    )));
                }
                out.push(t);
            }
            Ok(out)
        })
    }

    /// Measure mean wall-clock seconds per execution of `name` over `n`
    /// runs (after one warmup) — the calibration input for the
    /// simulation plane (DESIGN.md §4b).
    pub fn calibrate(&self, name: &str, n: usize) -> Result<f64> {
        let meta = self.meta(name)?.clone();
        let inputs: Vec<Vec<f32>> = meta
            .inputs
            .iter()
            .map(|sig| vec![0.5f32; sig.elements()])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        self.execute(name, &refs)?; // warmup (compile + first run)
        let start = Instant::now();
        for _ in 0..n.max(1) {
            self.execute(name, &refs)?;
        }
        Ok(start.elapsed().as_secs_f64() / n.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors() {
        let t = Tensor::F32(vec![1.0, 2.0]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_err());
        assert_eq!(t.len(), 2);
        let t = Tensor::I32(vec![3]);
        assert_eq!(t.as_i32().unwrap(), &[3]);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn manifest_parses() {
        let json = r#"{
            "kmeans": {"n_points": 10, "dim": 3, "k": 2, "decay": 0.9, "block": 5},
            "tomo": {"n_angles": 4, "n_det": 8, "img_h": 4, "img_w": 4,
                     "n_ray": 8, "mlem_iters": 2, "angle_block": 2},
            "artifacts": {
                "m": {"file": "m.hlo.txt",
                       "inputs": [{"shape": [10, 3], "dtype": "float32"}],
                       "outputs": [{"shape": [10], "dtype": "int32"}]}
            }
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.artifacts["m"].inputs[0].elements(), 30);
        assert_eq!(m.artifacts["m"].outputs[0].dtype, "int32");
        assert_eq!(m.kmeans.k, 2);
        assert_eq!(m.tomo.n_det, 8);
        assert!(Manifest::parse("{}").is_err());
    }
}
