//! Latency models for the Figure 7 experiment.
//!
//! Fig 7 compares end-to-end latency (production -> processing) at
//! 100 msg/s across: a plain Kafka consumer, Spark Streaming with
//! micro-batch windows from 0.2 s to 8 s, Amazon Kinesis and Google
//! Pub/Sub.  The Kafka base latency is a component model (client
//! serialize + two NIC hops + broker append + consumer poll); Spark
//! adds batch-boundary wait (uniform over the window) plus task
//! overhead — the paper reports the added overhead spanning ~0.2 s
//! (0.2 s window) to ~3 s (8 s window).  Cloud services use the
//! calibrated [`CloudBroker`] models.

use crate::broker::cloud::CloudBroker;
use crate::util::Rng;

use super::cost::CostModel;

/// Summary statistics for one latency configuration.
#[derive(Debug, Clone)]
pub struct LatencySummary {
    pub config: String,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

fn summarize(config: &str, mut samples: Vec<f64>) -> LatencySummary {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len().max(1);
    LatencySummary {
        config: config.to_string(),
        mean_secs: samples.iter().sum::<f64>() / n as f64,
        p50_secs: samples[n / 2],
        p99_secs: samples[((n as f64 * 0.99) as usize).min(n - 1)],
    }
}

/// The Fig 7 latency simulator.
pub struct LatencySim {
    costs: CostModel,
    msg_bytes: f64,
    nic_bps: f64,
    seed: u64,
}

impl LatencySim {
    pub fn new(costs: CostModel, msg_bytes: f64, nic_bps: f64, seed: u64) -> Self {
        LatencySim {
            costs,
            msg_bytes,
            nic_bps,
            seed,
        }
    }

    /// One Kafka produce->consume latency sample: serialize + two NIC
    /// hops + append + consumer long-poll delay (exponential, mean
    /// a few ms) + client deserialization jitter.
    fn kafka_sample(&self, rng: &mut Rng) -> f64 {
        let serialize = self.costs.gen_static_secs.max(1e-4);
        let hop = self.msg_bytes / self.nic_bps;
        let append = self.msg_bytes / 120e6;
        let poll = rng.exponential(1.0 / 0.004); // mean 4 ms poll delay
        let jitter = rng.lognormal(-6.0, 0.5); // ~2.5 ms client overhead
        serialize + 2.0 * hop + append + poll + jitter
    }

    /// Latency distribution of the plain Kafka consumer.
    pub fn kafka(&self, n: usize) -> LatencySummary {
        let mut rng = Rng::seed_from(self.seed);
        let samples = (0..n).map(|_| self.kafka_sample(&mut rng)).collect();
        summarize("kafka", samples)
    }

    /// Spark Streaming on top of Kafka with a micro-batch `window`:
    /// records wait for the batch boundary (uniform over the window)
    /// then pay scheduling + processing overhead.
    pub fn spark_streaming(&self, window_secs: f64, n: usize) -> LatencySummary {
        let mut rng = Rng::seed_from(self.seed ^ 0x5111);
        let samples = (0..n)
            .map(|_| {
                let base = self.kafka_sample(&mut rng);
                let boundary_wait = rng.f64() * window_secs;
                base + boundary_wait + self.costs.task_overhead_secs
            })
            .collect();
        summarize(&format!("spark-{window_secs}s"), samples)
    }

    /// Cloud broker latency (Kinesis / Pub/Sub models).
    pub fn cloud(&self, broker: &CloudBroker, n: usize) -> LatencySummary {
        summarize(broker.name(), broker.sample_latencies(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> LatencySim {
        // Fig 7 uses the KMeans message at 100 msg/s.
        LatencySim::new(CostModel::paper_era(), 0.32e6, 1.25e9, 11)
    }

    #[test]
    fn fig7_ordering_kafka_below_spark_below_cloud() {
        let s = sim();
        let kafka = s.kafka(4000);
        let spark = s.spark_streaming(1.0, 4000);
        let pubsub = s.cloud(&CloudBroker::pubsub(1), 4000);
        assert!(kafka.mean_secs < spark.mean_secs);
        assert!(spark.mean_secs < pubsub.mean_secs);
        // Paper: Pub/Sub ~6.2 s mean, the worst of all.
        assert!((5.0..7.5).contains(&pubsub.mean_secs), "{}", pubsub.mean_secs);
    }

    #[test]
    fn fig7_spark_overhead_tracks_window() {
        let s = sim();
        let kafka = s.kafka(4000).mean_secs;
        // Paper: overhead ~0.2 s at a 0.2 s window, ~3 s at an 8 s window.
        let w02 = s.spark_streaming(0.2, 4000).mean_secs - kafka;
        let w8 = s.spark_streaming(8.0, 4000).mean_secs - kafka;
        assert!((0.1..0.5).contains(&w02), "0.2s window overhead {w02}");
        assert!((2.5..4.8).contains(&w8), "8s window overhead {w8}");
        assert!(w8 > w02 * 8.0, "overhead grows ~linearly with window");
    }

    #[test]
    fn fig7_kinesis_subsecond() {
        let s = sim();
        let kinesis = s.cloud(&CloudBroker::kinesis(2), 4000);
        assert!((0.2..0.9).contains(&kinesis.mean_secs), "{}", kinesis.mean_secs);
        assert!(kinesis.p99_secs > kinesis.p50_secs);
    }

    #[test]
    fn fig7_kafka_millisecond_scale() {
        let s = sim();
        let kafka = s.kafka(4000);
        assert!(kafka.mean_secs < 0.1, "kafka mean {} (ms-scale)", kafka.mean_secs);
        assert!(kafka.p99_secs < 0.25);
    }
}
