//! Cost models for the simulation plane (DESIGN.md §4b).
//!
//! Two presets:
//!
//! * [`CostModel::calibrated`] — constants measured from *this repo's
//!   real plane*: Rust MASS generators and the PJRT-executed AOT
//!   artifacts (`ModelRuntime::calibrate`).  This is the honest
//!   "our implementation at Wrangler scale" model.
//! * [`CostModel::paper_era`] — producer generation and per-message
//!   processing costs scaled to the paper's Python stack (NumPy RNG +
//!   PyKafka string serialization; Spark/MLlib + TomoPy per-message
//!   overheads), restoring the regimes behind Fig 8's
//!   static-vs-random gap and Fig 9's absolute rates.

use crate::config::CostPreset;
use crate::runtime::ModelRuntime;

/// Per-operation virtual-time costs (seconds).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Generate + serialize one KMeans-random message (fresh RNG draw).
    pub gen_random_secs: f64,
    /// Serialize one static KMeans message (buffer reuse).
    pub gen_static_secs: f64,
    /// Serialize one light-source template message (2 MB copy).
    pub gen_lightsource_secs: f64,
    /// Process one KMeans message (score + model update).
    pub proc_kmeans_secs: f64,
    /// Reconstruct one sinogram with GridRec.
    pub proc_gridrec_secs: f64,
    /// Reconstruct one sinogram with ML-EM.
    pub proc_mlem_secs: f64,
    /// Per-task scheduling overhead of the micro-batch engine.
    pub task_overhead_secs: f64,
    /// Broker ack round trip (intra-cluster network).
    pub ack_rtt_secs: f64,
}

impl CostModel {
    /// Paper-era (Python) costs.  Producer costs sized so one producer
    /// process generates ~29 random / ~45 static msg/s (NumPy+PyKafka,
    /// §6.3's 1.6x static-over-random gap); processing costs sized to
    /// the paper's per-algorithm rates (§6.4: KMeans 277, GridRec 63,
    /// ML-EM 22 msg/s at max scale).
    pub fn paper_era() -> Self {
        CostModel {
            gen_random_secs: 0.035,
            gen_static_secs: 0.022,
            gen_lightsource_secs: 0.030,
            // With 4 broker nodes the paper runs 48 partitions and Spark
            // parallelism is capped at one task per partition, so
            // rate_max = partitions / proc_cost.  277/63/22 msg/s at 48
            // partitions => ~0.17/0.76/2.2 core-seconds per message.
            proc_kmeans_secs: 0.16,
            proc_gridrec_secs: 0.75,
            proc_mlem_secs: 2.3,
            task_overhead_secs: 0.15,
            ack_rtt_secs: 0.001,
        }
    }

    /// Fallback calibrated costs (measured once on the dev host; the
    /// live path re-measures via [`CostModel::calibrate`]).
    pub fn calibrated_default() -> Self {
        CostModel {
            gen_random_secs: 600e-6,
            gen_static_secs: 60e-6,
            gen_lightsource_secs: 120e-6,
            proc_kmeans_secs: 2.5e-3,
            proc_gridrec_secs: 20e-3,
            proc_mlem_secs: 130e-3,
            task_overhead_secs: 2e-3,
            ack_rtt_secs: 0.2e-3,
        }
    }

    pub fn preset(preset: CostPreset) -> Self {
        match preset {
            CostPreset::PaperEra => Self::paper_era(),
            CostPreset::Calibrated => Self::calibrated_default(),
        }
    }

    /// Measure the real plane: MASS generator micro-bench + PJRT
    /// execution of each artifact.  `reps` trades precision for time.
    pub fn calibrate(runtime: &ModelRuntime, reps: usize) -> crate::Result<Self> {
        use crate::miniapp::mass::{MassConfig, SourceKind};
        use std::time::Instant;

        let mut model = Self::calibrated_default();

        // Generator costs: time the real generator structs.
        let km = runtime.manifest().kmeans.clone();
        let tomo = runtime.manifest().tomo.clone();
        let template =
            std::sync::Arc::new(runtime.read_f32_file("template_sinogram.bin")?);
        let time_gen = |source: SourceKind, points: usize| -> f64 {
            let mut cfg = MassConfig::new(source, "calib");
            cfg.points_per_msg = points;
            let mut generator = crate::miniapp::mass::PayloadGenerator::new(&cfg, 1);
            let target = cfg.source.target_bytes();
            let t0 = Instant::now();
            for seq in 0..reps.max(1) {
                let values = generator.generate();
                let msg = crate::miniapp::Message::new(
                    cfg.source.payload_kind(),
                    seq as u64,
                    0,
                    values,
                );
                std::hint::black_box(msg.encode(target));
            }
            t0.elapsed().as_secs_f64() / reps.max(1) as f64
        };
        model.gen_random_secs = time_gen(
            SourceKind::KmeansRandom { n_centroids: km.k },
            km.n_points,
        );
        model.gen_static_secs = time_gen(SourceKind::KmeansStatic, km.n_points);
        model.gen_lightsource_secs = time_gen(
            SourceKind::Lightsource { template },
            tomo.n_angles * tomo.n_det / 3, // values count unused for template
        );

        // Processing costs: real PJRT execution.
        model.proc_kmeans_secs =
            runtime.calibrate("kmeans_score", reps)? + runtime.calibrate("kmeans_update", reps)?;
        model.proc_gridrec_secs = runtime.calibrate("gridrec", reps)?;
        model.proc_mlem_secs = runtime.calibrate("mlem", reps.max(2) / 2)?;
        Ok(model)
    }

    pub fn gen_cost(&self, source: &str) -> f64 {
        match source {
            "kmeans-random" => self.gen_random_secs,
            "kmeans-static" => self.gen_static_secs,
            "lightsource" => self.gen_lightsource_secs,
            _ => self.gen_random_secs,
        }
    }

    pub fn proc_cost(&self, processor: &str) -> f64 {
        match processor {
            "kmeans" => self.proc_kmeans_secs,
            "gridrec" => self.proc_gridrec_secs,
            "mlem" => self.proc_mlem_secs,
            _ => self.proc_kmeans_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_era_preserves_key_ratios() {
        let m = CostModel::paper_era();
        // Fig 8: static ~1.6x faster generation than random.
        let ratio = m.gen_random_secs / m.gen_static_secs;
        assert!((1.4..1.8).contains(&ratio), "ratio={ratio}");
        // Fig 9: KMeans >> GridRec > MLEM throughput => costs inverse.
        assert!(m.proc_kmeans_secs < m.proc_gridrec_secs);
        assert!(m.proc_gridrec_secs < m.proc_mlem_secs);
        // GridRec ~3x faster than MLEM (63 vs 22 msg/s).
        let r = m.proc_mlem_secs / m.proc_gridrec_secs;
        assert!((2.0..4.0).contains(&r), "r={r}");
    }

    #[test]
    fn preset_lookup() {
        let p = CostModel::preset(CostPreset::PaperEra);
        assert_eq!(p.gen_random_secs, CostModel::paper_era().gen_random_secs);
        let c = CostModel::preset(CostPreset::Calibrated);
        assert!(c.gen_random_secs < p.gen_random_secs, "rust faster than numpy");
    }

    #[test]
    fn cost_lookup_by_name() {
        let m = CostModel::paper_era();
        assert_eq!(m.gen_cost("kmeans-static"), m.gen_static_secs);
        assert_eq!(m.proc_cost("mlem"), m.proc_mlem_secs);
    }
}
