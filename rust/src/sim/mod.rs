//! The simulation plane (DESIGN.md §4b).
//!
//! This host has one CPU core; the paper's evaluation spans 32 nodes /
//! 1536 vcores.  The simulation plane reproduces the paper's figures at
//! full scale in virtual time:
//!
//! * [`resources`] — timeline resources (FIFO servers, core banks) from
//!   which queueing delays and saturation knees emerge;
//! * [`cost`] — per-operation cost models, either *calibrated* from the
//!   real plane (Rust generators + PJRT-executed artifacts) or the
//!   *paper-era* Python-stack preset;
//! * [`pipeline`] — the Fig 8 closed-loop producer simulation and the
//!   Fig 9 micro-batch processing simulation;
//! * [`latency`] — the Fig 7 latency component models;
//! * [`startup`] — the Fig 6 startup grid (shared with the live
//!   plugins' bootstrap models);
//! * [`elastic`] — the autoscaling harness: variable-rate sources
//!   driving [`crate::autoscale`] policies in virtual time, with
//!   modeled provisioning delays, at 32-node scale.

pub mod cost;
pub mod elastic;
pub mod latency;
pub mod pipeline;
pub mod resources;
pub mod startup;

pub use cost::CostModel;
pub use elastic::{ElasticScenario, ElasticSim, ElasticSimResult, ElasticWindow};
pub use latency::{LatencySim, LatencySummary};
pub use pipeline::{
    ProcessingScenario, ProcessingSim, ProcessingSimResult, ProducerScenario, ProducerSim,
    ProducerSimResult, SimMachine,
};
pub use resources::{CoreBank, SerialResource};
pub use startup::{startup_grid, wrangler_queue, StartupPoint};
