//! Timeline resources for the discrete-event simulation plane.
//!
//! The simulator models each contended device (NIC direction, SSD,
//! executor cores) as a *timeline resource*: acquiring `work` units at
//! virtual time `t` reserves the next available slot and returns the
//! completion time.  Because every acquisition is issued in
//! non-decreasing virtual-time order by the drivers (see `pipeline.rs`),
//! this reproduces FIFO queueing — including the queueing delays that
//! produce saturation knees — without a general event calendar.

/// A serial FIFO server with a fixed service rate (e.g. a NIC direction
/// at bytes/sec, a disk at bytes/sec).
#[derive(Debug, Clone)]
pub struct SerialResource {
    /// Units per virtual second.
    rate: f64,
    /// Time at which the server becomes free.
    free_at: f64,
    /// Total busy time (utilization probe).
    busy: f64,
}

impl SerialResource {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        SerialResource {
            rate,
            free_at: 0.0,
            busy: 0.0,
        }
    }

    /// Acquire `work` units at time `now`; returns completion time.
    pub fn acquire(&mut self, now: f64, work: f64) -> f64 {
        let start = self.free_at.max(now);
        let dur = work / self.rate;
        self.free_at = start + dur;
        self.busy += dur;
        self.free_at
    }

    /// Utilization over `[0, horizon]`.
    pub fn utilization(&self, horizon: f64) -> f64 {
        (self.busy / horizon.max(1e-12)).min(1.0)
    }

    pub fn free_at(&self) -> f64 {
        self.free_at
    }
}

/// A bank of `k` identical servers with per-task durations (executor
/// cores).  Tasks go to the earliest-free core.
#[derive(Debug, Clone)]
pub struct CoreBank {
    free_at: Vec<f64>,
    busy: f64,
}

impl CoreBank {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0);
        CoreBank {
            free_at: vec![0.0; cores],
            busy: 0.0,
        }
    }

    pub fn cores(&self) -> usize {
        self.free_at.len()
    }

    /// Schedule a task of `dur` seconds at `now`; returns completion.
    pub fn schedule(&mut self, now: f64, dur: f64) -> f64 {
        // Earliest-free core.
        let (idx, _) = self
            .free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = self.free_at[idx].max(now);
        self.free_at[idx] = start + dur;
        self.busy += dur;
        self.free_at[idx]
    }

    /// When all currently-scheduled work completes.
    pub fn drained_at(&self) -> f64 {
        self.free_at.iter().cloned().fold(0.0, f64::max)
    }

    pub fn utilization(&self, horizon: f64) -> f64 {
        (self.busy / (self.cores() as f64 * horizon.max(1e-12))).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_resource_fifo_queueing() {
        let mut r = SerialResource::new(10.0); // 10 units/sec
        assert_eq!(r.acquire(0.0, 10.0), 1.0);
        // Second request at t=0 queues behind the first.
        assert_eq!(r.acquire(0.0, 10.0), 2.0);
        // Request after the queue drains starts immediately.
        assert_eq!(r.acquire(5.0, 10.0), 6.0);
        assert!((r.utilization(6.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn core_bank_parallelism() {
        let mut b = CoreBank::new(2);
        assert_eq!(b.schedule(0.0, 1.0), 1.0);
        assert_eq!(b.schedule(0.0, 1.0), 1.0, "second core in parallel");
        assert_eq!(b.schedule(0.0, 1.0), 2.0, "third task queues");
        assert_eq!(b.drained_at(), 2.0);
        assert!((b.utilization(2.0) - 0.75).abs() < 1e-9);
    }

    #[test]
    fn saturation_emerges_when_offered_exceeds_rate() {
        // Offer 20 units/sec to a 10 units/sec server for 100 s.
        let mut r = SerialResource::new(10.0);
        let mut done = 0.0;
        for i in 0..2000 {
            let t = i as f64 * 0.05; // arrivals at 20/sec, 1 unit each
            done = r.acquire(t, 1.0);
        }
        // Completion time ~ 200 s (work-limited), not 100 s.
        assert!(done > 190.0, "done={done}");
    }
}
