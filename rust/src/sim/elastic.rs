//! Virtual-time elastic-scaling simulation.
//!
//! Exercises the [`crate::autoscale`] policies deterministically at
//! paper scale (32 Wrangler nodes) on a small host: a variable-rate
//! source ([`RateSchedule`]) feeds per-partition backlogs; each
//! micro-batch window the fleet processes what its cores allow (one
//! task per partition, paper §6.4); the policy sees the same
//! [`SignalSnapshot`] shape as on the real plane and its decisions are
//! applied with a modeled provisioning delay (batch queue + framework
//! extension), which is exactly the reaction-latency trade-off the
//! elasticity literature studies.
//!
//! Everything is pure arithmetic over virtual time — two runs of the
//! same scenario produce byte-identical results, so policy behaviour is
//! unit-testable at 32-node scale.
//!
//! Two entry points: [`ElasticSim::run`] exercises a bare policy (the
//! pre-planner decision path, kept for controller-free studies), and
//! [`ElasticSim::run_planned`] routes every intent through a
//! [`Planner`], executing the costed plans with per-framework extension
//! delays and a *dynamic broker tier* — `ExtendBroker` steps grow the
//! broker node count mid-run, so repartition-aware broker scale-up is
//! testable deterministically.

use crate::autoscale::{PlanStep, Planner, ScalingIntent, ScalingPolicy, SignalSnapshot};
use crate::broker::AckMode;
use crate::util::RateSchedule;

use super::cost::CostModel;
use super::pipeline::SimMachine;

/// An elastic-scaling scenario.
#[derive(Debug, Clone)]
pub struct ElasticScenario {
    /// Processor name ("kmeans" | "gridrec" | "mlem").
    pub processor: String,
    /// Offered input rate over virtual time, msgs/sec.
    pub schedule: RateSchedule,
    /// Micro-batch window (paper §6.4: 60 s).
    pub window_secs: f64,
    /// Number of windows to simulate.
    pub windows: usize,
    pub broker_nodes: usize,
    /// Partitions per broker node (paper: 12).
    pub partitions_per_node: usize,
    /// Fleet floor (the base pilot's processing nodes).
    pub min_nodes: usize,
    /// Fleet ceiling (paper max scale: 32).
    pub max_nodes: usize,
    pub initial_nodes: usize,
    /// Virtual seconds between a scale-up decision and the new nodes
    /// becoming usable (batch queue wait + framework extension).
    pub provision_delay_secs: f64,
    /// Virtual seconds between a repartition decision and the new
    /// partition set serving (metadata propagation + the consumer
    /// group draining the old epoch — `broker::repartition`'s
    /// drain-before-serve fence, in virtual time).
    pub repartition_delay_secs: f64,
    /// Ceiling on the partition count a `Repartition` decision can
    /// request.
    pub max_partitions: usize,
    /// Modeled topic replication factor for fault injection (1 = no
    /// replication; a node death exposes every partition).
    pub replication_factor: usize,
    /// Opt-in fault injection: window index at which one broker node
    /// dies.  Partitions with a replica on the dead node fail over
    /// (leaders move; no acked data is lost under replication) but run
    /// *degraded* — fewer in-sync replicas than the factor — until a
    /// replacement broker lands, which is exactly the window the
    /// planner's replication-repair branch exists to close.
    pub node_death_window: Option<usize>,
    /// Ack discipline the modeled producers run (the `acks=` analog):
    /// under [`AckMode::Quorum`] a failover loses nothing because every
    /// ack waited for the in-sync followers; under [`AckMode::Leader`]
    /// a dead leader's async followers trail by `replica_lag_records`,
    /// and that tail is lost on promotion (unclean accounting).
    pub ack_mode: AckMode,
    /// Modeled steady-state follower lag, records per partition — how
    /// far an async follower trails its leader at the moment the death
    /// strikes.  Only meaningful with `replication_factor > 1`.
    pub replica_lag_records: f64,
    /// Failure domains the broker tier is spread over (0 = unracked;
    /// rack fault injection needs >= 2).
    pub racks: usize,
    /// Opt-in fault injection: window index at which one whole rack —
    /// `broker_nodes / racks` brokers at once — dies.  The bounced
    /// brokers re-join two windows later with divergent tails truncated
    /// (the real tier's `rejoin_broker`), but they return *empty of
    /// replicas*: every affected set is crowded onto the surviving
    /// domain, and only a `ReassignReplicas` plan step (the planner
    /// path) heals that skew — the legacy intent path carries it to the
    /// end of the run.
    pub rack_death_window: Option<usize>,
}

impl ElasticScenario {
    /// The ROADMAP's calibrated-scale scenario: Rust-speed processor
    /// costs (use with [`CostModel::calibrated_default`]) need offered
    /// rates ~100x the paper era before anything saturates.  The burst
    /// demands more executor cores than the initial 48 partitions can
    /// feed (the §6.4 knee sits at 24 nodes x 2 executors), so only a
    /// partition-elastic policy can track it all the way to the 32-node
    /// ceiling.
    pub fn calibrated_burst(window_secs: f64) -> Self {
        ElasticScenario {
            processor: "gridrec".into(),
            // 150 msg/s base (half the 2-node floor's capacity), a
            // 3000 msg/s burst for 10 windows: serving it needs 30
            // nodes = 60 cores > 48 partitions.
            schedule: RateSchedule::bursty(150.0, 3000.0, 20.0 * window_secs, 10.0 * window_secs),
            window_secs,
            windows: 60,
            broker_nodes: 4,
            partitions_per_node: 12,
            min_nodes: 2,
            max_nodes: 32,
            initial_nodes: 2,
            provision_delay_secs: 1.5 * window_secs,
            repartition_delay_secs: window_secs,
            max_partitions: 128,
            replication_factor: 1,
            node_death_window: None,
            ack_mode: AckMode::Leader,
            replica_lag_records: 0.0,
            racks: 0,
            rack_death_window: None,
        }
    }

    /// The rack-failover scenario (`exp elastic --preset rackfail`):
    /// calibrated costs, a steady in-capacity rate (so every intent is
    /// Hold and the timeline shows only the fault lifecycle), a
    /// 2-rack/4-broker tier, and the loss of a whole rack at window 5.
    /// Under the default `Leader` acks the death loses the promoted
    /// followers' gap and the re-join truncates the same gap off the
    /// returning brokers; flip to `AckMode::Quorum` and both are zero.
    pub fn calibrated_rackfail(window_secs: f64) -> Self {
        ElasticScenario {
            processor: "gridrec".into(),
            schedule: RateSchedule::constant(150.0),
            window_secs,
            windows: 30,
            broker_nodes: 4,
            partitions_per_node: 12,
            min_nodes: 2,
            max_nodes: 32,
            initial_nodes: 2,
            provision_delay_secs: 1.5 * window_secs,
            repartition_delay_secs: window_secs,
            max_partitions: 128,
            replication_factor: 2,
            node_death_window: None,
            ack_mode: AckMode::Leader,
            replica_lag_records: 50.0,
            racks: 2,
            rack_death_window: Some(5),
        }
    }
}

/// Per-window trace row.
#[derive(Debug, Clone)]
pub struct ElasticWindow {
    pub t_secs: f64,
    /// Offered rate during this window, msgs/sec.
    pub input_rate: f64,
    /// Usable processing nodes during this window.
    pub nodes: usize,
    /// Topic partition count during this window (the task-parallelism
    /// cap; moves when the policy repartitions).
    pub partitions: usize,
    /// Broker-tier nodes during this window (moves when a plan
    /// co-schedules a broker extension).
    pub broker_nodes: usize,
    /// Messages processed this window.
    pub processed: f64,
    /// Backlog (lag) at window end, messages.
    pub lag: f64,
    /// Node delta decided this window (+up / -down / 0).
    pub decision: i64,
    /// Did demand outrun capacity this window?
    pub behind: bool,
    /// Acked records lost this window (nonzero only at a failover
    /// whose promoted followers trailed the dead leader).
    pub lost: f64,
    /// Divergent records truncated off re-joining brokers this window
    /// (nonzero only at the window a rack bounce's re-join lands).
    pub truncated: f64,
    /// Follower replicas moved by a `ReassignReplicas` plan step
    /// actuated this window.
    pub reassigned: usize,
}

/// Aggregate result of an elastic run.
#[derive(Debug, Clone)]
pub struct ElasticSimResult {
    pub rows: Vec<ElasticWindow>,
    pub peak_nodes: usize,
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Repartition decisions actuated.
    pub repartitions: usize,
    /// Broker-extension plan steps actuated.
    pub broker_ups: usize,
    /// Broker nodes released once the fleet returned to its floor (the
    /// controller's release rule, mirrored: only capacity the partition
    /// count no longer needs within the per-node budget).
    pub broker_downs: usize,
    /// Largest broker-tier node count reached.
    pub peak_broker_nodes: usize,
    /// Scale-up intents the planner deferred on cost grounds.
    pub deferrals: usize,
    /// Broker-node deaths injected by the scenario.
    pub failovers: usize,
    /// Windows during which replication ran degraded (a dead replica
    /// not yet replaced).
    pub degraded_windows: usize,
    /// Acked records lost across every injected failover (the
    /// durability cost of `Leader` acks; zero under `Quorum`).
    pub lost_records: f64,
    /// Divergent records truncated off re-joining brokers (KIP-101
    /// accounting: equals the lost tail under `Leader` acks, zero under
    /// `Quorum`).
    pub truncated_records: f64,
    /// `ReassignReplicas` plan steps actuated (placement repair passes,
    /// not individual replica moves).
    pub reassignments: usize,
    /// Brokers that re-joined after a rack bounce.
    pub rejoins: usize,
    /// Largest partition count reached.
    pub peak_partitions: usize,
    pub final_lag: f64,
    pub behind_windows: usize,
    /// Node-seconds of footprint (the cost an elastic policy saves
    /// against static peak provisioning).
    pub node_secs: f64,
}

/// The elastic simulator.
pub struct ElasticSim {
    pub machine: SimMachine,
    pub costs: CostModel,
}

impl ElasticSim {
    pub fn new(machine: SimMachine, costs: CostModel) -> Self {
        ElasticSim { machine, costs }
    }

    /// Run `policy` through the scenario with its intents actuated
    /// directly (the pre-planner decision path); deterministic.
    pub fn run(&self, sc: &ElasticScenario, policy: &mut dyn ScalingPolicy) -> ElasticSimResult {
        self.run_inner(sc, policy, None)
    }

    /// Run `policy` with every intent routed through `planner`:
    /// cost-aware deferral/resizing, per-framework extension lead times
    /// added on top of the scenario's batch-queue delay, and a dynamic
    /// broker tier (`ExtendBroker` plan steps land after the broker
    /// framework's modeled extension cost); deterministic.
    pub fn run_planned(
        &self,
        sc: &ElasticScenario,
        policy: &mut dyn ScalingPolicy,
        planner: &Planner,
    ) -> ElasticSimResult {
        self.run_inner(sc, policy, Some(planner))
    }

    fn run_inner(
        &self,
        sc: &ElasticScenario,
        policy: &mut dyn ScalingPolicy,
        planner: Option<&Planner>,
    ) -> ElasticSimResult {
        let mut n_partitions = (sc.broker_nodes * sc.partitions_per_node).max(1);
        let proc_cost = self.costs.proc_cost(&sc.processor);
        let mut nodes = sc.initial_nodes.clamp(sc.min_nodes, sc.max_nodes);
        let mut broker_nodes = sc.broker_nodes.max(1);
        // Scale-ups in flight: (ready_at_secs, nodes).
        let mut pending: Vec<(f64, usize)> = Vec::new();
        // Broker extensions in flight: (ready_at_secs, nodes).
        let mut pending_broker: Vec<(f64, usize)> = Vec::new();
        // Repartition in flight: (ready_at_secs, new_partition_count).
        let mut pending_repartition: Option<(f64, usize)> = None;
        let mut backlog = vec![0.0f64; n_partitions];
        let mut prev_lag = 0.0f64;

        let mut rows = Vec::with_capacity(sc.windows);
        let mut peak_nodes = nodes;
        let mut scale_ups = 0;
        let mut scale_downs = 0;
        let mut repartitions = 0;
        let mut broker_ups = 0;
        let mut broker_downs = 0;
        let mut peak_broker_nodes = broker_nodes;
        let mut deferrals = 0;
        let mut failovers = 0;
        let mut degraded_windows = 0;
        let mut lost_records = 0.0f64;
        let mut truncated_records = 0.0f64;
        let mut reassignments = 0;
        let mut rejoins = 0;
        // Partitions currently running with fewer in-sync replicas than
        // the scenario's factor (nonzero only after a node death).
        let mut degraded = 0usize;
        // A bounced rack on its way back: (rejoin_window, brokers,
        // partitions the dead brokers led).
        let mut pending_rejoin: Option<(usize, usize, usize)> = None;
        // Placement debt: 1.0 from the window a rack bounce's re-join
        // lands (the returning brokers hold no replicas, so every
        // affected set is crowded onto the surviving domain) until a
        // ReassignReplicas step actuates.
        let mut rack_skew = 0.0f64;
        let mut peak_partitions = n_partitions;
        let mut behind_windows = 0;
        let mut node_secs = 0.0;

        for w in 0..sc.windows {
            let t = w as f64 * sc.window_secs;
            // Provisioned extensions come online.
            let mut arrived = 0;
            pending.retain(|(ready_at, n)| {
                if *ready_at <= t {
                    arrived += n;
                    false
                } else {
                    true
                }
            });
            nodes = (nodes + arrived).min(sc.max_nodes);
            peak_nodes = peak_nodes.max(nodes);
            node_secs += nodes as f64 * sc.window_secs;
            let mut broker_arrived = 0;
            pending_broker.retain(|(ready_at, n)| {
                if *ready_at <= t {
                    broker_arrived += n;
                    false
                } else {
                    true
                }
            });
            broker_nodes += broker_arrived;
            peak_broker_nodes = peak_broker_nodes.max(broker_nodes);
            // A broker landing heals every degraded replica set (the
            // real plane's `add_brokers` reassigns follower sets as the
            // node joins).
            if broker_arrived > 0 {
                degraded = 0;
            }
            // A bounced rack re-joins: the brokers return to the
            // membership with their divergent tails truncated to the
            // survivors' fence (the real tier's `rejoin_broker`), catch
            // up, and re-enter the ISR — which heals the degraded sets
            // but leaves every one of them crowded onto the surviving
            // domain until a reassignment pass re-spreads them.
            let mut truncated = 0.0f64;
            if let Some((ready_w, n, led)) = pending_rejoin {
                if ready_w <= w {
                    pending_rejoin = None;
                    broker_nodes += n;
                    rejoins += n;
                    truncated = match sc.ack_mode {
                        AckMode::Quorum => 0.0,
                        AckMode::Leader => sc.replica_lag_records * led as f64,
                    };
                    truncated_records += truncated;
                    degraded = 0;
                    rack_skew = 1.0;
                }
            }
            // Fault injection: a whole failure domain dies this window
            // — `broker_nodes / racks` brokers at once.  Accounting
            // mirrors the single-node death below, scaled by the
            // domain size; the bounced brokers re-join two windows
            // later (the maintenance reboot the rack model assumes).
            let mut lost = 0.0f64;
            if sc.rack_death_window == Some(w) && sc.racks > 0 && broker_nodes > 1 {
                let before = broker_nodes;
                let dead = before.div_ceil(sc.racks).min(before - 1);
                broker_nodes -= dead;
                failovers += dead;
                let led = (n_partitions * dead).div_ceil(before).min(n_partitions);
                degraded = if sc.replication_factor > 1 {
                    lost = match sc.ack_mode {
                        AckMode::Quorum => 0.0,
                        AckMode::Leader => sc.replica_lag_records * led as f64,
                    };
                    (n_partitions * sc.replication_factor * dead)
                        .div_ceil(before)
                        .min(n_partitions)
                } else {
                    lost = backlog.iter().take(led).sum();
                    n_partitions
                };
                lost_records += lost;
                pending_rejoin = Some((w + 2, dead, led));
            }
            // Fault injection: one broker node dies this window.  The
            // affected partitions fail over to surviving replicas;
            // until a replacement lands they run with fewer in-sync
            // replicas than the factor.
            if sc.node_death_window == Some(w) && broker_nodes > 1 {
                let before = broker_nodes;
                broker_nodes -= 1;
                failovers += 1;
                // The dead node led ~1/before of the partitions; what
                // happens to their tail depends on the ack discipline.
                let led = n_partitions.div_ceil(before).min(n_partitions);
                let node_lost;
                degraded = if sc.replication_factor > 1 {
                    // Each node hosts ~factor/before of the replica
                    // slots; those partitions lost one replica.
                    node_lost = match sc.ack_mode {
                        // Quorum acks waited for the in-sync
                        // followers, so the promoted replica holds
                        // every acked record.
                        AckMode::Quorum => 0.0,
                        // Leader acks returned before the async
                        // followers applied: each promoted follower
                        // trails by the modeled lag, and that tail is
                        // gone (the real tier's unclean-election
                        // accounting, in virtual time).
                        AckMode::Leader => sc.replica_lag_records * led as f64,
                    };
                    (n_partitions * sc.replication_factor).div_ceil(before).min(n_partitions)
                } else {
                    // Unreplicated: the dead node's partitions have no
                    // follower to promote — their whole backlog is
                    // exposed regardless of ack mode.  (Accounting
                    // only: the backlog itself stays, modeling sources
                    // replaying into the rebuilt tier.)
                    node_lost = backlog.iter().take(led).sum();
                    n_partitions
                };
                lost += node_lost;
                lost_records += node_lost;
            }
            if degraded > 0 {
                degraded_windows += 1;
            }
            // Mirror the controller's broker-release rule: once the
            // fleet is back at its floor with nothing in flight,
            // saturation-driven broker extensions are released — but
            // only down to what the (persistent) partition count still
            // needs within the per-node I/O budget.
            if let Some(planner) = planner {
                if nodes <= sc.min_nodes
                    && pending.is_empty()
                    && pending_broker.is_empty()
                    && pending_repartition.is_none()
                {
                    let budget = planner.config().partitions_per_broker_node.max(1);
                    let needed = n_partitions.div_ceil(budget).max(sc.broker_nodes.max(1));
                    if broker_nodes > needed {
                        broker_downs += broker_nodes - needed;
                        broker_nodes = needed;
                    }
                }
            }

            // A decided repartition takes effect once its delay (the
            // old epoch's drain) elapses: grow appends empty partitions;
            // shrink folds the retired suffix's backlog into the
            // remaining set (the drain of the old epoch).
            if let Some((ready_at, new_count)) = pending_repartition {
                if ready_at <= t {
                    pending_repartition = None;
                    if new_count > n_partitions {
                        backlog.resize(new_count, 0.0);
                    } else if new_count < n_partitions {
                        let retired: f64 = backlog[new_count..].iter().sum();
                        backlog.truncate(new_count);
                        for b in backlog.iter_mut() {
                            *b += retired / new_count as f64;
                        }
                    }
                    n_partitions = new_count;
                    peak_partitions = peak_partitions.max(n_partitions);
                }
            }

            // Offered load arrives spread over the partitions.
            let input_rate = sc.schedule.rate_at(t);
            let arrivals = input_rate * sc.window_secs / n_partitions as f64;
            for b in backlog.iter_mut() {
                *b += arrivals;
            }
            let total_backlog: f64 = backlog.iter().sum();

            // One task per partition, one core per task: capacity is
            // bounded by both the core count and partition parallelism.
            let cores = nodes * self.machine.executors_per_node;
            let parallel = cores.min(n_partitions);
            let capacity = parallel as f64 * (sc.window_secs / proc_cost);
            let processed = capacity.min(total_backlog);
            if total_backlog > 0.0 {
                let frac = processed / total_backlog;
                for b in backlog.iter_mut() {
                    *b -= *b * frac;
                }
            }
            let lag: f64 = backlog.iter().sum();
            let behind = total_backlog > capacity;
            if behind {
                behind_windows += 1;
            }

            // Build the same snapshot shape the live probe produces.
            let pending_nodes: usize = pending.iter().map(|(_, n)| n).sum();
            let per_node_rate = if nodes > 0 {
                (parallel as f64 / nodes as f64) / proc_cost
            } else {
                0.0
            };
            let snapshot = SignalSnapshot {
                t_secs: t + sc.window_secs,
                lag: lag.round() as u64,
                lag_slope: (lag - prev_lag) / sc.window_secs,
                produce_rate: input_rate,
                consume_rate: processed / sc.window_secs,
                partition_backlog: backlog.iter().map(|b| b.round() as u64).collect(),
                // Like nodes below, an in-flight repartition counts as
                // present so the policy doesn't re-request it.
                partitions: pending_repartition.map(|(_, n)| n).unwrap_or(n_partitions),
                behind_batches: behind_windows as u64,
                last_batch_secs: if capacity > 0.0 {
                    sc.window_secs * (total_backlog / capacity).min(4.0)
                } else {
                    0.0
                },
                window_secs: sc.window_secs,
                // Policies must not double-scale for nodes already on
                // their way: in-flight extensions count as present.
                nodes: (nodes + pending_nodes).min(sc.max_nodes),
                min_nodes: sc.min_nodes,
                max_nodes: sc.max_nodes,
                service_rate_per_node: per_node_rate,
                // A broker extension on its way counts as present so
                // the planner doesn't re-request it every window.
                broker_nodes: broker_nodes + pending_broker.iter().map(|(_, n)| n).sum::<usize>(),
                // The elastic model tracks messages, not bytes; broker
                // pressure enters through the planner's per-node
                // partition budgets rather than live byte gauges.
                broker_nic_util: 0.0,
                broker_disk_util: 0.0,
                // Like the node counts above, a replacement broker on
                // its way counts as healing so the planner's repair
                // branch doesn't buy another node every window.  The
                // sim models factor == min_insync, so a dead replica is
                // both under-replicated and quorum-degraded.
                // A bounced rack counts as a replacement in flight for
                // the same reason: the planner must not buy a node for
                // brokers the maintenance model already returns.
                under_replicated: if pending_broker.is_empty() && pending_rejoin.is_none() {
                    degraded
                } else {
                    0
                },
                below_min_insync: if pending_broker.is_empty() && pending_rejoin.is_none() {
                    degraded
                } else {
                    0
                },
                // The message-level model has no per-broker byte
                // gauges, so load skew never fires here; placement
                // skew follows the rack-bounce lifecycle above.
                broker_util_skew: 0.0,
                rack_skew,
                shard_queue_depths: Vec::new(),
                edge_lags: Vec::new(),
            };
            prev_lag = lag;

            // The fleet that actually processed this window; a
            // scale-down decided below takes effect afterwards.
            let nodes_used = nodes;
            let partitions_used = n_partitions;
            let broker_nodes_used = broker_nodes;
            let mut decision = 0i64;
            let mut reassigned = 0usize;
            let headroom = sc.max_nodes - (nodes + pending_nodes).min(sc.max_nodes);
            let provision_at = t + sc.window_secs + sc.provision_delay_secs;
            let intent = policy.decide(&snapshot);
            match planner {
                // Plan-aware path: cost the intent, then execute the
                // plan's steps with per-framework lead times.
                Some(planner) => {
                    let plan = planner.plan(intent, &snapshot);
                    if plan.deferred.is_some() {
                        deferrals += 1;
                    }
                    for step in &plan.steps {
                        match *step {
                            PlanStep::ExtendBroker { nodes: n, cost } => {
                                // Broker joins skip the batch queue
                                // (the broker pilot already holds its
                                // allocation request path); they pay
                                // the framework's extension cost.
                                pending_broker.push((t + sc.window_secs + cost.lead_secs, n));
                                broker_ups += 1;
                            }
                            PlanStep::Repartition { partitions, .. } => {
                                let target = partitions.min(sc.max_partitions).max(1);
                                if pending_repartition.is_none() && target != n_partitions {
                                    pending_repartition = Some((
                                        t + sc.window_secs + sc.repartition_delay_secs,
                                        target,
                                    ));
                                    repartitions += 1;
                                }
                            }
                            PlanStep::ExtendProcessing { nodes: n, cost } => {
                                // Batch-queue delay plus the planner's
                                // per-framework extension lead.
                                let n = n.min(headroom);
                                if n > 0 {
                                    pending.push((provision_at + cost.lead_secs, n));
                                    scale_ups += 1;
                                    decision = n as i64;
                                }
                            }
                            PlanStep::ShrinkProcessing { nodes: n } => {
                                let n = n.min(nodes.saturating_sub(sc.min_nodes));
                                if n > 0 {
                                    nodes -= n;
                                    scale_downs += 1;
                                    decision = -(n as i64);
                                }
                            }
                            PlanStep::ReassignReplicas { moves, .. } => {
                                // Placement repair: a metadata pass on
                                // the existing tier, immediate in the
                                // window model.  The skew it undoes is
                                // exactly the rack-bounce debt above.
                                if rack_skew > 0.0 {
                                    rack_skew = 0.0;
                                    reassignments += 1;
                                    reassigned = moves;
                                }
                            }
                        }
                    }
                }
                // Legacy path: actuate the raw intent with the
                // scenario's flat provisioning delay.
                None => match intent {
                    ScalingIntent::Hold => {}
                    ScalingIntent::ScaleUp(n) => {
                        let n = n.min(headroom);
                        if n > 0 {
                            pending.push((provision_at, n));
                            scale_ups += 1;
                            decision = n as i64;
                        }
                    }
                    ScalingIntent::Repartition { partitions, scale_up } => {
                        let target = partitions.min(sc.max_partitions).max(1);
                        if pending_repartition.is_none() && target != n_partitions {
                            pending_repartition =
                                Some((t + sc.window_secs + sc.repartition_delay_secs, target));
                            repartitions += 1;
                        }
                        let n = scale_up.min(headroom);
                        if n > 0 {
                            pending.push((provision_at, n));
                            scale_ups += 1;
                            decision = n as i64;
                        }
                    }
                    ScalingIntent::ScaleDown(n) => {
                        // Shrinking is immediate (stop an extension pilot).
                        let n = n.min(nodes.saturating_sub(sc.min_nodes));
                        if n > 0 {
                            nodes -= n;
                            scale_downs += 1;
                            decision = -(n as i64);
                        }
                    }
                },
            }

            rows.push(ElasticWindow {
                t_secs: t,
                input_rate,
                nodes: nodes_used,
                partitions: partitions_used,
                broker_nodes: broker_nodes_used,
                processed,
                lag,
                decision,
                behind,
                lost,
                truncated,
                reassigned,
            });
        }

        ElasticSimResult {
            peak_nodes,
            scale_ups,
            scale_downs,
            repartitions,
            broker_ups,
            broker_downs,
            peak_broker_nodes,
            deferrals,
            failovers,
            degraded_windows,
            lost_records,
            truncated_records,
            reassignments,
            rejoins,
            peak_partitions,
            final_lag: prev_lag,
            behind_windows,
            node_secs,
            rows,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::{BinPackingPolicy, ThresholdPolicy};

    /// Paper-scale burst: 60 s windows, 4 brokers (48 partitions), a
    /// 10x input burst in the middle of the run.  Heavy reconstruction
    /// executors (2 slots per node, memory-bound GridRec) keep executor
    /// cores below the partition count up to 24 nodes, so the elastic
    /// regime spans most of the 32-node machine (§6.4's knee).
    fn sim() -> ElasticSim {
        let machine = SimMachine {
            executors_per_node: 2,
            ..Default::default()
        };
        ElasticSim::new(machine, CostModel::paper_era())
    }

    fn burst_scenario() -> ElasticScenario {
        ElasticScenario {
            processor: "gridrec".into(),
            schedule: RateSchedule::bursty(4.0, 40.0, 20.0 * 60.0, 10.0 * 60.0),
            window_secs: 60.0,
            windows: 60,
            broker_nodes: 4,
            partitions_per_node: 12,
            min_nodes: 2,
            max_nodes: 32,
            initial_nodes: 2,
            provision_delay_secs: 90.0,
            repartition_delay_secs: 60.0,
            max_partitions: 128,
            replication_factor: 1,
            node_death_window: None,
            ack_mode: AckMode::Leader,
            replica_lag_records: 0.0,
            racks: 0,
            rack_death_window: None,
        }
    }

    fn threshold() -> ThresholdPolicy {
        ThresholdPolicy::new(600, 60)
            .with_sustain(1)
            .with_cooldown_secs(120.0)
            .with_step(8)
    }

    #[test]
    fn burst_drives_scale_up_then_recovery() {
        let sim = sim();
        let mut policy = threshold();
        let res = sim.run(&burst_scenario(), &mut policy);
        assert!(res.scale_ups >= 1, "burst must trigger growth");
        assert!(res.scale_downs >= 1, "recovery must shrink back");
        assert!(res.peak_nodes > 2 && res.peak_nodes <= 32, "peak {}", res.peak_nodes);
        assert!(res.final_lag < 60.0, "final lag {} not drained", res.final_lag);
        // The footprint must end back at the floor.
        assert_eq!(res.rows.last().unwrap().nodes, 2);
        // Elasticity beats static peak provisioning on node-seconds.
        let static_peak = res.peak_nodes as f64 * 60.0 * 60.0;
        assert!(res.node_secs < static_peak, "{} !< {static_peak}", res.node_secs);
    }

    #[test]
    fn steady_load_within_capacity_never_scales() {
        let sim = sim();
        let mut sc = burst_scenario();
        sc.schedule = RateSchedule::constant(8.0);
        let mut policy = threshold();
        let res = sim.run(&sc, &mut policy);
        assert_eq!(res.scale_ups, 0);
        assert_eq!(res.scale_downs, 0);
        assert_eq!(res.peak_nodes, 2);
        assert_eq!(res.behind_windows, 0);
    }

    #[test]
    fn runs_are_deterministic() {
        let sim = sim();
        let sc = burst_scenario();
        let run = |sc: &ElasticScenario| {
            let mut policy = threshold();
            let res = sim.run(sc, &mut policy);
            res.rows
                .iter()
                .map(|r| (r.nodes, r.partitions, r.decision, r.lag.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(&sc), run(&sc));
    }

    #[test]
    fn bin_packing_tracks_burst_at_scale() {
        let sim = sim();
        let mut policy = BinPackingPolicy::new().with_cooldown_secs(120.0);
        let res = sim.run(&burst_scenario(), &mut policy);
        assert!(res.scale_ups >= 1);
        assert!(res.peak_nodes <= 32);
        assert!(res.rows.last().unwrap().nodes <= 4, "packed back down");
    }

    #[test]
    fn calibrated_burst_knee_moves_with_partition_elastic_policy() {
        use crate::autoscale::PartitionElastic;

        // Rust-speed costs: the ROADMAP's calibrated-scale scenario.
        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_burst(60.0);
        let knee_cores = sc.broker_nodes * sc.partitions_per_node; // 48
        let per_core_window = sc.window_secs / CostModel::calibrated_default().proc_cost("gridrec");

        // Without partition elasticity the knee caps useful capacity:
        // no window can process more than 48 cores' worth.
        let capped = sim.run(&sc, &mut calibrated_threshold());
        assert_eq!(capped.repartitions, 0);
        assert_eq!(capped.peak_partitions, knee_cores);
        for r in &capped.rows {
            assert!(
                r.processed <= knee_cores as f64 * per_core_window + 1e-6,
                "window t={} processed {} past the knee",
                r.t_secs,
                r.processed
            );
        }

        // Wrapped in PartitionElastic, the same inner policy moves the
        // cap: at least one repartition fires and at least one window
        // processes more than 48 cores ever could.
        let mut elastic = PartitionElastic::new(calibrated_threshold(), 2);
        let res = sim.run(&sc, &mut elastic);
        assert!(res.repartitions >= 1, "no repartition fired");
        assert!(res.peak_partitions > knee_cores, "cap never moved");
        assert!(
            res.peak_nodes > knee_cores / 2,
            "fleet stuck at the knee: peak {}",
            res.peak_nodes
        );
        assert!(
            res.rows
                .iter()
                .any(|r| r.processed > knee_cores as f64 * per_core_window + 1.0),
            "no window outran the one-task-per-partition cap"
        );
        // The burst still drains and the footprint returns to the floor.
        assert!(res.final_lag < 2_000.0, "final lag {}", res.final_lag);
        assert_eq!(res.rows.last().unwrap().nodes, sc.min_nodes);
    }

    /// Threshold tuning for calibrated-scale rates (msgs are ~100x the
    /// paper era's).
    fn calibrated_threshold() -> ThresholdPolicy {
        ThresholdPolicy::new(20_000, 2_000)
            .with_sustain(1)
            .with_cooldown_secs(120.0)
            .with_step(8)
    }

    #[test]
    fn calibrated_burst_timeline_is_deterministic() {
        use crate::autoscale::PartitionElastic;

        // Regression pin: the calibrated scenario's scaling timeline —
        // every (window, nodes, partitions, decision) tuple — must be
        // byte-identical across runs, so policy or cost drift shows up
        // as a diff here rather than as silent behavior change.
        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_burst(60.0);
        let run = || {
            let mut policy = PartitionElastic::new(calibrated_threshold(), 2);
            let res = sim.run(&sc, &mut policy);
            (
                res.rows
                    .iter()
                    .map(|r| (r.nodes, r.partitions, r.decision, r.lag.to_bits()))
                    .collect::<Vec<_>>(),
                res.repartitions,
                res.peak_partitions,
                res.peak_nodes,
            )
        };
        let a = run();
        assert_eq!(a, run());
        // Structural pins on the timeline shape: the repartition
        // happens during the burst, after which the partition count on
        // the rows strictly exceeds the initial 48.
        let rows_partitions: Vec<usize> = a.0.iter().map(|r| r.1).collect();
        let first_grown = rows_partitions.iter().position(|p| *p > 48);
        assert!(first_grown.is_some(), "partition count never grew");
        assert!(
            first_grown.unwrap() >= 20,
            "repartition before the burst started"
        );
        assert!(rows_partitions.iter().all(|p| *p >= 48 && *p <= 128));
    }

    /// The tentpole scenario: routed through the planner, the
    /// calibrated burst's repartitions oversubscribe the 12-partition
    /// per-broker-node I/O budget, so the plans co-schedule broker
    /// extensions — and the partition count never outruns the budget of
    /// the (extended) broker tier.
    #[test]
    fn planned_calibrated_burst_coschedules_broker_extension() {
        use crate::autoscale::{PartitionElastic, Planner, PlannerConfig};

        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_burst(60.0);
        let planner = Planner::new(
            PlannerConfig::default()
                .with_max_step(8)
                .with_drain_horizon_secs(6.0 * sc.window_secs)
                .with_partitions_per_broker_node(sc.partitions_per_node)
                .with_max_broker_step(2),
        );
        let mut policy = PartitionElastic::new(calibrated_threshold(), 2);
        let res = sim.run_planned(&sc, &mut policy, &planner);

        assert!(res.repartitions >= 1, "no repartition fired");
        assert!(res.peak_partitions > 48, "cap never moved");
        assert!(
            res.broker_ups >= 1,
            "repartition past the 48-partition budget must bring brokers"
        );
        assert!(res.peak_broker_nodes > sc.broker_nodes, "broker tier never grew");
        assert!(
            res.peak_partitions <= res.peak_broker_nodes * sc.partitions_per_node,
            "partitions {} oversubscribe {} brokers x {} budget",
            res.peak_partitions,
            res.peak_broker_nodes,
            sc.partitions_per_node
        );
        // The knee still moves and the burst still drains to the floor.
        assert!(res.peak_nodes > 24, "fleet stuck at the knee: {}", res.peak_nodes);
        assert!(res.final_lag < 2_000.0, "final lag {}", res.final_lag);
        assert_eq!(res.rows.last().unwrap().nodes, sc.min_nodes);
        // Broker growth is visible on the per-window rows.
        assert_eq!(res.rows[0].broker_nodes, sc.broker_nodes);
        assert!(res.rows.iter().any(|r| r.broker_nodes > sc.broker_nodes));
    }

    /// Fault injection meets the planner's replication-repair branch: a
    /// broker node dies before the burst, the affected partitions run
    /// degraded, and the very next Hold intent becomes a
    /// broker-replacement plan whose landing heals the tier — windows
    /// degraded is bounded by the replacement's extension lead, not the
    /// run length.
    #[test]
    fn node_death_heals_via_planned_broker_replacement() {
        use crate::autoscale::{PartitionElastic, Planner, PlannerConfig};

        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let mut sc = ElasticScenario::calibrated_burst(60.0);
        sc.replication_factor = 2;
        sc.node_death_window = Some(5); // quiet pre-burst window: intent is Hold
        let planner = Planner::new(
            PlannerConfig::default()
                .with_max_step(8)
                .with_drain_horizon_secs(6.0 * sc.window_secs)
                .with_partitions_per_broker_node(sc.partitions_per_node)
                .with_max_broker_step(2),
        );
        let mut policy = PartitionElastic::new(calibrated_threshold(), 2);
        let res = sim.run_planned(&sc, &mut policy, &planner);

        assert_eq!(res.failovers, 1);
        assert!(res.degraded_windows >= 1, "the death never degraded the tier");
        // Kafka replacement lead is 23 s on top of one 60 s window:
        // healed within 2 windows, not the remaining 55.
        assert!(
            res.degraded_windows <= 3,
            "replacement never landed: {} degraded windows",
            res.degraded_windows
        );
        // Exactly one repair purchase (in-flight replacement counts as
        // healing, so the planner does not re-buy every window) — any
        // further broker growth comes from the burst's repartitions.
        assert!(res.broker_ups >= 1);
        // The burst is still tracked and drained afterwards.
        assert!(res.final_lag < 2_000.0, "final lag {}", res.final_lag);
        assert_eq!(res.rows.last().unwrap().nodes, sc.min_nodes);

        // Without the planner (legacy intent path) nothing repairs the
        // tier: replication stays degraded for the rest of the run.
        let mut policy = PartitionElastic::new(calibrated_threshold(), 2);
        let unplanned = sim.run(&sc, &mut policy);
        assert_eq!(unplanned.failovers, 1);
        assert!(
            unplanned.degraded_windows > res.degraded_windows,
            "unplanned {} !> planned {}",
            unplanned.degraded_windows,
            res.degraded_windows
        );
    }

    /// The durability side of the ack-mode trade, in virtual time:
    /// with async followers trailing by a modeled lag, killing a
    /// broker under `Leader` acks loses exactly the promoted
    /// followers' gap, while `Quorum` acks lose nothing — mirroring
    /// the real tier's unclean-election accounting deterministically.
    #[test]
    fn ack_mode_trades_durability_on_node_death() {
        let sim = sim();
        let mut sc = burst_scenario();
        sc.replication_factor = 2;
        sc.node_death_window = Some(5);
        sc.replica_lag_records = 50.0;

        sc.ack_mode = AckMode::Leader;
        let leader = sim.run(&sc, &mut threshold());
        assert_eq!(leader.failovers, 1);
        // 48 partitions over 4 brokers: the victim led 12, and each
        // promoted follower trailed by the modeled 50 records.
        assert_eq!(leader.lost_records, 600.0);
        assert_eq!(leader.rows[5].lost, 600.0);
        assert!(leader.rows.iter().enumerate().all(|(w, r)| w == 5 || r.lost == 0.0));

        sc.ack_mode = AckMode::Quorum;
        let quorum = sim.run(&sc, &mut threshold());
        assert_eq!(quorum.failovers, 1);
        assert_eq!(quorum.lost_records, 0.0);
        assert!(quorum.rows.iter().all(|r| r.lost == 0.0));

        // Unreplicated, the dead node's partitions have no follower:
        // their whole mid-burst backlog is exposed under either mode.
        sc.replication_factor = 1;
        sc.node_death_window = Some(21); // one window into the burst
        let exposed = sim.run(&sc, &mut threshold());
        assert_eq!(exposed.failovers, 1);
        assert!(exposed.lost_records > 0.0, "no backlog exposed");
    }

    /// The rack-failover lifecycle end to end: a whole domain dies,
    /// bounces back two windows later with its divergent tails
    /// truncated, and the planner's reassignment step — not a broker
    /// purchase — clears the placement debt the bounce left behind.
    #[test]
    fn rack_bounce_truncates_tails_and_reassignment_heals_the_skew() {
        use crate::autoscale::{Planner, PlannerConfig};

        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_rackfail(60.0);
        let planner = Planner::new(
            PlannerConfig::default()
                .with_max_step(8)
                .with_partitions_per_broker_node(sc.partitions_per_node)
                .with_max_broker_step(2),
        );
        let res = sim.run_planned(&sc, &mut calibrated_threshold(), &planner);

        // 2 racks x 4 brokers: the domain took 2 nodes, both returned.
        assert_eq!(res.failovers, 2);
        assert_eq!(res.rejoins, 2);
        // The dead brokers led 24 of the 48 partitions; under Leader
        // acks each promoted follower trailed by 50 records, and the
        // re-join truncates exactly the tail the failover lost.
        assert_eq!(res.lost_records, 1200.0);
        assert_eq!(res.truncated_records, res.lost_records);
        assert_eq!(res.rows[5].lost, 1200.0);
        assert_eq!(res.rows[5].broker_nodes, 2, "the domain is gone for the window");
        assert_eq!(res.rows[7].truncated, 1200.0, "re-join lands two windows later");
        assert_eq!(res.rows[7].broker_nodes, 4, "the bounced brokers are back");
        // Degraded only while the rack was down — the re-join heals it.
        assert_eq!(res.degraded_windows, 2);
        // Placement repair, not a purchase: the skew the bounce left is
        // cleared by one reassignment pass and the tier never grew.
        assert_eq!(res.reassignments, 1);
        assert_eq!(res.rows[7].reassigned, 48, "every crowded partition re-spread");
        assert_eq!(res.broker_ups, 0, "a bounce must not buy brokers");
        assert_eq!(res.peak_broker_nodes, sc.broker_nodes);
        assert_eq!(res.scale_ups, 0, "steady load: the fault is the only story");

        // Quorum acks close the durability hole: nothing lost, nothing
        // to truncate — but the placement debt (and its repair) remain.
        let mut quorum = sc.clone();
        quorum.ack_mode = AckMode::Quorum;
        let res = sim.run_planned(&quorum, &mut calibrated_threshold(), &planner);
        assert_eq!(res.lost_records, 0.0);
        assert_eq!(res.truncated_records, 0.0);
        assert_eq!(res.rejoins, 2);
        assert_eq!(res.reassignments, 1);

        // The legacy intent path has no reassignment step: the bounce
        // still truncates, but the crowding is never repaired.
        let res = sim.run(&sc, &mut calibrated_threshold());
        assert_eq!(res.rejoins, 2);
        assert_eq!(res.truncated_records, 1200.0);
        assert_eq!(res.reassignments, 0, "no planner, no placement repair");
    }

    #[test]
    fn planned_runs_are_deterministic() {
        use crate::autoscale::{PartitionElastic, Planner, PlannerConfig};

        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_burst(60.0);
        let run = || {
            let planner = Planner::new(
                PlannerConfig::default()
                    .with_max_step(8)
                    .with_drain_horizon_secs(6.0 * sc.window_secs)
                    .with_partitions_per_broker_node(sc.partitions_per_node)
                    .with_max_broker_step(2),
            );
            let mut policy = PartitionElastic::new(calibrated_threshold(), 2);
            let res = sim.run_planned(&sc, &mut policy, &planner);
            (
                res.rows
                    .iter()
                    .map(|r| (r.nodes, r.partitions, r.broker_nodes, r.decision, r.lag.to_bits()))
                    .collect::<Vec<_>>(),
                res.broker_ups,
                res.repartitions,
                res.deferrals,
            )
        };
        assert_eq!(run(), run());
    }

    /// Cost-aware deferral in virtual time: a drain horizon shorter
    /// than the framework's extension lead means no scale-up can ever
    /// pay for itself — the planner defers every one and the fleet
    /// stays at the floor (eating the lag instead of the cost).
    #[test]
    fn short_horizon_defers_every_scale_up() {
        use crate::autoscale::{Planner, PlannerConfig};

        let sim = ElasticSim::new(
            SimMachine {
                executors_per_node: 2,
                ..Default::default()
            },
            CostModel::calibrated_default(),
        );
        let sc = ElasticScenario::calibrated_burst(60.0);
        // Spark extension lead is >= 16 s; a 10 s horizon can never pay.
        let planner = Planner::new(
            PlannerConfig::default().with_max_step(8).with_drain_horizon_secs(10.0),
        );
        let mut policy = calibrated_threshold();
        let res = sim.run_planned(&sc, &mut policy, &planner);
        assert_eq!(res.scale_ups, 0, "a deferred scale-up was actuated");
        assert!(res.deferrals >= 1, "nothing was deferred");
        assert_eq!(res.peak_nodes, sc.initial_nodes);
        assert!(res.final_lag > 0.0, "the burst cannot drain at the floor");
    }

    #[test]
    fn provision_delay_defers_capacity() {
        let sim = sim();
        let mut fast = burst_scenario();
        fast.provision_delay_secs = 0.0;
        let mut slow = burst_scenario();
        slow.provision_delay_secs = 600.0;
        let r_fast = sim.run(&fast, &mut threshold());
        let r_slow = sim.run(&slow, &mut threshold());
        // Slower provisioning -> strictly more windows behind the rate.
        assert!(
            r_slow.behind_windows >= r_fast.behind_windows,
            "{} < {}",
            r_slow.behind_windows,
            r_fast.behind_windows
        );
    }
}
