//! Startup-time model for the Figure 6 experiment.
//!
//! Fig 6 reports Kafka / Spark / Dask cluster startup on Wrangler as a
//! function of node count, decomposed into (i) the batch job placement
//! and (ii) framework initialization.  The models below are the same
//! ones the live plugins use ([`crate::plugins::bootstrap_model_for`]),
//! so the simulated figure and the real coordinator can never drift
//! apart.

use crate::config::QueueModel;
use crate::pilot::FrameworkKind;
use crate::plugins::bootstrap_model_for;

/// One Fig 6 data point.
#[derive(Debug, Clone)]
pub struct StartupPoint {
    pub framework: FrameworkKind,
    pub nodes: usize,
    pub queue_wait_secs: f64,
    pub framework_init_secs: f64,
}

impl StartupPoint {
    pub fn total_secs(&self) -> f64 {
        self.queue_wait_secs + self.framework_init_secs
    }
}

/// Compute the startup grid for a set of frameworks and node counts.
pub fn startup_grid(
    frameworks: &[FrameworkKind],
    node_counts: &[usize],
    queue: QueueModel,
) -> Vec<StartupPoint> {
    let mut out = Vec::new();
    for &fw in frameworks {
        let model = bootstrap_model_for(fw);
        for &nodes in node_counts {
            out.push(StartupPoint {
                framework: fw,
                nodes,
                queue_wait_secs: queue.wait_secs(nodes),
                framework_init_secs: model.init_secs(nodes),
            });
        }
    }
    out
}

/// The paper's queue model for Wrangler (also used by SimSlurmAdaptor).
pub fn wrangler_queue() -> QueueModel {
    QueueModel {
        base_secs: 20.0,
        per_node_secs: 0.5,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_grid_shape() {
        let grid = startup_grid(
            &[FrameworkKind::Kafka, FrameworkKind::Spark, FrameworkKind::Dask],
            &[1, 2, 4, 8, 16, 32],
            wrangler_queue(),
        );
        assert_eq!(grid.len(), 18);
        // For every node count: Kafka > Spark > Dask total startup.
        for nodes in [1, 2, 4, 8, 16, 32] {
            let get = |fw: FrameworkKind| {
                grid.iter()
                    .find(|p| p.framework == fw && p.nodes == nodes)
                    .unwrap()
                    .total_secs()
            };
            assert!(get(FrameworkKind::Kafka) > get(FrameworkKind::Spark));
            assert!(get(FrameworkKind::Spark) > get(FrameworkKind::Dask));
        }
        // Monotone in node count.
        for fw in [FrameworkKind::Kafka, FrameworkKind::Spark, FrameworkKind::Dask] {
            let series: Vec<f64> = [1, 2, 4, 8, 16, 32]
                .iter()
                .map(|n| {
                    grid.iter()
                        .find(|p| p.framework == fw && p.nodes == *n)
                        .unwrap()
                        .total_secs()
                })
                .collect();
            for w in series.windows(2) {
                assert!(w[1] >= w[0], "{fw:?}: {series:?}");
            }
        }
    }

    #[test]
    fn startup_magnitudes_plausible_for_wrangler() {
        // Sanity: startups are minutes-scale, not hours or millis.
        let grid = startup_grid(&[FrameworkKind::Kafka], &[16], wrangler_queue());
        let total = grid[0].total_secs();
        assert!((60.0..600.0).contains(&total), "kafka@16 = {total}s");
    }
}
