//! Virtual-time pipeline simulators for the Figure 8/9 experiments.
//!
//! Both simulators model the paper's Wrangler deployment with timeline
//! resources ([`super::resources`]) and cost models ([`super::cost`]):
//!
//! * [`ProducerSim`] (Fig 8) — closed-loop MASS producers (8 per node)
//!   pushing padded messages through per-node egress NICs into broker
//!   ingress NICs + append logs (effective Kafka write bandwidth);
//! * [`ProcessingSim`] (Fig 9) — a micro-batch engine pulling from the
//!   broker (one task per partition, paper §6.4) onto executor cores,
//!   with per-message compute costs from the cost model.
//!
//! Saturation knees, broker-bound flatlines and the
//! more-nodes-don't-help regimes emerge from resource contention, not
//! from curve fitting.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::cost::CostModel;
use super::resources::{CoreBank, SerialResource};

/// Wrangler-like resource constants for the simulation plane.
#[derive(Debug, Clone, Copy)]
pub struct SimMachine {
    /// NIC bandwidth per node per direction, bytes/sec.
    pub nic_bps: f64,
    /// Effective Kafka log-append bandwidth per broker node, bytes/sec.
    /// Much lower than raw SSD speed: fsync, JVM and page-cache
    /// overheads — calibrated so 4 broker nodes sustain ≈ the paper's
    /// ~390 MB/s aggregate (§6.5).
    pub broker_append_bps: f64,
    /// Executor slots per processing node (Spark executor cores).
    pub executors_per_node: usize,
}

impl Default for SimMachine {
    fn default() -> Self {
        SimMachine {
            nic_bps: 1.25e9,          // 10 GbE
            broker_append_bps: 120e6, // effective Kafka append
            executors_per_node: 24,   // paper: 24-core Wrangler nodes
        }
    }
}

/// Fig 8 scenario description.
#[derive(Debug, Clone)]
pub struct ProducerScenario {
    /// MASS source name ("kmeans-random" | "kmeans-static" | "lightsource").
    pub source: String,
    pub msg_bytes: f64,
    pub producer_nodes: usize,
    pub producers_per_node: usize,
    pub broker_nodes: usize,
    /// Partitions per broker node (paper: 12).
    pub partitions_per_node: usize,
    /// Virtual seconds to simulate.
    pub duration_secs: f64,
}

/// Fig 8 result row.
#[derive(Debug, Clone)]
pub struct ProducerSimResult {
    pub messages: u64,
    pub msg_rate: f64,
    pub mb_rate: f64,
    /// Mean broker append utilization (saturation indicator).
    pub broker_util: f64,
    /// Mean producer-node egress utilization.
    pub producer_nic_util: f64,
}

/// Closed-loop producer simulation (Fig 8).
pub struct ProducerSim {
    pub machine: SimMachine,
    pub costs: CostModel,
}

impl ProducerSim {
    pub fn new(machine: SimMachine, costs: CostModel) -> Self {
        ProducerSim { machine, costs }
    }

    pub fn run(&self, sc: &ProducerScenario) -> ProducerSimResult {
        let n_producers = sc.producer_nodes * sc.producers_per_node;
        let n_partitions = sc.broker_nodes * sc.partitions_per_node;
        let gen = self.costs.gen_cost(&sc.source);
        let rtt = self.costs.ack_rtt_secs;

        let mut node_egress: Vec<SerialResource> = (0..sc.producer_nodes)
            .map(|_| SerialResource::new(self.machine.nic_bps))
            .collect();
        let mut broker_ingress: Vec<SerialResource> = (0..sc.broker_nodes)
            .map(|_| SerialResource::new(self.machine.nic_bps))
            .collect();
        let mut broker_append: Vec<SerialResource> = (0..sc.broker_nodes)
            .map(|_| SerialResource::new(self.machine.broker_append_bps))
            .collect();

        // Closed-loop producers: heap keyed by next-send time.
        #[derive(PartialEq)]
        struct Key(f64);
        impl Eq for Key {}
        impl PartialOrd for Key {
            fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for Key {
            fn cmp(&self, other: &Self) -> std::cmp::Ordering {
                self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
            }
        }

        let mut heap: BinaryHeap<Reverse<(Key, usize)>> = (0..n_producers)
            // Small deterministic stagger so producers don't phase-lock.
            .map(|p| Reverse((Key(p as f64 * gen / n_producers.max(1) as f64), p)))
            .collect();
        let mut seq: u64 = 0;
        let mut messages: u64 = 0;
        let mut last_done: f64 = 0.0;

        while let Some(Reverse((Key(t), p))) = heap.pop() {
            if t >= sc.duration_secs {
                continue; // producer's window closed
            }
            let node = p % sc.producer_nodes;
            // Round-robin partition choice -> leader broker.
            let partition = (seq as usize) % n_partitions;
            let broker = partition % sc.broker_nodes;
            seq += 1;

            let gen_done = t + gen;
            let out_done = node_egress[node].acquire(gen_done, sc.msg_bytes);
            let in_done = broker_ingress[broker].acquire(out_done, sc.msg_bytes);
            let append_done = broker_append[broker].acquire(in_done, sc.msg_bytes);
            let ack = append_done + rtt;
            messages += 1;
            last_done = last_done.max(ack);
            heap.push(Reverse((Key(ack), p)));
        }

        let horizon = last_done.max(sc.duration_secs);
        let broker_util = broker_append
            .iter()
            .map(|r| r.utilization(horizon))
            .sum::<f64>()
            / sc.broker_nodes as f64;
        let producer_nic_util = node_egress
            .iter()
            .map(|r| r.utilization(horizon))
            .sum::<f64>()
            / sc.producer_nodes as f64;
        ProducerSimResult {
            messages,
            msg_rate: messages as f64 / horizon,
            mb_rate: messages as f64 * sc.msg_bytes / 1e6 / horizon,
            broker_util,
            producer_nic_util,
        }
    }
}

/// Fig 9 scenario description.
#[derive(Debug, Clone)]
pub struct ProcessingScenario {
    /// Processor name ("kmeans" | "gridrec" | "mlem").
    pub processor: String,
    pub msg_bytes: f64,
    /// Input rate offered by the MASS producers, msgs/sec.
    pub input_rate: f64,
    pub processing_nodes: usize,
    pub broker_nodes: usize,
    pub partitions_per_node: usize,
    /// Micro-batch window (paper: 60 s).
    pub window_secs: f64,
    /// Number of windows to simulate.
    pub windows: usize,
}

/// Fig 9 result row.
#[derive(Debug, Clone)]
pub struct ProcessingSimResult {
    pub processed: u64,
    pub msg_rate: f64,
    pub mb_rate: f64,
    /// Mean executor-core utilization.
    pub core_util: f64,
    /// Mean broker egress utilization (read-side bottleneck indicator).
    pub broker_read_util: f64,
    /// Fraction of batches that outran the window (falling behind).
    pub behind_fraction: f64,
}

/// Micro-batch processing simulation (Fig 9).
pub struct ProcessingSim {
    pub machine: SimMachine,
    pub costs: CostModel,
}

impl ProcessingSim {
    pub fn new(machine: SimMachine, costs: CostModel) -> Self {
        ProcessingSim { machine, costs }
    }

    pub fn run(&self, sc: &ProcessingScenario) -> ProcessingSimResult {
        let n_partitions = sc.broker_nodes * sc.partitions_per_node;
        let proc_cost = self.costs.proc_cost(&sc.processor);
        let overhead = self.costs.task_overhead_secs;

        let mut broker_egress: Vec<SerialResource> = (0..sc.broker_nodes)
            .map(|_| SerialResource::new(self.machine.nic_bps))
            .collect();
        let mut node_ingress: Vec<SerialResource> = (0..sc.processing_nodes)
            .map(|_| SerialResource::new(self.machine.nic_bps))
            .collect();
        let mut cores = CoreBank::new(sc.processing_nodes * self.machine.executors_per_node);

        // Per-partition backlog (messages waiting in the broker).
        let mut backlog = vec![0.0f64; n_partitions];
        let per_partition_in = sc.input_rate * sc.window_secs / n_partitions as f64;

        let mut processed: u64 = 0;
        let mut behind = 0usize;
        let mut batch_free_at = 0.0f64; // drivers serialize batches
        let horizon = sc.window_secs * sc.windows as f64;

        for w in 0..sc.windows {
            let tick = w as f64 * sc.window_secs;
            // Input arrives continuously; credit this window's arrivals.
            for b in backlog.iter_mut() {
                *b += per_partition_in;
            }
            let start = tick.max(batch_free_at);
            let mut batch_done = start;
            // One task per partition (paper §6.4).
            for (p, b) in backlog.iter_mut().enumerate() {
                let msgs = b.floor();
                if msgs < 1.0 {
                    continue;
                }
                *b -= msgs;
                let broker = p % sc.broker_nodes;
                let node = p % sc.processing_nodes;
                let bytes = msgs * sc.msg_bytes;
                // Fetch: broker egress then node ingress.
                let fetched = node_ingress[node]
                    .acquire(broker_egress[broker].acquire(start, bytes), bytes);
                // Compute: task occupies one executor core.
                let done = cores.schedule(fetched, overhead + msgs * proc_cost);
                processed += msgs as u64;
                batch_done = batch_done.max(done);
            }
            let batch_secs = batch_done - start;
            if batch_secs > sc.window_secs {
                behind += 1;
            }
            batch_free_at = batch_done;
        }

        let total = batch_free_at.max(horizon);
        ProcessingSimResult {
            processed,
            msg_rate: processed as f64 / total,
            mb_rate: processed as f64 * sc.msg_bytes / 1e6 / total,
            core_util: cores.utilization(total),
            broker_read_util: broker_egress
                .iter()
                .map(|r| r.utilization(total))
                .sum::<f64>()
                / sc.broker_nodes as f64,
            behind_fraction: behind as f64 / sc.windows.max(1) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn producer_scenario(source: &str, pnodes: usize, brokers: usize) -> ProducerScenario {
        ProducerScenario {
            source: source.into(),
            msg_bytes: if source == "lightsource" { 2e6 } else { 0.32e6 },
            producer_nodes: pnodes,
            producers_per_node: 8,
            broker_nodes: brokers,
            partitions_per_node: 12,
            duration_secs: 60.0,
        }
    }

    #[test]
    fn fig8_static_beats_random_in_paper_era() {
        let sim = ProducerSim::new(SimMachine::default(), CostModel::paper_era());
        // Producer-bound regime: few producers, plenty of brokers.
        let random = sim.run(&producer_scenario("kmeans-random", 2, 4));
        let stat = sim.run(&producer_scenario("kmeans-static", 2, 4));
        let ratio = stat.msg_rate / random.msg_rate;
        assert!(
            (1.3..1.9).contains(&ratio),
            "static/random ratio {ratio} (paper: 1.6x)"
        );
    }

    #[test]
    fn fig8_one_broker_flatlines() {
        let sim = ProducerSim::new(SimMachine::default(), CostModel::paper_era());
        let b1_p4 = sim.run(&producer_scenario("lightsource", 4, 1));
        let b1_p16 = sim.run(&producer_scenario("lightsource", 16, 1));
        // Broker-bound: 4x producers barely helps.
        assert!(
            b1_p16.msg_rate < b1_p4.msg_rate * 1.3,
            "1-broker flatline violated: {} vs {}",
            b1_p16.msg_rate,
            b1_p4.msg_rate
        );
        assert!(b1_p16.broker_util > 0.9, "broker saturated");
        // More brokers lift the ceiling.
        let b4_p16 = sim.run(&producer_scenario("lightsource", 16, 4));
        assert!(b4_p16.msg_rate > b1_p16.msg_rate * 2.0);
    }

    #[test]
    fn fig8_throughput_scales_with_producers_until_brokers_bound() {
        let sim = ProducerSim::new(SimMachine::default(), CostModel::paper_era());
        let p1 = sim.run(&producer_scenario("kmeans-static", 1, 4));
        let p4 = sim.run(&producer_scenario("kmeans-static", 4, 4));
        assert!(
            p4.msg_rate > p1.msg_rate * 3.0,
            "producer scaling: {} -> {}",
            p1.msg_rate,
            p4.msg_rate
        );
    }

    fn processing_scenario(proc: &str, nodes: usize, brokers: usize) -> ProcessingScenario {
        ProcessingScenario {
            processor: proc.into(),
            msg_bytes: if proc == "kmeans" { 0.32e6 } else { 2e6 },
            input_rate: if proc == "kmeans" { 280.0 } else { 70.0 },
            processing_nodes: nodes,
            broker_nodes: brokers,
            partitions_per_node: 12,
            window_secs: 60.0,
            windows: 10,
        }
    }

    #[test]
    fn fig9_ordering_kmeans_gridrec_mlem() {
        let sim = ProcessingSim::new(SimMachine::default(), CostModel::paper_era());
        let kmeans = sim.run(&processing_scenario("kmeans", 8, 4));
        let gridrec = sim.run(&processing_scenario("gridrec", 8, 4));
        let mlem = sim.run(&processing_scenario("mlem", 8, 4));
        assert!(kmeans.msg_rate > gridrec.msg_rate);
        assert!(gridrec.msg_rate > mlem.msg_rate);
        // Paper: GridRec ~3x MLEM (63 vs 22).
        let r = gridrec.msg_rate / mlem.msg_rate;
        assert!((1.8..4.5).contains(&r), "gridrec/mlem {r}");
    }

    #[test]
    fn fig9_processing_nodes_help_while_cores_below_partitions() {
        // 4 brokers = 48 partitions.  1 node has 24 cores (cores-bound);
        // 2 nodes have 48 (partition-bound): throughput ~doubles, and
        // further nodes add nothing — the paper's "additional processing
        // nodes improved the performance as long as ..." knee.
        let sim = ProcessingSim::new(SimMachine::default(), CostModel::paper_era());
        let mut sc = processing_scenario("mlem", 1, 4);
        sc.input_rate = 200.0; // oversubscribe
        let n1 = sim.run(&sc);
        sc.processing_nodes = 2;
        let n2 = sim.run(&sc);
        sc.processing_nodes = 8;
        let n8 = sim.run(&sc);
        assert!(
            n2.msg_rate > n1.msg_rate * 1.7,
            "cores-bound scaling {} -> {}",
            n1.msg_rate,
            n2.msg_rate
        );
        assert!(
            n8.msg_rate < n2.msg_rate * 1.3,
            "partition-bound flatline {} -> {}",
            n2.msg_rate,
            n8.msg_rate
        );
    }

    #[test]
    fn fig9_kmeans_sustains_offered_rate() {
        // Paper: 277 msg/s sustained with ease at max scale.
        let sim = ProcessingSim::new(SimMachine::default(), CostModel::paper_era());
        let res = sim.run(&processing_scenario("kmeans", 8, 4));
        assert!(
            res.msg_rate > 250.0,
            "kmeans throughput {} (paper ~277)",
            res.msg_rate
        );
        assert!(res.behind_fraction < 0.3);
    }
}
