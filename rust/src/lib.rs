//! # Pilot-Streaming
//!
//! A reproduction of *"Pilot-Streaming: A Stream Processing Framework
//! for High-Performance Computing"* (Luckow, Chantzialexiou, Jha —
//! HPDC'18) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   Pilot-Job abstraction ([`pilot`]) over a SAGA-like resource
//!   adaptor ([`saga`]) managing framework plugins ([`plugins`]) on a
//!   simulated HPC machine ([`cluster`]); a Kafka-like log [`broker`];
//!   Spark-/Dask-like stream [`engine`]s; the framework-agnostic
//!   Compute-Unit layer ([`cu`]); the Streaming Mini-Apps
//!   ([`miniapp`]: MASS + MASA); and the elastic [`autoscale`]
//!   subsystem that closes the loop from observed backpressure
//!   (consumer lag, window overrun) back to pilot extend/shrink.
//! * **L2 (python/compile/model.py)** — the Mini-App compute payloads
//!   (streaming KMeans, GridRec, ML-EM) as JAX graphs, AOT-lowered to
//!   HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots (nearest-centroid assignment, tomographic forward/back
//!   projection).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and executes
//! them on the request path — Python never runs at serving time.
//!
//! Two execution planes (DESIGN.md §4b): the *real plane* moves real
//! bytes through the broker and runs real XLA compute; the *simulation
//! plane* ([`sim`]) is a discrete-event model of the paper's Wrangler
//! testbed, calibrated from real-plane measurements, used by the
//! experiment harness ([`exp`]) to regenerate every figure of the
//! paper at 32-node scale on a small host.
//!
//! ## Quickstart
//!
//! The [`app`] layer declares a whole streaming application — broker,
//! sources, processing stages, autoscaling — as one validated spec:
//!
//! ```no_run
//! use std::sync::Arc;
//! use std::time::Duration;
//! use pilot_streaming::app::{CountingProcessor, SourceSpec, StageSpec, StreamingApp};
//! use pilot_streaming::prelude::*;
//!
//! let service = Arc::new(PilotComputeService::new(Machine::wrangler(8)));
//! let app = StreamingApp::builder()
//!     .broker(KafkaDescription::new(1), &[("frames", 4)])
//!     .source(
//!         SourceSpec::mass(MassConfig::new(SourceKind::KmeansStatic, "frames"))
//!             .with_producers(2)
//!             .with_total_messages(24),
//!     )
//!     .stage(
//!         StageSpec::new("count", "frames", CountingProcessor::new())
//!             .with_window(Duration::from_millis(100)),
//!     )
//!     .build()?;
//! let handle = app.launch(&service)?;
//! handle.await_sources()?;
//! let report = handle.drain_and_stop()?;
//! assert!(report.drained);
//! # Ok::<(), pilot_streaming::Error>(())
//! ```
//!
//! The paper's raw primitives (Listing 2's descriptions, Listing 4's
//! `extend_pilot`, Listing 6's native contexts) remain available
//! underneath; `AppHandle::extend` is Listing 4 at the application
//! level.  See `examples/` for the end-to-end light-source pipeline,
//! streaming KMeans, and dynamic scaling under backpressure.

pub mod app;
pub mod autoscale;
pub mod broker;
pub mod cluster;
pub mod config;
pub mod cu;
pub mod engine;
pub mod error;
pub mod exp;
pub mod metrics;
pub mod miniapp;
pub mod pilot;
pub mod plugins;
pub mod runtime;
pub mod saga;
pub mod sim;
pub mod util;

pub use error::{Error, Result};

/// Convenient re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::app::{
        AppHandle, AppReport, AutoscaleSpec, BatchAdapter, CountingProcessor, DataSource,
        MergeSpec, RelayProcessor, ReplicationSpec, SourceSpec, SplitRoute, SplitSpec,
        StageSpec, StreamProcessor, StreamingApp, StreamingAppBuilder,
    };
    pub use crate::autoscale::{
        Autoscaler, AutoscalerConfig, BinPackingPolicy, EdgeLag, LagSlopePolicy,
        PartitionElastic, Planner, PlannerConfig, PolicyDecision, ScalingIntent, ScalingPlan,
        ScalingPolicy, SignalSnapshot, ThresholdPolicy,
    };
    pub use crate::broker::{
        AckMode, BrokerCluster, Consumer, ConsumerConfig, FailoverReport, Producer,
        ProducerConfig, Record, ReplicationConfig,
    };
    pub use crate::cluster::Machine;
    pub use crate::config::{CostPreset, ExperimentConfig, MachineConfig};
    pub use crate::cu::{submit_unit, ComputeUnit, ComputeUnitDescription, ComputeUnitState};
    pub use crate::engine::{
        BatchProcessor, Emitter, MicroBatchEngine, StreamingJobConfig, TaskContext, TaskEngine,
    };
    pub use crate::error::{Error, Result};
    pub use crate::metrics::{ScalingAction, ScalingEvent, ScalingTimeline};
    pub use crate::miniapp::{
        MasaApp, MasaConfig, MassConfig, MassSource, ProcessorKind, SourceKind,
    };
    pub use crate::pilot::{
        DaskDescription, FlinkDescription, FrameworkKind, KafkaDescription, Pilot,
        PilotComputeDescription, PilotComputeService, PilotState, SparkDescription,
    };
    pub use crate::runtime::ModelRuntime;
    pub use crate::sim::CostModel;
    pub use crate::util::RateSchedule;
}
