//! Metrics and profiling probes.
//!
//! The Streaming Mini-Apps ship "standard profiling probes ... to measure
//! common metrics, such as production and consumption rate" (paper §5).
//! This module provides the probes used across the broker, engines and
//! Mini-Apps: thread-safe rate meters, log-bucketed latency histograms,
//! and a CSV experiment recorder used by the figure harnesses.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotonic message/byte rate meter (thread-safe, lock-free counts).
#[derive(Debug)]
pub struct RateMeter {
    started: Instant,
    messages: AtomicU64,
    bytes: AtomicU64,
}

impl Default for RateMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl RateMeter {
    pub fn new() -> Self {
        RateMeter {
            started: Instant::now(),
            messages: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// Record one message of `bytes` bytes.
    pub fn record(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Record `n` messages totalling `bytes` bytes.
    pub fn record_many(&self, n: u64, bytes: u64) {
        self.messages.fetch_add(n, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Seconds since the meter was created.
    pub fn elapsed_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Messages per second since creation.
    pub fn msg_rate(&self) -> f64 {
        self.messages() as f64 / self.elapsed_secs().max(1e-9)
    }

    /// Megabytes per second since creation.
    pub fn mb_rate(&self) -> f64 {
        self.bytes() as f64 / 1e6 / self.elapsed_secs().max(1e-9)
    }
}

/// Current/peak depth gauge for bounded queues (thread-safe, lock-free).
///
/// Tracks a population that rises and falls — e.g. fetchers parked on a
/// broker shard's doorbell — exposing both the instantaneous depth (the
/// autoscale planner's queue-depth signal) and its high-water mark.
/// All operations are `Relaxed`: the gauge is a statistic, not a
/// synchronization point — callers that use the depth as a coalescing
/// gate (see `broker::shard`) pair it with their own `SeqCst` fences.
#[derive(Debug, Default)]
pub struct DepthGauge {
    current: AtomicU64,
    peak: AtomicU64,
}

impl DepthGauge {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self) {
        let now = self.current.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn dec(&self) {
        self.current.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn current(&self) -> u64 {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram: 1 µs .. ~1 hour, 5% resolution.
///
/// Lock-free recording; quantile queries take a snapshot.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const HIST_BASE_NS: f64 = 1_000.0; // 1 µs
const HIST_GROWTH: f64 = 1.05;
const HIST_BUCKETS: usize = 450; // 1.05^450 * 1µs ≈ 3.3e9 µs ≈ 55 min

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    fn bucket_for(ns: u64) -> usize {
        if ns as f64 <= HIST_BASE_NS {
            return 0;
        }
        let idx = ((ns as f64 / HIST_BASE_NS).ln() / HIST_GROWTH.ln()).floor() as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Lower edge of bucket `i` in nanoseconds.
    fn bucket_edge_ns(i: usize) -> f64 {
        HIST_BASE_NS * HIST_GROWTH.powi(i as i32)
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_for(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record_secs(&self, secs: f64) {
        self.record_ns((secs.max(0.0) * 1e9) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds.
    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    pub fn max_secs(&self) -> f64 {
        self.max_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Quantile (0.0..=1.0) in seconds, linear within the bucket edge.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                return Self::bucket_edge_ns(i) / 1e9;
            }
        }
        self.max_secs()
    }

    pub fn p50_secs(&self) -> f64 {
        self.quantile_secs(0.50)
    }

    pub fn p99_secs(&self) -> f64 {
        self.quantile_secs(0.99)
    }
}

/// Direction of an elastic-scaling action.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingAction {
    /// Processing resources added (pilot extension).
    Up,
    /// Processing resources released (extension stopped / pilot shrunk).
    Down,
    /// Topic repartitioned so the one-task-per-partition cap moves with
    /// the fleet (usually immediately followed by an `Up` extension).
    Repartition,
    /// Broker-tier nodes added — a planner step co-scheduled when a
    /// repartition would oversubscribe per-node NIC/disk budgets, or
    /// when broker saturation gauges cross their threshold.
    BrokerUp,
    /// Broker-tier extension released (the processing fleet returned
    /// to its base, so the co-scheduled broker capacity follows it
    /// down instead of accumulating across burst cycles).
    BrokerDown,
    /// The planner declined a scale-up whose modeled cost could not pay
    /// for itself within the drain horizon (cost-aware deferral).
    Defer,
    /// A broker node died and its partitions failed over to surviving
    /// replicas — `cost_secs` carries the measured recovery time, so
    /// failures land on the same timeline (and cost axis) as planned
    /// scaling actions.
    Failover,
    /// A previously-killed broker re-joined the cluster: its retained
    /// replica logs were truncated back to the epoch fence (KIP-101)
    /// before it resumed as an out-of-sync follower — `lost_records`
    /// carries the truncated-record count (records the returning
    /// replica held under epochs it never acked, not durability loss).
    Rejoin,
    /// Follower replicas were moved off hot or rack-crowded brokers —
    /// the planner's targeted repair for utilization/rack skew, cheaper
    /// than extending the whole tier (`delta_nodes` carries the number
    /// of replica moves).
    ReassignReplicas,
}

impl std::fmt::Display for ScalingAction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScalingAction::Up => write!(f, "up"),
            ScalingAction::Down => write!(f, "down"),
            ScalingAction::Repartition => write!(f, "repartition"),
            ScalingAction::BrokerUp => write!(f, "broker-up"),
            ScalingAction::BrokerDown => write!(f, "broker-down"),
            ScalingAction::Defer => write!(f, "defer"),
            ScalingAction::Failover => write!(f, "failover"),
            ScalingAction::Rejoin => write!(f, "rejoin"),
            ScalingAction::ReassignReplicas => write!(f, "reassign-replicas"),
        }
    }
}

/// One autoscaling decision that was acted on: when, which way, how many
/// nodes, and the backpressure signal that triggered it.  Recorded by
/// [`crate::autoscale::Autoscaler`] so experiments can plot resource
/// footprint against input rate (the paper's dynamic-scaling story).
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    /// Seconds since the timeline's epoch.
    pub at_secs: f64,
    pub action: ScalingAction,
    /// Nodes added or released by this action.
    pub delta_nodes: usize,
    /// Total processing nodes after the action.
    pub total_nodes: usize,
    /// Consumer lag (messages) observed at decision time.
    pub lag: u64,
    /// Active partition count of the watched topic after the action
    /// (what caps task parallelism; changed by `Repartition` events).
    pub partitions: usize,
    /// Name of the policy that made the decision.
    pub policy: String,
    /// Detection-to-actuated latency: for scale-ups, the time from the
    /// triggering sample to the extension pilot reaching Running.
    pub reaction_secs: f64,
    /// Modeled cost of this plan step (lead seconds until the bought
    /// capacity is usable; 0 for shrinks and legacy events).
    pub cost_secs: f64,
    /// Acked records lost by this action — nonzero only for `Failover`
    /// events whose promotion was unclean (the elected replica trailed
    /// the dead leader's high watermark).
    pub lost_records: u64,
}

/// Thread-safe, append-only record of scaling events (share via `Arc`).
#[derive(Debug, Default)]
pub struct ScalingTimeline {
    events: Mutex<Vec<ScalingEvent>>,
}

impl ScalingTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, event: ScalingEvent) {
        self.events.lock().unwrap().push(event);
    }

    /// Snapshot of all events in record order.
    pub fn events(&self) -> Vec<ScalingEvent> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.lock().unwrap().is_empty()
    }

    /// How many events went the given direction.
    pub fn count(&self, action: ScalingAction) -> usize {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter(|e| e.action == action)
            .count()
    }

    /// Render as an experiment [`Recorder`] (one row per event) for CSV
    /// emission alongside the figure harnesses.
    pub fn to_recorder(&self) -> Recorder {
        let rec = Recorder::new();
        for e in self.events.lock().unwrap().iter() {
            rec.add(
                Row::new()
                    .push("t_s", format!("{:.3}", e.at_secs))
                    .push("action", e.action)
                    .push("delta_nodes", e.delta_nodes)
                    .push("total_nodes", e.total_nodes)
                    .push("lag_msgs", e.lag)
                    .push("partitions", e.partitions)
                    .push("policy", &e.policy)
                    .push("reaction_s", format!("{:.4}", e.reaction_secs))
                    .push("cost_s", format!("{:.1}", e.cost_secs))
                    .push("lost_records", e.lost_records),
            );
        }
        rec
    }
}

/// One row of an experiment record: free-form key/value pairs with a
/// fixed column order, so the harness can emit paper-figure CSVs.
#[derive(Debug, Clone)]
pub struct Row {
    pub values: Vec<(String, String)>,
}

impl Row {
    pub fn new() -> Self {
        Row { values: Vec::new() }
    }

    pub fn push<T: std::fmt::Display>(mut self, key: &str, value: T) -> Self {
        self.values.push((key.to_string(), value.to_string()));
        self
    }
}

impl Default for Row {
    fn default() -> Self {
        Self::new()
    }
}

/// Collects rows and renders CSV and aligned text tables.
#[derive(Debug, Default)]
pub struct Recorder {
    rows: Mutex<Vec<Row>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, row: Row) {
        self.rows.lock().unwrap().push(row);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.lock().unwrap().is_empty()
    }

    fn header(rows: &[Row]) -> Vec<String> {
        let mut cols: Vec<String> = Vec::new();
        for r in rows {
            for (k, _) in &r.values {
                if !cols.contains(k) {
                    cols.push(k.clone());
                }
            }
        }
        cols
    }

    /// Render all rows as CSV (header from union of keys, row order kept).
    pub fn to_csv(&self) -> String {
        let rows = self.rows.lock().unwrap();
        let cols = Self::header(&rows);
        let mut out = cols.join(",");
        out.push('\n');
        for r in rows.iter() {
            let line: Vec<String> = cols
                .iter()
                .map(|c| {
                    r.values
                        .iter()
                        .find(|(k, _)| k == c)
                        .map(|(_, v)| v.clone())
                        .unwrap_or_default()
                })
                .collect();
            out.push_str(&line.join(","));
            out.push('\n');
        }
        out
    }

    /// Render as an aligned text table (for terminal output).
    pub fn to_table(&self) -> String {
        let rows = self.rows.lock().unwrap();
        let cols = Self::header(&rows);
        let mut widths: Vec<usize> = cols.iter().map(|c| c.len()).collect();
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                cols.iter()
                    .enumerate()
                    .map(|(i, c)| {
                        let v = r
                            .values
                            .iter()
                            .find(|(k, _)| k == c)
                            .map(|(_, v)| v.clone())
                            .unwrap_or_default();
                        widths[i] = widths[i].max(v.len());
                        v
                    })
                    .collect()
            })
            .collect();
        let mut out = String::new();
        for (i, c) in cols.iter().enumerate() {
            out.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        out.push('\n');
        for (i, _) in cols.iter().enumerate() {
            out.push_str(&"-".repeat(widths[i]));
            out.push_str("  ");
        }
        out.push('\n');
        for row in cells {
            for (i, v) in row.iter().enumerate() {
                out.push_str(&format!("{:<w$}  ", v, w = widths[i]));
            }
            out.push('\n');
        }
        out
    }

    /// Write CSV to `path`, creating parent directories.
    pub fn write_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_meter_counts() {
        let m = RateMeter::new();
        m.record(100);
        m.record(200);
        m.record_many(3, 300);
        assert_eq!(m.messages(), 5);
        assert_eq!(m.bytes(), 600);
        assert!(m.msg_rate() > 0.0);
    }

    #[test]
    fn depth_gauge_tracks_current_and_peak() {
        let g = DepthGauge::new();
        assert_eq!((g.current(), g.peak()), (0, 0));
        g.inc();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.current(), 2);
        assert_eq!(g.peak(), 3, "peak is sticky");
        g.dec();
        g.dec();
        assert_eq!((g.current(), g.peak()), (0, 3));
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1_000_000); // 1..1000 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50_secs();
        let p99 = h.p99_secs();
        assert!(p50 < p99, "p50={p50} p99={p99}");
        // p50 should land near 0.5 s (5% bucket resolution).
        assert!((p50 - 0.5).abs() < 0.1, "p50={p50}");
        assert!((h.mean_secs() - 0.5005).abs() < 0.01);
        assert!((h.max_secs() - 1.0).abs() < 0.01);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.quantile_secs(0.5), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
    }

    #[test]
    fn histogram_extremes_clamp() {
        let h = Histogram::new();
        h.record_ns(1); // below base
        h.record_ns(u64::MAX / 2); // above top bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile_secs(1.0) > 0.0);
    }

    #[test]
    fn scaling_timeline_records_and_counts() {
        let tl = ScalingTimeline::new();
        assert!(tl.is_empty());
        tl.record(ScalingEvent {
            at_secs: 1.0,
            action: ScalingAction::Up,
            delta_nodes: 2,
            total_nodes: 3,
            lag: 40,
            partitions: 4,
            policy: "threshold".into(),
            reaction_secs: 0.05,
            cost_secs: 16.0,
            lost_records: 0,
        });
        tl.record(ScalingEvent {
            at_secs: 4.0,
            action: ScalingAction::Down,
            delta_nodes: 2,
            total_nodes: 1,
            lag: 0,
            partitions: 4,
            policy: "threshold".into(),
            reaction_secs: 0.0,
            cost_secs: 0.0,
            lost_records: 0,
        });
        tl.record(ScalingEvent {
            at_secs: 5.0,
            action: ScalingAction::Repartition,
            delta_nodes: 0,
            total_nodes: 1,
            lag: 0,
            partitions: 8,
            policy: "partition-elastic".into(),
            reaction_secs: 0.0,
            cost_secs: 0.0,
            lost_records: 0,
        });
        assert_eq!(tl.len(), 3);
        assert_eq!(tl.count(ScalingAction::Up), 1);
        assert_eq!(tl.count(ScalingAction::Down), 1);
        assert_eq!(tl.count(ScalingAction::Repartition), 1);
        let csv = tl.to_recorder().to_csv();
        assert!(csv.starts_with("t_s,action,delta_nodes"));
        assert!(csv.contains("up"), "{csv}");
        assert!(csv.contains("down"), "{csv}");
        assert!(csv.contains("repartition"), "{csv}");
        assert_eq!(tl.events()[0].lag, 40);
        assert_eq!(tl.events()[2].partitions, 8);
    }

    #[test]
    fn recorder_csv_and_table() {
        let rec = Recorder::new();
        rec.add(Row::new().push("nodes", 2).push("secs", 1.5));
        rec.add(Row::new().push("nodes", 4).push("secs", 2.5).push("extra", "x"));
        let csv = rec.to_csv();
        assert!(csv.starts_with("nodes,secs,extra\n"));
        assert!(csv.contains("2,1.5,\n"));
        assert!(csv.contains("4,2.5,x\n"));
        let table = rec.to_table();
        assert!(table.contains("nodes"));
    }
}
