//! Consumer client: group membership, partition assignment, offsets.
//!
//! Consumers join a consumer group on a topic; the group coordinator
//! (inside [`BrokerCluster`]) hands out range assignments and tracks
//! committed offsets.  A consumer polls its assigned partitions in turn;
//! when membership changes (join/leave — the dynamic-scaling case the
//! paper's resource management enables) the next `poll` observes the
//! bumped generation and picks up its new assignment transparently.
//!
//! Rebalances are **epoch-aware**: when the topic is repartitioned
//! ([`BrokerCluster::repartition_topic`]) the group drains the old
//! partition-set epoch first — polls are capped at the transition's
//! fences — and only after every fence is committed does the group
//! advance and spread over the new partition set.  Committed progress
//! migrates untouched because partition ids are stable across epochs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::metrics::RateMeter;

use super::cluster::BrokerCluster;
use super::log::Record;

/// Consumer configuration.
#[derive(Debug, Clone)]
pub struct ConsumerConfig {
    /// Max payload bytes per poll across partitions.
    pub max_poll_bytes: usize,
    /// Per-partition fetch timeout within a poll.
    pub fetch_timeout: Duration,
    /// Commit automatically after each successful poll.
    pub auto_commit: bool,
}

impl Default for ConsumerConfig {
    fn default() -> Self {
        ConsumerConfig {
            max_poll_bytes: 8 << 20,
            fetch_timeout: Duration::from_millis(100),
            auto_commit: true,
        }
    }
}

/// A record annotated with its source partition.
#[derive(Debug, Clone)]
pub struct PartitionRecord {
    pub partition: usize,
    pub record: Record,
}

/// A group consumer bound to one topic, fetching to one node.
pub struct Consumer {
    cluster: BrokerCluster,
    topic: String,
    /// Cached topic handle for the fetch hot path; revalidated
    /// lock-free via [`super::cluster::Topic::is_current`] so polls
    /// never resolve the topics snapshot while the handle is fresh.
    topic_handle: Arc<super::cluster::Topic>,
    group: String,
    node: NodeId,
    member_id: u64,
    generation: u64,
    /// Partition-set epoch this member is serving (trails the topic's
    /// epoch while the group drains a repartition).
    epoch: u64,
    /// The topic's epoch when the current serve plan was computed —
    /// re-checked after uncapped fetches (see `poll`).
    topic_epoch: u64,
    assignment: Vec<usize>,
    /// Fetch ceilings for draining partitions: offsets this member must
    /// not read past until the group advances its epoch.  Empty when
    /// the group is caught up with the topic's epoch.
    ceilings: HashMap<usize, u64>,
    positions: HashMap<usize, u64>,
    next_idx: usize,
    config: ConsumerConfig,
    pub metrics: Arc<RateMeter>,
    /// Lag over this member's assigned partitions, refreshed on every
    /// poll — a cheap atomic gauge the autoscaler can watch from another
    /// thread without touching broker locks.
    lag_gauge: Arc<AtomicU64>,
}

impl Consumer {
    /// Join `group` on `topic`, fetching into `node`.
    pub fn join(
        cluster: BrokerCluster,
        topic: &str,
        group: &str,
        node: NodeId,
        config: ConsumerConfig,
    ) -> Result<Self> {
        let (member_id, _) = cluster.group_join(group, topic);
        let topic_handle = cluster.topic(topic)?;
        let mut c = Consumer {
            cluster,
            topic: topic.to_string(),
            topic_handle,
            group: group.to_string(),
            node,
            member_id,
            generation: 0,
            epoch: 0,
            topic_epoch: 0,
            assignment: Vec::new(),
            ceilings: HashMap::new(),
            positions: HashMap::new(),
            next_idx: 0,
            config,
            metrics: Arc::new(RateMeter::new()),
            lag_gauge: Arc::new(AtomicU64::new(0)),
        };
        c.refresh_assignment()?;
        Ok(c)
    }

    fn refresh_assignment(&mut self) -> Result<()> {
        // Revalidate the cached topic handle first (lock-free when
        // current): the fetch path below reads through it, and a grown
        // partition set only exists on a fresh handle.
        if !self.topic_handle.is_current() {
            self.topic_handle = self.cluster.topic(&self.topic)?;
        }
        let plan = self
            .cluster
            .group_serve_plan(&self.group, &self.topic, self.member_id)?;
        if plan.generation != self.generation {
            self.generation = plan.generation;
            self.epoch = plan.epoch;
            self.topic_epoch = plan.topic_epoch;
            self.ceilings.clear();
            for (p, ceiling) in plan.partitions.iter().zip(plan.ceilings.iter()) {
                if let Some(c) = ceiling {
                    self.ceilings.insert(*p, *c);
                }
            }
            self.assignment = plan.partitions;
            self.next_idx = 0;
            self.positions.clear();
            for p in &self.assignment {
                self.positions
                    .insert(*p, self.cluster.committed(&self.group, &self.topic, *p));
            }
            // The assignment just changed (rebalance or epoch advance):
            // recompute the gauge now, so cross-thread observers (the
            // autoscaler's signal probe) never read lag for partitions
            // this member no longer owns — previously the stale value
            // survived until the next poll completed a fetch.
            self.refresh_lag();
        }
        Ok(())
    }

    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }

    /// The partition-set epoch this member is serving.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn member_id(&self) -> u64 {
        self.member_id
    }

    /// Lag (unconsumed messages) over this member's assignment, as of
    /// the last poll.
    pub fn lag(&self) -> u64 {
        self.lag_gauge.load(Ordering::Relaxed)
    }

    /// Shareable handle to the lag gauge (for cross-thread observers
    /// like the autoscaler).
    pub fn lag_gauge(&self) -> Arc<AtomicU64> {
        self.lag_gauge.clone()
    }

    /// Recompute the lag gauge from the partitions' high-watermark
    /// mirrors — one topic lookup plus an atomic load per assigned
    /// partition, cheap enough to run on every poll.
    fn refresh_lag(&self) {
        let Ok(topic) = self.cluster.topic(&self.topic) else {
            return;
        };
        let mut lag = 0u64;
        for p in &self.assignment {
            let pos = *self.positions.get(p).unwrap_or(&0);
            if let Some(partition) = topic.partitions.get(*p) {
                lag += partition.end_offset().saturating_sub(pos);
            }
        }
        self.lag_gauge.store(lag, Ordering::Relaxed);
    }

    /// Poll the next assigned partition (round-robin across polls).
    ///
    /// Returns records tagged with their partition.  Auto-commits when
    /// configured.  An empty vec means no data arrived within the fetch
    /// timeout.
    pub fn poll(&mut self) -> Result<Vec<PartitionRecord>> {
        self.refresh_assignment()?;
        if self.assignment.is_empty() {
            // A rebalance may have stripped this member: the gauge must
            // drop to zero, not keep reporting the old partitions' lag.
            self.refresh_lag();
            std::thread::sleep(self.config.fetch_timeout);
            return Ok(Vec::new());
        }
        // Try each assigned partition at most once, starting from the
        // round-robin cursor, so one idle partition can't starve others.
        let mut skipped = 0;
        for _ in 0..self.assignment.len() {
            let p = self.assignment[self.next_idx % self.assignment.len()];
            self.next_idx = (self.next_idx + 1) % self.assignment.len();
            let pos = *self.positions.get(&p).unwrap_or(&0);
            let ceiling = self.ceilings.get(&p).copied();
            if let Some(c) = ceiling {
                if pos >= c {
                    // This partition's share of the draining epoch is
                    // already consumed; the rest belongs to the next
                    // epoch and is served only after the group advances.
                    skipped += 1;
                    continue;
                }
            }
            if p >= self.topic_handle.partitions.len() {
                // A repartition grew the topic after the handle was
                // refreshed but before this plan was computed: the new
                // partition only exists on a fresh handle.
                self.topic_handle = self.cluster.topic(&self.topic)?;
            }
            let mut recs = match self.cluster.fetch_from(
                &self.topic_handle,
                p,
                pos,
                self.config.max_poll_bytes,
                self.node,
                self.config.fetch_timeout,
            ) {
                Ok(recs) => recs,
                // The partition's data-plane shard stayed quiesced past
                // the bounded-wait grace (a repartition sealing it) —
                // transient by design: skip to the next partition; the
                // next poll's refreshed plan lands after the resume.
                Err(Error::ShardQuiesced(_)) => {
                    skipped += 1;
                    continue;
                }
                Err(e) => return Err(e),
            };
            if let Some(c) = ceiling {
                recs.truncate(recs.partition_point(|r| r.offset < c));
            } else if !recs.is_empty()
                && self.cluster.topic_epoch(&self.topic)? != self.topic_epoch
            {
                // A repartition landed while the (blocking) fetch was in
                // flight: these uncapped records may lie beyond a fence
                // this plan never saw.  Discard them (nothing was
                // committed) and end the poll — the repartition bumped
                // the generation, so the next poll refreshes and
                // re-fetches under ceilings.
                break;
            }
            if recs.is_empty() {
                continue;
            }
            let new_pos = recs.last().unwrap().offset + 1;
            self.positions.insert(p, new_pos);
            let bytes: usize = recs.iter().map(|r| r.value.len()).sum();
            self.metrics.record_many(recs.len() as u64, bytes as u64);
            if self.config.auto_commit {
                self.cluster.commit(&self.group, &self.topic, p, new_pos);
            }
            self.refresh_lag();
            return Ok(recs
                .into_iter()
                .map(|record| PartitionRecord { partition: p, record })
                .collect());
        }
        if skipped == self.assignment.len() {
            // Every owned partition is drained to its fence: this member
            // is waiting on the rest of the group to finish the epoch.
            // Pace the wait instead of spinning.
            std::thread::sleep(self.config.fetch_timeout);
        }
        self.refresh_lag();
        Ok(Vec::new())
    }

    /// Explicitly commit the current positions of all assigned partitions.
    pub fn commit(&self) {
        for (p, pos) in &self.positions {
            self.cluster.commit(&self.group, &self.topic, *p, *pos);
        }
        // Committing is a progress point observers key off: a drain
        // loop that commits and then reads `lag()` (or an autoscale
        // probe sampling the shared gauge) must see lag computed
        // against the current positions, not the last poll's.
        self.refresh_lag();
    }
}

impl Drop for Consumer {
    fn drop(&mut self) {
        if self.config.auto_commit {
            self.commit();
        }
        self.cluster
            .group_leave(&self.group, &self.topic, self.member_id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    fn setup(partitions: usize) -> BrokerCluster {
        let c = BrokerCluster::new(Machine::unthrottled(3), vec![0]);
        c.create_topic("t", partitions).unwrap();
        c
    }

    fn fast_config() -> ConsumerConfig {
        ConsumerConfig {
            fetch_timeout: Duration::from_millis(10),
            ..Default::default()
        }
    }

    #[test]
    fn consumer_reads_all_partitions() {
        let c = setup(3);
        for p in 0..3 {
            c.produce("t", p, 0, &[vec![p as u8]]).unwrap();
        }
        let mut consumer = Consumer::join(c, "t", "g", 1, fast_config()).unwrap();
        let mut seen = Vec::new();
        for _ in 0..6 {
            for r in consumer.poll().unwrap() {
                seen.push(r.record.value[0]);
            }
        }
        seen.sort();
        assert_eq!(seen, vec![0, 1, 2]);
    }

    #[test]
    fn two_members_split_partitions() {
        let c = setup(4);
        let mut c1 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        let c2 = Consumer::join(c.clone(), "t", "g", 2, fast_config()).unwrap();
        // c1 must observe the generation bump caused by c2 joining.
        c1.poll().unwrap();
        let mut all = [c1.assignment().to_vec(), c2.assignment().to_vec()].concat();
        all.sort();
        assert_eq!(all, vec![0, 1, 2, 3]);
        assert_eq!(c1.assignment().len(), 2);
        assert_eq!(c2.assignment().len(), 2);
    }

    #[test]
    fn offsets_resume_after_member_replacement() {
        let c = setup(1);
        c.produce("t", 0, 0, &[vec![1], vec![2], vec![3]]).unwrap();
        {
            let mut c1 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
            let recs = c1.poll().unwrap();
            assert_eq!(recs.len(), 3);
        } // drop commits + leaves
        c.produce("t", 0, 0, &[vec![4]]).unwrap();
        let mut c2 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        let recs = c2.poll().unwrap();
        assert_eq!(recs.len(), 1, "must resume at committed offset");
        assert_eq!(recs[0].record.value, vec![4]);
    }

    #[test]
    fn rebalance_on_leave_reassigns_everything() {
        let c = setup(2);
        let mut c1 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        {
            let _c2 = Consumer::join(c.clone(), "t", "g", 2, fast_config()).unwrap();
            c1.poll().unwrap();
            assert_eq!(c1.assignment().len(), 1);
        } // c2 leaves
        c1.poll().unwrap();
        assert_eq!(c1.assignment().len(), 2, "c1 should own both partitions");
    }

    #[test]
    fn lag_gauge_tracks_unconsumed_messages() {
        let c = setup(2);
        c.produce("t", 0, 0, &[vec![1], vec![2]]).unwrap();
        c.produce("t", 1, 0, &[vec![3]]).unwrap();
        let mut consumer = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        assert_eq!(consumer.lag(), 3, "gauge warm from the join-time refresh");
        let gauge = consumer.lag_gauge();
        // Drain everything; the gauge must settle at 0.
        let mut drained = 0;
        for _ in 0..8 {
            drained += consumer.poll().unwrap().len();
        }
        assert_eq!(drained, 3);
        assert_eq!(consumer.lag(), 0);
        // New production shows up after the next poll.
        c.produce("t", 0, 0, &[vec![4], vec![5]]).unwrap();
        consumer.poll().unwrap();
        assert_eq!(gauge.load(Ordering::Relaxed), 0, "poll consumed the new records");
    }

    #[test]
    fn lag_gauge_fresh_after_rebalance() {
        // Regression: after a rebalance strips partitions from this
        // member, the gauge must reflect the *new* assignment as soon
        // as the assignment refreshes — not after the next completed
        // fetch (observers sampling between rebalance and fetch used to
        // see the old assignment's lag).
        let c = setup(2);
        for _ in 0..5 {
            c.produce("t", 0, 0, &[vec![0]]).unwrap();
        }
        let mut c1 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        assert_eq!(c1.lag(), 5, "sole member sees the whole backlog");
        // A second member takes partition 1 (empty); c1 keeps partition
        // 0 with its 5-message backlog.
        let c2 = Consumer::join(c.clone(), "t", "g", 2, fast_config()).unwrap();
        assert_eq!(c2.assignment(), &[1]);
        assert_eq!(c2.lag(), 0, "freshly joined member owns no backlog");
        // c1's next poll observes the rebalance; the gauge must be
        // updated by the assignment refresh itself, which poll runs
        // before fetching.  Drain and confirm it settles at 0.
        let mut drained = 0;
        for _ in 0..8 {
            drained += c1.poll().unwrap().len();
        }
        assert_eq!(drained, 5);
        assert_eq!(c1.assignment(), &[0]);
        assert_eq!(c1.lag(), 0);
    }

    #[test]
    fn commit_refreshes_lag_gauge() {
        // Regression: `commit` used to leave the gauge stale, so a
        // drain loop that commits and then reads `lag()` saw the value
        // from the last poll instead of the current backlog.
        let c = setup(1);
        c.produce("t", 0, 0, &[vec![1]]).unwrap();
        let mut consumer = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        while consumer.lag() > 0 {
            consumer.poll().unwrap();
        }
        c.produce("t", 0, 0, &[vec![2], vec![3]]).unwrap();
        consumer.commit();
        assert_eq!(consumer.lag(), 2, "commit recomputes the gauge");
    }

    #[test]
    fn consumer_poll_serves_from_local_in_sync_follower() {
        use crate::broker::ReplicationConfig;
        let c = BrokerCluster::new(Machine::unthrottled(3), vec![0, 1]);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2).with_follower_fetch(true))
            .unwrap();
        c.produce("t", 0, 2, &[vec![7; 64]]).unwrap();
        let io0 = c.broker_io();
        // The consumer fetches into node 1, which hosts partition 0's
        // in-sync follower: the bytes are served (and billed) locally,
        // leaving the leader's egress untouched.
        let mut consumer = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        let recs = consumer.poll().unwrap();
        assert_eq!(recs.len(), 1);
        let io1 = c.broker_io();
        assert_eq!(
            io1[0].nic_out_bytes, io0[0].nic_out_bytes,
            "leader egress untouched by the follower-served fetch"
        );
        assert_eq!(
            io1[1].nic_out_bytes - io0[1].nic_out_bytes,
            64,
            "the local follower served the fetch bytes"
        );
    }

    #[test]
    fn consumer_drains_repartitioned_topic_in_epoch_order() {
        let c = setup(1);
        c.produce("t", 0, 0, &[vec![1], vec![2]]).unwrap();
        let mut consumer = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        // Repartition with standing backlog: epoch 0 must drain first.
        c.repartition_topic("t", 3).unwrap();
        c.produce("t", 1, 0, &[vec![3]]).unwrap();
        c.produce("t", 2, 0, &[vec![4]]).unwrap();
        let mut seen = Vec::new();
        for _ in 0..12 {
            for r in consumer.poll().unwrap() {
                seen.push(r.record.value[0]);
            }
            if seen.len() == 4 {
                break;
            }
        }
        // Old-epoch records strictly precede new-epoch records.
        assert_eq!(seen[..2], [1, 2]);
        let mut tail = seen[2..].to_vec();
        tail.sort();
        assert_eq!(tail, vec![3, 4]);
        assert_eq!(consumer.epoch(), 1);
        assert_eq!(consumer.assignment().len(), 3);
    }

    #[test]
    fn empty_assignment_poll_is_empty() {
        // 1 partition, 2 members: second member gets nothing.
        let c = setup(1);
        let _c1 = Consumer::join(c.clone(), "t", "g", 1, fast_config()).unwrap();
        let mut c2 = Consumer::join(c.clone(), "t", "g", 2, fast_config()).unwrap();
        assert!(c2.poll().unwrap().is_empty());
    }
}
