//! The Kafka-like message broker substrate.
//!
//! The paper deploys Apache Kafka via Pilot-Streaming to decouple data
//! production from consumption (§2.1, §4).  This module is our from-
//! scratch equivalent (DESIGN.md §3): a log-based publish/subscribe
//! broker with
//!
//! * zero-copy shared-slab partition logs ([`log`]): appends land in
//!   `Arc`-backed segment slabs behind a narrow writer lock, fetches
//!   return [`SharedSlice`] views published through snapshot swaps —
//!   readers never contend with producers and never copy payloads,
//! * a cluster layer with partition leadership over simulated broker
//!   nodes, blocking fetches, and consumer-group coordination
//!   ([`cluster`]),
//! * a thread-per-core sharded data plane ([`shard`]): partitions map
//!   onto core-pinned shards via the jump-consistent hash, fetchers
//!   park on per-shard coalesced doorbells, and producers ring once
//!   per append batch — the contended produce/fetch path scales with
//!   cores instead of serializing on per-partition condvars,
//! * batching producers with flush-visible batched acks ([`producer`])
//!   and group consumers ([`consumer`]),
//! * online topic repartitioning ([`repartition`]): epoch-stamped
//!   partition sets with drain-before-serve fences and jump consistent
//!   hashing, so the one-task-per-partition scaling cap (§6.4's knee)
//!   moves with the fleet,
//! * calibrated cloud-broker latency models for Amazon Kinesis and
//!   Google Pub/Sub ([`cloud`]) used by the Figure 7 comparison.
//!
//! Data movement pays per-node NIC/disk token-bucket costs, so broker
//! I/O saturation — the central effect in the paper's Figures 8 and 9 —
//! emerges from the same mechanism as on real hardware.

pub mod cloud;
pub mod cluster;
pub mod consumer;
pub mod log;
pub mod producer;
pub mod repartition;
pub mod replication;
pub mod shard;

pub use cloud::{CloudBroker, CloudLatencyModel, CloudRecord};
pub use cluster::{BrokerCluster, BrokerIoStat, Partition, Topic};
pub use consumer::{Consumer, ConsumerConfig, PartitionRecord};
pub use log::{copytrack, LogConfig, LogMirror, PartitionLog, Record, SharedSlice};
pub use producer::{AckBatch, Partitioner, Producer, ProducerConfig};
pub use repartition::{jump_hash, key_hash, key_partition, EpochTransition, ServePlan};
pub use replication::{AckMode, FailoverEvent, FailoverReport, RejoinReport, ReplicationConfig};
pub use shard::{default_shards, shard_of, ShardStats};
