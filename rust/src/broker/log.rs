//! Zero-copy shared-slab partition log (the Kafka storage model).
//!
//! A partition is a sequence of segments; each segment stores record
//! payloads contiguously in an `Arc`-backed **slab** plus an append-only
//! index of `(position, length, timestamp)` per record.  Appends go to
//! the active segment's slab under a narrow writer lock; reads are
//! offset-addressed and return [`SharedSlice`] *views* into the slabs —
//! no payload bytes are copied on the fetch path (the modeled network
//! cost is still paid: `cluster::Throttle` charges the returned bytes at
//! the broker boundary, so callers see the same simulated NIC/disk cost
//! a remote client would, without the real memcpy).
//!
//! Lock split (§Perf L3): the reader path never contends with appends.
//! Writers mutate only the active slab (raw bytes + a `Release` on the
//! committed length); the segment *list* is published through an
//! [`ArcCell`] snapshot that changes only on segment roll / retention.
//! Retention is safe by construction — a reader holding a [`SharedSlice`]
//! (or a whole snapshot) keeps the underlying slab alive via `Arc` while
//! the log itself has long forgotten it.
//!
//! Shard affinity (§Perf L4): a log belongs to exactly one partition,
//! and every partition is owned by one data-plane shard (see
//! [`super::shard`]) — so under the thread-per-core deployment the
//! writer lock and the active slab's cache lines are only ever touched
//! from the owning shard's cores, and fetch wakeups for this log go
//! through that shard's doorbell rather than a per-log condvar.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::ArcCell;

/// Debug-only accounting of payload materializations.  The zero-copy
/// guarantee is asserted through this counter: [`SharedSlice::to_vec`]
/// is the only way record bytes leave a slab as fresh owned memory, so
/// a produce→fetch→process pipeline that stays on views leaves it
/// untouched (see `fetch_performs_no_payload_copies` in the broker
/// integration tests).
pub mod copytrack {
    #[cfg(debug_assertions)]
    thread_local! {
        // Per-thread so parallel tests can assert on their own fetch
        // pipelines without cross-talk; a fetch's copies (if any would
        // exist) happen on the fetching thread.
        static PAYLOAD_COPIES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    }

    /// Payload copies performed *by this thread* since it started
    /// (always 0 in release builds, where the counter compiles out).
    #[cfg(debug_assertions)]
    pub fn payload_copies() -> u64 {
        PAYLOAD_COPIES.with(|c| c.get())
    }

    /// Payload copies performed *by this thread* since it started
    /// (always 0 in release builds, where the counter compiles out).
    #[cfg(not(debug_assertions))]
    pub fn payload_copies() -> u64 {
        0
    }

    #[cfg(debug_assertions)]
    pub(crate) fn note_copy() {
        PAYLOAD_COPIES.with(|c| c.set(c.get() + 1));
    }

    #[cfg(not(debug_assertions))]
    pub(crate) fn note_copy() {}
}

// ---------------------------------------------------------------------
// Append-only slab
// ---------------------------------------------------------------------

/// A fixed-capacity append-only buffer shared between one writer and
/// many readers.
///
/// The writer (serialized externally by the partition's writer lock)
/// appends into spare capacity and publishes the new length with a
/// `Release` store; readers snapshot the committed length with an
/// `Acquire` load and only ever touch `[..committed]`, which is
/// immutable from the moment it is published.  The backing allocation
/// never moves (capacity is fixed at construction), so raw-pointer
/// views into the committed prefix stay valid for the slab's lifetime.
pub(crate) struct AppendSlab<T> {
    ptr: *mut T,
    cap: usize,
    committed: AtomicUsize,
}

// Safety: the committed prefix is immutable and the single-writer
// contract (enforced by the caller's lock) covers the mutable tail.
unsafe impl<T: Send + Sync> Send for AppendSlab<T> {}
unsafe impl<T: Send + Sync> Sync for AppendSlab<T> {}

impl<T: Copy> AppendSlab<T> {
    fn with_capacity(cap: usize) -> Self {
        // Reserved-but-untouched pages are not committed by the OS, so
        // preallocating the full segment is virtually free while sparing
        // the hot path any reallocation (§Perf L3-1) — and a stable
        // allocation is what makes the zero-copy views sound.
        let mut v = Vec::<T>::with_capacity(cap);
        // Record the allocation's *actual* capacity (with_capacity
        // guarantees only "at least"): Drop must hand Vec::from_raw_parts
        // the exact capacity the allocation was made with.
        let cap = v.capacity();
        let ptr = v.as_mut_ptr();
        std::mem::forget(v);
        AppendSlab {
            ptr,
            cap,
            committed: AtomicUsize::new(0),
        }
    }

    /// Take ownership of an existing `Vec` without copying it.
    fn from_vec(mut v: Vec<T>) -> Self {
        let len = v.len();
        let cap = v.capacity();
        let ptr = v.as_mut_ptr();
        std::mem::forget(v);
        AppendSlab {
            ptr,
            cap,
            committed: AtomicUsize::new(len),
        }
    }

    fn committed(&self) -> usize {
        self.committed.load(Ordering::Acquire)
    }

    /// Spare capacity (writer-side; only the writer moves `committed`
    /// upward, so a relaxed read is exact under the writer lock).
    fn remaining(&self) -> usize {
        self.cap - self.committed.load(Ordering::Relaxed)
    }

    /// Append `items`, returning the start position.
    ///
    /// # Safety
    /// The caller must be the slab's only writer (hold the partition's
    /// writer lock) and must have checked `remaining() >= items.len()`.
    unsafe fn append(&self, items: &[T]) -> usize {
        let at = self.committed.load(Ordering::Relaxed);
        debug_assert!(self.cap - at >= items.len(), "slab overflow");
        std::ptr::copy_nonoverlapping(items.as_ptr(), self.ptr.add(at), items.len());
        // Publish: readers that observe the new length (Acquire) also
        // observe the bytes written above.
        self.committed.store(at + items.len(), Ordering::Release);
        at
    }

    /// The committed prefix.  Safe for any thread: the range was
    /// published with `Release` and never mutates afterwards.
    fn as_committed(&self) -> &[T] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.committed()) }
    }
}

impl<T> Drop for AppendSlab<T> {
    fn drop(&mut self) {
        // Reconstruct with length 0: frees the allocation without
        // running element destructors (elements are `Copy` everywhere
        // this type is instantiated).
        unsafe { drop(Vec::from_raw_parts(self.ptr, 0, self.cap)) };
    }
}

// ---------------------------------------------------------------------
// Shared payload views
// ---------------------------------------------------------------------

/// A cheap view of record payload bytes: slab `Arc` + offset + length.
///
/// Cloning bumps a refcount; no payload bytes move.  Holding a
/// `SharedSlice` keeps its slab alive even after retention drops the
/// segment from the log, so views handed out by a fetch can never
/// dangle.  Derefs to `[u8]`, so call sites that used to receive a
/// `Vec<u8>` payload read it unchanged.
///
/// The flip side of that liveness guarantee: one retained view pins its
/// whole segment slab (up to `segment_bytes`).  Process-and-drop
/// consumers (every pipeline in this repo) never notice, but code that
/// *stashes* records past the poll that produced them should
/// [`SharedSlice::to_vec`] the few it keeps, trading one counted copy
/// for releasing the slab to retention.
#[derive(Clone)]
pub struct SharedSlice {
    slab: Arc<AppendSlab<u8>>,
    offset: usize,
    len: usize,
}

impl SharedSlice {
    /// Wrap owned bytes in a dedicated slab (no copy — the `Vec`'s
    /// allocation is adopted).  Used at non-log boundaries that need a
    /// `SharedSlice` from materialized data.
    pub fn from_vec(v: Vec<u8>) -> Self {
        let len = v.len();
        SharedSlice {
            slab: Arc::new(AppendSlab::from_vec(v)),
            offset: 0,
            len,
        }
    }

    pub fn as_slice(&self) -> &[u8] {
        // Safety: construction guarantees `offset + len` lies within
        // the slab's committed (hence initialized and immutable) prefix.
        unsafe { std::slice::from_raw_parts(self.slab.ptr.add(self.offset), self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Materialize the payload as owned bytes.  This is the *only*
    /// copying exit from the zero-copy plane; debug builds count each
    /// call in [`copytrack`].
    pub fn to_vec(&self) -> Vec<u8> {
        copytrack::note_copy();
        self.as_slice().to_vec()
    }
}

impl std::ops::Deref for SharedSlice {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for SharedSlice {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl std::fmt::Debug for SharedSlice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedSlice({:?})", self.as_slice())
    }
}

impl PartialEq for SharedSlice {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedSlice {}

impl PartialEq<[u8]> for SharedSlice {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for SharedSlice {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for SharedSlice {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<const N: usize> PartialEq<[u8; N]> for SharedSlice {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for SharedSlice {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

impl From<Vec<u8>> for SharedSlice {
    fn from(v: Vec<u8>) -> Self {
        SharedSlice::from_vec(v)
    }
}

/// A record as returned from a fetch.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Absolute offset within the partition.
    pub offset: u64,
    /// Broker-side append timestamp (ns since producer epoch).
    pub timestamp_ns: u64,
    /// Payload view (zero-copy; derefs to `[u8]`).
    pub value: SharedSlice,
}

// ---------------------------------------------------------------------
// Segments
// ---------------------------------------------------------------------

/// Per-record index entry: payload position + length + timestamp.
#[derive(Clone, Copy)]
struct IndexEntry {
    pos: usize,
    len: u32,
    ts: u64,
}

/// One log segment: a payload slab + a record index, both append-only.
/// Sealed segments are frozen; the active (last) segment grows through
/// the committed-length atomics, so stale snapshots of the list still
/// observe new appends.
#[derive(Clone)]
struct Segment {
    /// Offset of the first record in this segment.
    base_offset: u64,
    data: Arc<AppendSlab<u8>>,
    index: Arc<AppendSlab<IndexEntry>>,
}

impl Segment {
    fn new(base_offset: u64, data_capacity: usize, index_capacity: usize) -> Self {
        Segment {
            base_offset,
            data: Arc::new(AppendSlab::with_capacity(data_capacity)),
            index: Arc::new(AppendSlab::with_capacity(index_capacity)),
        }
    }

    fn len(&self) -> usize {
        self.index.committed()
    }

    fn bytes(&self) -> usize {
        self.data.committed()
    }

    fn record(&self, rel: usize) -> Record {
        let e = self.index.as_committed()[rel];
        Record {
            offset: self.base_offset + rel as u64,
            timestamp_ns: e.ts,
            value: SharedSlice {
                slab: self.data.clone(),
                offset: e.pos,
                len: e.len as usize,
            },
        }
    }
}

/// Records per segment index slab.  Segments roll when either the data
/// slab or the index fills, so tiny-record workloads can't grow an
/// index without bound.
fn index_capacity(segment_bytes: usize) -> usize {
    (segment_bytes / 16).clamp(64, 1 << 20)
}

/// Configuration for a partition log.
#[derive(Debug, Clone, Copy)]
pub struct LogConfig {
    /// Roll the active segment after this many payload bytes.
    pub segment_bytes: usize,
    /// Drop whole old segments once total bytes exceed this (None = keep
    /// everything).  Mirrors Kafka size-based retention.
    pub retention_bytes: Option<usize>,
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig {
            segment_bytes: 64 << 20, // 64 MB
            retention_bytes: Some(512 << 20),
        }
    }
}

// ---------------------------------------------------------------------
// The partition log
// ---------------------------------------------------------------------

/// Reader snapshot: the live segment list.  Published on roll /
/// retention / creation only — per-record appends never touch it.
struct LogView {
    segments: Vec<Segment>,
}

/// Writer-side state, guarded by the narrow writer lock.
struct WriterState {
    /// All live segments; the last one is active.  Mirrors the
    /// published `LogView`.
    segments: Vec<Segment>,
    next_offset: u64,
    total_bytes: usize,
    /// Repartition fences: `(epoch, end_offset_at_seal)` per sealed
    /// epoch, ascending.  Everything below the watermark of epoch `e`
    /// was appended before the topic transitioned *to* epoch `e` — the
    /// boundary consumer groups drain to before serving epoch `e` data.
    epoch_marks: Vec<(u64, u64)>,
}

/// The partition log: shared-slab segments + high watermark.
///
/// All methods take `&self`: appends serialize on an internal writer
/// mutex, reads run against the published snapshot and never block on
/// (or block) the writer.
pub struct PartitionLog {
    config: LogConfig,
    writer: Mutex<WriterState>,
    view: ArcCell<LogView>,
    /// High-watermark mirror (log end offset), `Release`-published after
    /// every append so lag probes read it without any lock.
    next_offset: AtomicU64,
    /// Earliest retained offset, mirrored likewise.
    start_offset: AtomicU64,
    total_bytes: AtomicUsize,
}

impl std::fmt::Debug for PartitionLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PartitionLog")
            .field("start_offset", &self.start_offset())
            .field("end_offset", &self.end_offset())
            .field("total_bytes", &self.total_bytes())
            .field("segments", &self.segment_count())
            .finish()
    }
}

impl PartitionLog {
    pub fn new(config: LogConfig) -> Self {
        let seed = Segment::new(0, config.segment_bytes, index_capacity(config.segment_bytes));
        PartitionLog {
            config,
            writer: Mutex::new(WriterState {
                segments: vec![seed.clone()],
                next_offset: 0,
                total_bytes: 0,
                epoch_marks: Vec::new(),
            }),
            view: ArcCell::new(Arc::new(LogView {
                segments: vec![seed],
            })),
            next_offset: AtomicU64::new(0),
            start_offset: AtomicU64::new(0),
            total_bytes: AtomicUsize::new(0),
        }
    }

    /// Log end offset (the offset the next record will get).
    #[inline]
    pub fn end_offset(&self) -> u64 {
        self.next_offset.load(Ordering::Acquire)
    }

    /// Earliest offset still retained.
    #[inline]
    pub fn start_offset(&self) -> u64 {
        self.start_offset.load(Ordering::Acquire)
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes.load(Ordering::Relaxed)
    }

    pub fn segment_count(&self) -> usize {
        self.view.load().segments.len()
    }

    /// Seal the log for a repartition to `epoch`: record the current
    /// end offset as that epoch's watermark and return it.  Records at
    /// offsets below the watermark belong to earlier epochs; everything
    /// appended afterwards belongs to `epoch` (or later).  Idempotent
    /// per epoch.
    pub fn seal_epoch(&self, epoch: u64) -> u64 {
        self.seal_epoch_then(epoch, || {})
    }

    /// [`PartitionLog::seal_epoch`], plus run `publish` while the writer
    /// lock is still held — the repartition path stores the partition's
    /// epoch atomic there, so a concurrent fenced append either lands
    /// below the returned watermark or observes the new epoch.
    pub fn seal_epoch_then<F: FnOnce()>(&self, epoch: u64, publish: F) -> u64 {
        let mut w = self.writer.lock().unwrap();
        let sticky = match w.epoch_marks.last() {
            Some(&(e, mark)) if e >= epoch => Some(mark),
            _ => None,
        };
        let mark = match sticky {
            Some(mark) => mark,
            None => {
                let mark = w.next_offset;
                w.epoch_marks.push((epoch, mark));
                mark
            }
        };
        publish();
        mark
    }

    /// The watermark recorded when the log was sealed for `epoch`
    /// (`None` if that epoch was never sealed here).
    pub fn epoch_watermark(&self, epoch: u64) -> Option<u64> {
        self.writer
            .lock()
            .unwrap()
            .epoch_marks
            .iter()
            .find(|(e, _)| *e == epoch)
            .map(|(_, mark)| *mark)
    }

    /// Append a batch; returns the base offset of the batch.
    pub fn append_batch<'a, I>(&self, values: I, timestamp_ns: u64) -> u64
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        match self.append_batch_fenced(values, timestamp_ns, || Ok(())) {
            Ok(base) => base,
            Err(_) => unreachable!("unfenced append cannot fail"),
        }
    }

    /// Append a batch after `fence` passes under the writer lock.  The
    /// broker's produce path checks its epoch fence there, making the
    /// check atomic with the append w.r.t. [`PartitionLog::seal_epoch_then`].
    pub fn append_batch_fenced<'a, I, F>(
        &self,
        values: I,
        timestamp_ns: u64,
        fence: F,
    ) -> Result<u64>
    where
        I: IntoIterator<Item = &'a [u8]>,
        F: FnOnce() -> Result<()>,
    {
        let mut w = self.writer.lock().unwrap();
        fence()?;
        let base = w.next_offset;
        let mut publish = false;
        for v in values {
            publish |= self.ensure_active_fits(&mut w, v.len());
            let active = w.segments.last().expect("log has a segment");
            let len = u32::try_from(v.len()).expect("record larger than 4 GiB");
            // Safety: the writer mutex serializes all slab appends, and
            // `ensure_active_fits` guaranteed capacity.
            let entry = unsafe {
                let pos = active.data.append(v);
                let entry = IndexEntry {
                    pos,
                    len,
                    ts: timestamp_ns,
                };
                active.index.append(&[entry]);
                entry
            };
            w.total_bytes += entry.len as usize;
            w.next_offset += 1;
        }
        publish |= self.enforce_retention(&mut w);
        if publish {
            self.view.store(Arc::new(LogView {
                segments: w.segments.clone(),
            }));
        }
        self.start_offset.store(
            w.segments.first().map(|s| s.base_offset).unwrap_or(0),
            Ordering::Release,
        );
        self.total_bytes.store(w.total_bytes, Ordering::Relaxed);
        self.next_offset.store(w.next_offset, Ordering::Release);
        Ok(base)
    }

    /// Roll (or right-size) the active segment so a `len`-byte record
    /// fits.  Returns true if the segment list changed.
    fn ensure_active_fits(&self, w: &mut WriterState, len: usize) -> bool {
        let (fits, empty) = {
            let active = w.segments.last().expect("log has a segment");
            (
                len <= active.data.remaining() && active.index.remaining() > 0,
                active.len() == 0,
            )
        };
        if fits {
            return false;
        }
        let index_cap = index_capacity(self.config.segment_bytes);
        let data_cap = self.config.segment_bytes.max(len);
        if empty {
            // The active segment has no records yet but its slab is too
            // small (an oversized record): replace it in place with a
            // dedicated right-sized slab, keeping the base offset.
            let base = w.segments.last().unwrap().base_offset;
            *w.segments.last_mut().unwrap() = Segment::new(base, data_cap, index_cap);
        } else {
            w.segments
                .push(Segment::new(w.next_offset, data_cap, index_cap));
        }
        true
    }

    /// Drop whole sealed segments from the front while over the
    /// retention budget.  Returns true if anything was dropped.  Readers
    /// holding views of a dropped segment keep its slab alive via `Arc`.
    fn enforce_retention(&self, w: &mut WriterState) -> bool {
        let Some(limit) = self.config.retention_bytes else {
            return false;
        };
        let mut dropped = false;
        // Never drop the active segment.
        while w.segments.len() > 1 && w.total_bytes > limit {
            let seg = w.segments.remove(0);
            w.total_bytes -= seg.bytes();
            dropped = true;
        }
        dropped
    }

    /// Snapshot this log as a follower [`LogMirror`]: the replica
    /// adopts the leader's segment `Arc`s, so in-process replication
    /// copies no payload bytes — a mirror is a refcount bump per
    /// segment plus two counters.  The end offset is read *before* the
    /// segment snapshot, so every record the mirror claims is reachable
    /// through the segments it holds (a roll between the two reads can
    /// only add records past the claimed end, never lose any).
    pub fn mirror(&self) -> LogMirror {
        let end_offset = self.end_offset();
        let total_bytes = self.total_bytes();
        let view = self.view.load();
        LogMirror {
            segments: view.segments.clone(),
            end_offset,
            total_bytes,
            high_watermark: end_offset,
        }
    }

    /// Read records starting at `offset`, up to `max_bytes` of payload
    /// (at least one record if available).  Returns an error if `offset`
    /// was already garbage-collected; an empty vec if `offset` is at or
    /// past the end of the log.  Runs entirely against the published
    /// snapshot — never touches the writer lock — and the returned
    /// records are zero-copy views into the slabs.
    pub fn read(&self, offset: u64, max_bytes: usize) -> Result<Vec<Record>> {
        let view = self.view.load();
        let start = view.segments[0].base_offset;
        let last = view.segments.last().expect("log has a segment");
        let end = last.base_offset + last.len() as u64;
        if offset >= end {
            return Ok(Vec::new());
        }
        if offset < start {
            return Err(Error::Broker(format!(
                "offset {offset} below log start {start} (retention)"
            )));
        }
        // Segments are sorted by base_offset; binary search.
        let mut seg_idx = match view
            .segments
            .binary_search_by(|s| s.base_offset.cmp(&offset))
        {
            Ok(i) => i,
            Err(i) => i - 1, // i > 0: offset >= start was checked above
        };
        let mut out = Vec::new();
        let mut bytes = 0usize;
        let mut cur = offset;
        'outer: while seg_idx < view.segments.len() {
            let seg = &view.segments[seg_idx];
            let n = seg.len();
            let rel0 = (cur - seg.base_offset) as usize;
            for rel in rel0..n {
                let rec = seg.record(rel);
                if !out.is_empty() && bytes + rec.value.len() > max_bytes {
                    break 'outer;
                }
                bytes += rec.value.len();
                out.push(rec);
                cur += 1;
                if bytes >= max_bytes {
                    break 'outer;
                }
            }
            seg_idx += 1;
        }
        Ok(out)
    }
}

/// A follower's zero-copy replica of a leader partition log: the
/// leader's segment list adopted by `Arc` at replication time, plus the
/// replicated watermark.  Holding a mirror keeps every replicated slab
/// alive (the same liveness rule as [`SharedSlice`]), so a promoted
/// follower serves the full replicated prefix even after the leader
/// node is gone — without a single payload byte having been copied.
#[derive(Clone)]
pub struct LogMirror {
    segments: Vec<Segment>,
    end_offset: u64,
    total_bytes: usize,
    high_watermark: u64,
}

impl LogMirror {
    /// Offset up to which this mirror holds the leader's segments
    /// (exclusive) — what the follower has *received*.
    pub fn end_offset(&self) -> u64 {
        self.end_offset
    }

    /// Offset up to which this mirror has durably applied the leader's
    /// records (exclusive) — what the follower has *replicated*.  Under
    /// the async lag model this trails [`LogMirror::end_offset`] by the
    /// follower's modeled gap; a freshly taken mirror is fully applied.
    pub fn high_watermark(&self) -> u64 {
        self.high_watermark
    }

    /// Leader records received but not yet applied by this follower.
    pub fn gap(&self) -> u64 {
        self.end_offset.saturating_sub(self.high_watermark)
    }

    /// Advance the applied watermark for the lag model.  The watermark
    /// never moves backwards and never exceeds the received end offset.
    pub fn set_high_watermark(&mut self, offset: u64) {
        self.high_watermark = offset.min(self.end_offset).max(self.high_watermark);
    }

    /// Rebase a freshly taken mirror's applied watermark (a fresh
    /// mirror reports itself fully applied; the async replication path
    /// re-anchors it at the follower's previous watermark before
    /// advancing by the modeled catch-up).
    pub(crate) fn with_high_watermark(mut self, offset: u64) -> Self {
        self.high_watermark = offset.min(self.end_offset);
        self
    }

    /// Truncate this mirror's claimed tail to `offset` (exclusive):
    /// the KIP-101-style divergence cut a re-joining replica applies
    /// after comparing its retained log against the current leader
    /// epoch.  Accounting-level — the adopted segment `Arc`s are kept
    /// (slab payloads stay shared) but the mirror stops claiming any
    /// record at or past `offset`, and its applied watermark is pulled
    /// back with it.  Returns how many claimed records were dropped.
    /// A no-op (returns 0) when the mirror already ends at or before
    /// `offset`.
    pub fn truncate_to(&mut self, offset: u64) -> u64 {
        let dropped = self.end_offset.saturating_sub(offset);
        self.end_offset = self.end_offset.min(offset);
        self.high_watermark = self.high_watermark.min(self.end_offset);
        dropped
    }

    /// Payload bytes reachable through the adopted segments.
    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }
}

impl std::fmt::Debug for LogMirror {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogMirror")
            .field("end_offset", &self.end_offset)
            .field("high_watermark", &self.high_watermark)
            .field("total_bytes", &self.total_bytes)
            .field("segments", &self.segments.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(segment_bytes: usize, retention: Option<usize>) -> PartitionLog {
        PartitionLog::new(LogConfig {
            segment_bytes,
            retention_bytes: retention,
        })
    }

    #[test]
    fn append_assigns_sequential_offsets() {
        let log = log_with(1024, None);
        let base = log.append_batch([b"aa".as_slice(), b"bb".as_slice()], 1);
        assert_eq!(base, 0);
        let base2 = log.append_batch([b"cc".as_slice()], 2);
        assert_eq!(base2, 2);
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn read_returns_appended_values() {
        let log = log_with(1024, None);
        log.append_batch([b"hello".as_slice(), b"world".as_slice()], 7);
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].value, b"hello");
        assert_eq!(recs[0].offset, 0);
        assert_eq!(recs[0].timestamp_ns, 7);
        assert_eq!(recs[1].value, b"world");
        assert_eq!(recs[1].offset, 1);
    }

    #[test]
    fn read_past_end_is_empty() {
        let log = log_with(1024, None);
        log.append_batch([b"x".as_slice()], 0);
        assert!(log.read(1, 1024).unwrap().is_empty());
        assert!(log.read(100, 1024).unwrap().is_empty());
    }

    #[test]
    fn mirror_truncate_drops_claimed_tail_and_watermark() {
        let log = log_with(1024, None);
        log.append_batch([b"a".as_slice(), b"b".as_slice(), b"c".as_slice()], 0);
        let mut m = log.mirror();
        assert_eq!(m.end_offset(), 3);
        assert_eq!(m.high_watermark(), 3);
        assert_eq!(m.truncate_to(1), 2, "two claimed records dropped");
        assert_eq!(m.end_offset(), 1);
        assert_eq!(m.high_watermark(), 1, "watermark pulled back with the cut");
        assert_eq!(m.truncate_to(5), 0, "past-end truncation is a no-op");
        assert_eq!(m.end_offset(), 1);
        // The watermark can never be re-advanced past the truncated end.
        m.set_high_watermark(10);
        assert_eq!(m.high_watermark(), 1);
    }

    #[test]
    fn read_respects_max_bytes_but_returns_at_least_one() {
        let log = log_with(1024, None);
        log.append_batch(
            [b"0123456789".as_slice(), b"0123456789".as_slice(), b"x".as_slice()],
            0,
        );
        let recs = log.read(0, 15).unwrap();
        assert_eq!(recs.len(), 1); // second record would cross the 15-byte cap
        let recs = log.read(0, 21).unwrap();
        assert_eq!(recs.len(), 3); // 10 + 10 + 1 fits exactly at the cap boundary
        let recs = log.read(0, 1).unwrap();
        assert_eq!(recs.len(), 1, "must make progress even if record > max_bytes");
    }

    #[test]
    fn segments_roll_at_size() {
        let log = log_with(10, None);
        for _ in 0..10 {
            log.append_batch([b"123456".as_slice()], 0);
        }
        assert!(log.segment_count() >= 5, "segments={}", log.segment_count());
        // All offsets still readable across segment boundaries.
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), 10);
        assert_eq!(recs[9].offset, 9);
    }

    #[test]
    fn retention_drops_old_segments() {
        let log = log_with(10, Some(30));
        for i in 0..20u8 {
            log.append_batch([[i; 6].as_slice()], 0);
        }
        assert!(log.total_bytes() <= 36, "bytes={}", log.total_bytes());
        assert!(log.start_offset() > 0);
        // Reading a GC'd offset errors.
        assert!(log.read(0, 1024).is_err());
        // Reading from start_offset works.
        let recs = log.read(log.start_offset(), usize::MAX).unwrap();
        assert_eq!(
            recs.last().unwrap().offset,
            log.end_offset() - 1,
            "tail must be intact"
        );
    }

    #[test]
    fn views_survive_retention_eviction() {
        // The safe-by-construction eviction guarantee: a fetch that
        // started before retention dropped its segment still reads its
        // slab — the view's Arc keeps the bytes alive.
        let log = log_with(16, Some(32));
        log.append_batch([[7u8; 12].as_slice()], 1);
        let held = log.read(0, usize::MAX).unwrap();
        assert_eq!(held.len(), 1);
        // Push offset 0's segment out of retention.
        for i in 0..10u8 {
            log.append_batch([[i; 12].as_slice()], 2);
        }
        assert!(log.start_offset() > 0, "offset 0 must be evicted");
        assert!(log.read(0, usize::MAX).is_err(), "new reads error cleanly");
        // The old view still reads its original bytes.
        assert_eq!(held[0].value, [7u8; 12]);
        assert_eq!(held[0].offset, 0);
    }

    #[test]
    fn oversized_record_gets_dedicated_slab() {
        let log = log_with(8, None);
        // First record bigger than the segment size: the empty active
        // segment is right-sized in place.
        log.append_batch([[1u8; 50].as_slice()], 0);
        // And an oversized record after normal ones rolls into its own
        // dedicated slab.
        log.append_batch([[2u8; 3].as_slice()], 0);
        log.append_batch([[3u8; 40].as_slice()], 0);
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].value, [1u8; 50]);
        assert_eq!(recs[1].value, [2u8; 3]);
        assert_eq!(recs[2].value, [3u8; 40]);
    }

    #[test]
    fn reads_are_zero_copy_views() {
        let log = log_with(1024, None);
        log.append_batch([[9u8; 64].as_slice()], 0);
        let before = copytrack::payload_copies();
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs[0].value, [9u8; 64]);
        assert_eq!(
            copytrack::payload_copies(),
            before,
            "read must not materialize payloads"
        );
        // Materializing explicitly is counted (debug builds).
        let owned = recs[0].value.to_vec();
        assert_eq!(owned, vec![9u8; 64]);
        if cfg!(debug_assertions) {
            assert_eq!(copytrack::payload_copies(), before + 1);
        }
    }

    #[test]
    fn epoch_watermarks_are_sticky_and_ordered() {
        let log = log_with(1024, None);
        log.append_batch([b"a".as_slice(), b"b".as_slice()], 0);
        assert_eq!(log.seal_epoch(1), 2);
        // Sealing the same epoch again returns the original watermark.
        log.append_batch([b"c".as_slice()], 0);
        assert_eq!(log.seal_epoch(1), 2);
        assert_eq!(log.epoch_watermark(1), Some(2));
        assert_eq!(log.epoch_watermark(2), None);
        // A later epoch seals at the new end.
        assert_eq!(log.seal_epoch(2), 3);
        assert_eq!(log.epoch_watermark(1), Some(2));
        assert_eq!(log.epoch_watermark(2), Some(3));
    }

    #[test]
    fn mirror_adopts_segments_without_copying() {
        let log = log_with(64, None);
        log.append_batch([[1u8; 32].as_slice(), [2u8; 32].as_slice()], 0);
        let before = copytrack::payload_copies();
        let mirror = log.mirror();
        assert_eq!(mirror.end_offset(), 2);
        assert_eq!(mirror.total_bytes(), 64);
        assert_eq!(mirror.segment_count(), log.segment_count());
        assert_eq!(
            copytrack::payload_copies(),
            before,
            "mirroring must be Arc adoption, not a copy"
        );
        // The mirror is a snapshot: later appends move the log, not it.
        log.append_batch([[3u8; 8].as_slice()], 0);
        assert_eq!(mirror.end_offset(), 2);
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn mirror_keeps_replicated_segments_alive_past_retention() {
        // A follower that replicated before retention evicted a segment
        // still holds the bytes — the failover story's liveness rule.
        let log = log_with(16, Some(32));
        log.append_batch([[7u8; 12].as_slice()], 1);
        let mirror = log.mirror();
        for i in 0..10u8 {
            log.append_batch([[i; 12].as_slice()], 2);
        }
        assert!(log.start_offset() > 0, "offset 0 must be evicted");
        assert_eq!(mirror.end_offset(), 1, "mirror still claims its prefix");
        assert!(mirror.segment_count() >= 1);
    }

    #[test]
    fn read_from_middle_segment() {
        let log = log_with(8, None);
        for i in 0..12u8 {
            log.append_batch([[i; 4].as_slice()], 0);
        }
        let recs = log.read(7, usize::MAX).unwrap();
        assert_eq!(recs[0].offset, 7);
        assert_eq!(recs[0].value, vec![7u8; 4]);
        assert_eq!(recs.len(), 5);
    }

    #[test]
    fn concurrent_append_and_read() {
        // Readers chase a writer through rolls and retention without
        // locks; every record they see must be byte-identical to the
        // deterministic pattern for its offset.
        let log = Arc::new(log_with(256, Some(1024)));
        let pattern = |off: u64| vec![(off % 251) as u8; 17];
        let writer = {
            let log = log.clone();
            std::thread::spawn(move || {
                for off in 0..2000u64 {
                    log.append_batch([pattern(off).as_slice()], off);
                }
            })
        };
        let mut checked = 0u64;
        while checked < 2000 {
            let from = log.start_offset().max(checked);
            match log.read(from, 4096) {
                Ok(recs) => {
                    for r in &recs {
                        assert_eq!(r.value, pattern(r.offset), "offset {}", r.offset);
                    }
                    if let Some(last) = recs.last() {
                        checked = last.offset + 1;
                    }
                }
                // `from` raced retention; skip forward.
                Err(_) => checked = log.start_offset(),
            }
        }
        writer.join().unwrap();
    }
}
