//! Segmented append-only partition log (the Kafka storage model).
//!
//! A partition is a sequence of segments; each segment stores record
//! payloads contiguously plus a sparse-free in-memory index of
//! `(position, length, timestamp)` per record.  Appends go to the active
//! segment; reads are offset-addressed and return copies (the broker is
//! in-process, but we deliberately copy to model the network boundary —
//! the caller pays the same per-byte costs a remote client would).

use crate::error::{Error, Result};

/// One immutable-once-rolled log segment.
#[derive(Debug)]
pub struct Segment {
    /// Offset of the first record in this segment.
    pub base_offset: u64,
    /// Contiguous record payloads.
    data: Vec<u8>,
    /// Per record: (position in `data`, length, timestamp ns).
    index: Vec<(u32, u32, u64)>,
}

impl Segment {
    fn new(base_offset: u64, capacity: usize) -> Self {
        Segment {
            base_offset,
            // Preallocate the full segment (§Perf L3-1): Vec doubling on
            // a 64 MB segment costs a ~32 MB memmove at the worst moment
            // (p95 append spikes).  Reserved-but-untouched pages are not
            // committed by the OS, so this is virtually free.
            data: Vec::with_capacity(capacity),
            index: Vec::new(),
        }
    }

    fn len(&self) -> usize {
        self.index.len()
    }

    fn bytes(&self) -> usize {
        self.data.len()
    }

    fn append(&mut self, value: &[u8], timestamp_ns: u64) {
        let pos = self.data.len() as u32;
        self.data.extend_from_slice(value);
        self.index.push((pos, value.len() as u32, timestamp_ns));
    }

    fn get(&self, rel: usize) -> (&[u8], u64) {
        let (pos, len, ts) = self.index[rel];
        (&self.data[pos as usize..(pos + len) as usize], ts)
    }
}

/// A record as returned from a fetch.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Absolute offset within the partition.
    pub offset: u64,
    /// Broker-side append timestamp (ns since producer epoch).
    pub timestamp_ns: u64,
    /// Payload bytes.
    pub value: Vec<u8>,
}

/// Configuration for a partition log.
#[derive(Debug, Clone, Copy)]
pub struct LogConfig {
    /// Roll the active segment after this many payload bytes.
    pub segment_bytes: usize,
    /// Drop whole old segments once total bytes exceed this (None = keep
    /// everything).  Mirrors Kafka size-based retention.
    pub retention_bytes: Option<usize>,
}

impl Default for LogConfig {
    fn default() -> Self {
        LogConfig {
            segment_bytes: 64 << 20, // 64 MB
            retention_bytes: Some(512 << 20),
        }
    }
}

/// The partition log: segments + high watermark.
#[derive(Debug)]
pub struct PartitionLog {
    config: LogConfig,
    segments: Vec<Segment>,
    /// Next offset to be assigned (aka log end offset / high watermark).
    next_offset: u64,
    total_bytes: usize,
    /// Repartition fences: `(epoch, end_offset_at_seal)` per sealed
    /// epoch, ascending.  Everything below the watermark of epoch `e`
    /// was appended before the topic transitioned *to* epoch `e` — the
    /// boundary consumer groups drain to before serving epoch `e` data.
    epoch_marks: Vec<(u64, u64)>,
}

impl PartitionLog {
    pub fn new(config: LogConfig) -> Self {
        PartitionLog {
            segments: vec![Segment::new(0, config.segment_bytes)],
            config,
            next_offset: 0,
            total_bytes: 0,
            epoch_marks: Vec::new(),
        }
    }

    /// Log end offset (the offset the next record will get).
    pub fn end_offset(&self) -> u64 {
        self.next_offset
    }

    /// Earliest offset still retained.
    pub fn start_offset(&self) -> u64 {
        self.segments.first().map(|s| s.base_offset).unwrap_or(0)
    }

    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    pub fn segment_count(&self) -> usize {
        self.segments.len()
    }

    /// Seal the log for a repartition to `epoch`: record the current
    /// end offset as that epoch's watermark and return it.  Records at
    /// offsets below the watermark belong to earlier epochs; everything
    /// appended afterwards belongs to `epoch` (or later).  Idempotent
    /// per epoch.
    pub fn seal_epoch(&mut self, epoch: u64) -> u64 {
        if let Some((e, mark)) = self.epoch_marks.last() {
            if *e >= epoch {
                return *mark;
            }
        }
        self.epoch_marks.push((epoch, self.next_offset));
        self.next_offset
    }

    /// The watermark recorded when the log was sealed for `epoch`
    /// (`None` if that epoch was never sealed here).
    pub fn epoch_watermark(&self, epoch: u64) -> Option<u64> {
        self.epoch_marks
            .iter()
            .find(|(e, _)| *e == epoch)
            .map(|(_, mark)| *mark)
    }

    /// Append a batch; returns the base offset of the batch.
    pub fn append_batch<'a, I>(&mut self, values: I, timestamp_ns: u64) -> u64
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        let base = self.next_offset;
        for v in values {
            let active = self.segments.last_mut().expect("log has a segment");
            if active.bytes() + v.len() > self.config.segment_bytes && active.len() > 0 {
                let next_base = self.next_offset;
                self.segments
                    .push(Segment::new(next_base, self.config.segment_bytes));
            }
            let active = self.segments.last_mut().unwrap();
            active.append(v, timestamp_ns);
            self.total_bytes += v.len();
            self.next_offset += 1;
        }
        self.enforce_retention();
        base
    }

    fn enforce_retention(&mut self) {
        let Some(limit) = self.config.retention_bytes else {
            return;
        };
        // Never drop the active segment.
        while self.segments.len() > 1 && self.total_bytes > limit {
            let seg = self.segments.remove(0);
            self.total_bytes -= seg.bytes();
        }
    }

    fn segment_for(&self, offset: u64) -> Option<usize> {
        if offset >= self.next_offset {
            return None;
        }
        // Segments are sorted by base_offset; binary search.
        match self
            .segments
            .binary_search_by(|s| s.base_offset.cmp(&offset))
        {
            Ok(i) => Some(i),
            Err(0) => None, // before the earliest retained offset
            Err(i) => Some(i - 1),
        }
    }

    /// Read records starting at `offset`, up to `max_bytes` of payload
    /// (at least one record if available).  Returns an error if `offset`
    /// was already garbage-collected; an empty vec if `offset` is at or
    /// past the end of the log.
    pub fn read(&self, offset: u64, max_bytes: usize) -> Result<Vec<Record>> {
        if offset >= self.next_offset {
            return Ok(Vec::new());
        }
        if offset < self.start_offset() {
            return Err(Error::Broker(format!(
                "offset {} below log start {} (retention)",
                offset,
                self.start_offset()
            )));
        }
        let mut out = Vec::new();
        let mut bytes = 0usize;
        let mut seg_idx = self
            .segment_for(offset)
            .ok_or_else(|| Error::Broker(format!("offset {offset} not found")))?;
        let mut cur = offset;
        'outer: while seg_idx < self.segments.len() {
            let seg = &self.segments[seg_idx];
            let rel0 = (cur - seg.base_offset) as usize;
            for rel in rel0..seg.len() {
                let (value, ts) = seg.get(rel);
                if !out.is_empty() && bytes + value.len() > max_bytes {
                    break 'outer;
                }
                bytes += value.len();
                out.push(Record {
                    offset: seg.base_offset + rel as u64,
                    timestamp_ns: ts,
                    value: value.to_vec(),
                });
                cur += 1;
                if bytes >= max_bytes {
                    break 'outer;
                }
            }
            seg_idx += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with(segment_bytes: usize, retention: Option<usize>) -> PartitionLog {
        PartitionLog::new(LogConfig {
            segment_bytes,
            retention_bytes: retention,
        })
    }

    #[test]
    fn append_assigns_sequential_offsets() {
        let mut log = log_with(1024, None);
        let base = log.append_batch([b"aa".as_slice(), b"bb".as_slice()], 1);
        assert_eq!(base, 0);
        let base2 = log.append_batch([b"cc".as_slice()], 2);
        assert_eq!(base2, 2);
        assert_eq!(log.end_offset(), 3);
    }

    #[test]
    fn read_returns_appended_values() {
        let mut log = log_with(1024, None);
        log.append_batch([b"hello".as_slice(), b"world".as_slice()], 7);
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].value, b"hello");
        assert_eq!(recs[0].offset, 0);
        assert_eq!(recs[0].timestamp_ns, 7);
        assert_eq!(recs[1].value, b"world");
        assert_eq!(recs[1].offset, 1);
    }

    #[test]
    fn read_past_end_is_empty() {
        let mut log = log_with(1024, None);
        log.append_batch([b"x".as_slice()], 0);
        assert!(log.read(1, 1024).unwrap().is_empty());
        assert!(log.read(100, 1024).unwrap().is_empty());
    }

    #[test]
    fn read_respects_max_bytes_but_returns_at_least_one() {
        let mut log = log_with(1024, None);
        log.append_batch(
            [b"0123456789".as_slice(), b"0123456789".as_slice(), b"x".as_slice()],
            0,
        );
        let recs = log.read(0, 15).unwrap();
        assert_eq!(recs.len(), 1); // second record would cross the 15-byte cap
        let recs = log.read(0, 21).unwrap();
        assert_eq!(recs.len(), 3); // 10 + 10 + 1 fits exactly at the cap boundary
        let recs = log.read(0, 1).unwrap();
        assert_eq!(recs.len(), 1, "must make progress even if record > max_bytes");
    }

    #[test]
    fn segments_roll_at_size() {
        let mut log = log_with(10, None);
        for _ in 0..10 {
            log.append_batch([b"123456".as_slice()], 0);
        }
        assert!(log.segment_count() >= 5, "segments={}", log.segment_count());
        // All offsets still readable across segment boundaries.
        let recs = log.read(0, usize::MAX).unwrap();
        assert_eq!(recs.len(), 10);
        assert_eq!(recs[9].offset, 9);
    }

    #[test]
    fn retention_drops_old_segments() {
        let mut log = log_with(10, Some(30));
        for i in 0..20u8 {
            log.append_batch([[i; 6].as_slice()], 0);
        }
        assert!(log.total_bytes() <= 36, "bytes={}", log.total_bytes());
        assert!(log.start_offset() > 0);
        // Reading a GC'd offset errors.
        assert!(log.read(0, 1024).is_err());
        // Reading from start_offset works.
        let recs = log.read(log.start_offset(), usize::MAX).unwrap();
        assert_eq!(
            recs.last().unwrap().offset,
            log.end_offset() - 1,
            "tail must be intact"
        );
    }

    #[test]
    fn epoch_watermarks_are_sticky_and_ordered() {
        let mut log = log_with(1024, None);
        log.append_batch([b"a".as_slice(), b"b".as_slice()], 0);
        assert_eq!(log.seal_epoch(1), 2);
        // Sealing the same epoch again returns the original watermark.
        log.append_batch([b"c".as_slice()], 0);
        assert_eq!(log.seal_epoch(1), 2);
        assert_eq!(log.epoch_watermark(1), Some(2));
        assert_eq!(log.epoch_watermark(2), None);
        // A later epoch seals at the new end.
        assert_eq!(log.seal_epoch(2), 3);
        assert_eq!(log.epoch_watermark(1), Some(2));
        assert_eq!(log.epoch_watermark(2), Some(3));
    }

    #[test]
    fn read_from_middle_segment() {
        let mut log = log_with(8, None);
        for i in 0..12u8 {
            log.append_batch([[i; 4].as_slice()], 0);
        }
        let recs = log.read(7, usize::MAX).unwrap();
        assert_eq!(recs[0].offset, 7);
        assert_eq!(recs[0].value, vec![7u8; 4]);
        assert_eq!(recs.len(), 5);
    }
}
