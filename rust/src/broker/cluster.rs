//! The broker cluster: topics, partition leadership, group coordination.
//!
//! This is the repo's Kafka substrate (DESIGN.md §3): a log-based
//! publish/subscribe broker whose data plane is real (bytes move through
//! [`PartitionLog`]s, blocking fetches wake on appends) while node
//! boundaries come from the simulated [`Machine`] — every produce/fetch
//! pays the NIC/disk token-bucket costs of the nodes involved, so broker
//! I/O saturation (the effect behind Figs 8/9) is observable in-process.
//!
//! Hot-path locking (§Perf L3): the topics map and broker-node list are
//! copy-on-write snapshots behind [`ArcCell`]s — control-plane writers
//! (create/repartition/extend) publish new snapshots; produce/fetch
//! resolve against the current one without ever taking a global mutex,
//! and clients holding an `Arc<Topic>` handle skip even that (see
//! [`BrokerCluster::produce_to`] / [`BrokerCluster::fetch_from`]).
//! Within a partition, appends serialize on the log's narrow writer
//! lock while fetches read a published segment snapshot, so readers
//! never contend with producers.
//!
//! Sharded data plane (§Perf L4, see [`super::shard`]): every partition
//! is owned by exactly one of N thread-per-core shards
//! ([`super::shard::shard_of`] over the jump-consistent hash), and all
//! fetch wakeups go through the owning shard's coalesced doorbell —
//! producers ring once per append batch, fetchers park per shard — so
//! produce/fetch synchronization never bounces cache lines across
//! every core the way the old per-partition `Condvar` did.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::cluster::{Machine, NodeId};
use crate::error::{Error, Result};
use crate::metrics::ScalingTimeline;
use crate::util::ArcCell;

use super::log::{LogConfig, PartitionLog, Record};
use super::repartition::EpochTransition;
use super::replication::{
    AckMode, DepartedBroker, FailoverEvent, ReplicaSet, ReplicationConfig,
};
use super::shard::{default_shards, Shard, ShardSet, ShardStats, QUIESCE_SLICE, QUIESCE_WAIT_MAX};

/// One partition: leader broker node + the log + fetch wakeups.
pub struct Partition {
    pub id: usize,
    /// Index into the cluster's broker-node list (leadership moves on
    /// rebalance).
    leader: AtomicUsize,
    pub(super) log: PartitionLog,
    /// The data-plane shard that owns this partition: its doorbell is
    /// where this partition's fetchers park and its producers ring —
    /// see [`super::shard`].
    pub(super) shard: Arc<Shard>,
    /// Topic epoch this partition's next append belongs to.  Bumped
    /// under the log's writer lock when a repartition seals the log, so
    /// a produce that routed under an older partition-set epoch is
    /// detected (and rejected as [`Error::StaleEpoch`]) instead of
    /// landing above the fence consumers drain to.
    pub(super) epoch: AtomicU64,
    /// Replica set: broker node ids in priority order (leader first)
    /// plus each follower's adopted log mirror — see
    /// [`super::replication`].
    pub(super) replicas: Mutex<ReplicaSet>,
    /// Replication high watermark: fetches only see offsets below it,
    /// so a record is never served before it is on every alive replica.
    /// Advanced monotonically via `fetch_max` (racing producers can
    /// publish their ends out of order).  Replication is synchronous
    /// in-process, so after every produce this equals the log end —
    /// unreplicated topics behave exactly as before.
    pub(super) high_watermark: AtomicU64,
}

impl Partition {
    pub(super) fn new(
        id: usize,
        leader: usize,
        epoch: u64,
        config: LogConfig,
        shard: Arc<Shard>,
    ) -> Self {
        Partition {
            id,
            leader: AtomicUsize::new(leader),
            log: PartitionLog::new(config),
            shard,
            epoch: AtomicU64::new(epoch),
            replicas: Mutex::new(ReplicaSet::default()),
            high_watermark: AtomicU64::new(0),
        }
    }

    /// The data-plane shard that owns this partition.
    pub fn shard_id(&self) -> usize {
        self.shard.id()
    }

    pub fn leader_index(&self) -> usize {
        self.leader.load(Ordering::Relaxed)
    }

    pub(super) fn set_leader_index(&self, idx: usize) {
        self.leader.store(idx, Ordering::Relaxed);
    }

    /// This partition's replica set: broker node ids in priority order
    /// (leader first; failover promotes the first surviving entry).
    pub fn replica_nodes(&self) -> Vec<NodeId> {
        self.replicas.lock().unwrap().nodes.clone()
    }

    /// High watermark — a lock-free atomic read, so lag probes (consumer
    /// gauges, the autoscaler, the micro-batch driver) never touch the
    /// write path.
    pub fn end_offset(&self) -> u64 {
        self.log.end_offset()
    }

    /// Ring the owning shard's doorbell after publishing this
    /// partition's watermark — once per append *batch*, coalesced away
    /// entirely when no fetcher is parked on the shard (see
    /// [`super::shard::Shard::ring`] for the lost-wakeup pairing).
    pub(super) fn notify_data(&self) {
        self.shard.ring();
    }
}

/// A topic: a named, epoch-stamped partition set.
///
/// Repartitioning never removes entries from `partitions` — a shrink
/// retires a suffix (still readable while consumer groups drain it)
/// and a grow appends or re-activates entries — so partition ids stay
/// stable across epochs and committed offsets survive every resize.
pub struct Topic {
    pub name: String,
    /// Every partition ever created for this topic, by id.
    pub partitions: Vec<Arc<Partition>>,
    /// Partitions accepting new writes in the current epoch (a prefix
    /// of `partitions`).
    pub(super) active: usize,
    /// Repartition epoch: 0 at creation, +1 per resize.
    pub(super) epoch: u64,
    /// One entry per epoch transition, ascending by epoch.
    pub(super) transitions: Vec<EpochTransition>,
    /// Replication configuration (factor, ack mode, min in-sync) every
    /// partition of this topic carries.
    pub(super) replication: ReplicationConfig,
}

impl Topic {
    /// Partitions accepting new writes in the current epoch.
    pub fn active_partitions(&self) -> usize {
        self.active
    }

    /// Current repartition epoch (0 until the first resize).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Whether this handle still describes the live partition set.
    /// Every repartition bumps every partition's epoch atomic (shared
    /// between the old and new `Topic` snapshots), so a handle whose
    /// recorded epoch matches partition 0's live epoch is current —
    /// a lock-free staleness probe clients use to cache handles.
    pub fn is_current(&self) -> bool {
        self.partitions[0].epoch.load(Ordering::Acquire) == self.epoch
    }

    /// Replication configuration this topic was created with.
    pub fn replication(&self) -> ReplicationConfig {
        self.replication
    }
}

/// Consumer-group coordination state for one (group, topic).
#[derive(Debug, Default)]
pub(super) struct GroupState {
    /// Monotonic membership generation; bumped on join/leave, on every
    /// topic repartition, and on every epoch advance.
    pub(super) generation: u64,
    pub(super) members: Vec<u64>,
    /// Committed offsets per partition.
    pub(super) offsets: HashMap<usize, u64>,
    pub(super) next_member_id: u64,
    /// The topic epoch this group is serving.  While it trails the
    /// topic's epoch the group is draining: fetches are capped at the
    /// next transition's fences, and the epoch advances (bumping the
    /// generation) once every fence is committed.
    pub(super) epoch: u64,
}

pub(super) struct Inner {
    pub(super) machine: Machine,
    /// Copy-on-write broker-node list (snapshot per control-plane edit).
    pub(super) broker_nodes: ArcCell<Vec<NodeId>>,
    /// Copy-on-write topics map: produce/fetch load the snapshot; only
    /// create/repartition publish new ones (serialized by `control`).
    pub(super) topics: ArcCell<HashMap<String, Arc<Topic>>>,
    /// Serializes control-plane mutations (topic create/repartition,
    /// broker add/remove) — the data plane never takes it.
    pub(super) control: Mutex<()>,
    pub(super) groups: Mutex<HashMap<(String, String), GroupState>>,
    /// The fixed thread-per-core shard set every partition maps onto
    /// ([`super::shard::shard_of`]); sized at cluster creation.
    pub(super) shards: ShardSet,
    pub(super) log_config: LogConfig,
    pub(super) stopped: AtomicBool,
    pub(super) epoch: Instant,
    /// Timelines that record a `Failover` event per broker-node death
    /// (see [`BrokerCluster::add_scaling_timeline`]).
    pub(super) timelines: Mutex<Vec<Arc<ScalingTimeline>>>,
    /// Queued failover notifications the autoscale control loop drains
    /// ([`BrokerCluster::take_failover_events`]).
    pub(super) failover_events: Mutex<Vec<FailoverEvent>>,
    /// Append-only ring of every broker node this cluster has ever
    /// known, in first-seen order.  Group-coordinator placement
    /// jump-hashes over this *stable* list (walking past dead nodes),
    /// so unrelated membership churn does not remap coordinators the
    /// way hashing over the alive list did.
    pub(super) coordinator_ring: Mutex<Vec<NodeId>>,
    /// Failure-domain labels: broker node → rack id.  Empty = unracked
    /// (placement stays pure ring order).  Labels persist across node
    /// death so a re-joining broker returns to its old domain — see
    /// [`BrokerCluster::set_racks`].
    pub(super) racks: Mutex<HashMap<NodeId, usize>>,
    /// Replica placements forced to co-locate two replicas in one rack
    /// because no anti-affine slot existed (see
    /// [`BrokerCluster::rack_constraint_violations`]).
    pub(super) rack_constraint_violations: AtomicU64,
    /// Retained replica state of killed brokers, keyed by node: the
    /// mirrors each victim held at death plus per-partition divergence
    /// fences, consumed by [`BrokerCluster::rejoin_broker`].
    pub(super) departed: Mutex<HashMap<NodeId, DepartedBroker>>,
}

/// One broker node's cumulative I/O counters and bucket capacities
/// (see [`BrokerCluster::broker_io`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BrokerIoStat {
    pub node: NodeId,
    /// NIC bytes received by this node (produce ingress).  Kept
    /// separate from egress so a one-directional saturation (producers
    /// flooding a broker whose consumers stalled) reads as full
    /// utilization of that direction's bucket.
    pub nic_in_bytes: u64,
    /// NIC bytes sent by this node (fetch egress).
    pub nic_out_bytes: u64,
    /// Disk bytes appended on this node (log writes).
    pub disk_bytes: u64,
    /// NIC capacity, bytes/sec per direction (`None` = unthrottled).
    /// [`crate::cluster::Machine`] builds ingress and egress from the
    /// same configured `nic_mbps`, so one rate covers both directions;
    /// an asymmetric machine shape would need a second field here.
    pub nic_rate: Option<f64>,
    /// Disk capacity, bytes/sec (`None` = unthrottled).
    pub disk_rate: Option<f64>,
}

/// Cloneable handle to a broker cluster.
#[derive(Clone)]
pub struct BrokerCluster {
    pub(super) inner: Arc<Inner>,
}

impl std::fmt::Debug for BrokerCluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BrokerCluster")
            .field("brokers", &self.inner.broker_nodes.load().len())
            .field("topics", &self.inner.topics.load().len())
            .finish()
    }
}

impl BrokerCluster {
    /// Create a broker cluster on `broker_nodes` of `machine`.
    pub fn new(machine: Machine, broker_nodes: Vec<NodeId>) -> Self {
        Self::with_log_config(machine, broker_nodes, LogConfig::default())
    }

    pub fn with_log_config(
        machine: Machine,
        broker_nodes: Vec<NodeId>,
        log_config: LogConfig,
    ) -> Self {
        Self::with_shards(machine, broker_nodes, log_config, default_shards())
    }

    /// [`BrokerCluster::with_log_config`] with an explicit data-plane
    /// shard count (defaults to one shard per available core, clamped
    /// to `1..=32`).  Benches pin the count to the contention way-count
    /// under test; `1` reproduces the pre-shard single-doorbell plane.
    pub fn with_shards(
        machine: Machine,
        broker_nodes: Vec<NodeId>,
        log_config: LogConfig,
        n_shards: usize,
    ) -> Self {
        assert!(!broker_nodes.is_empty(), "broker cluster needs >= 1 node");
        let ring = broker_nodes.clone();
        BrokerCluster {
            inner: Arc::new(Inner {
                machine,
                broker_nodes: ArcCell::new(Arc::new(broker_nodes)),
                topics: ArcCell::new(Arc::new(HashMap::new())),
                control: Mutex::new(()),
                groups: Mutex::new(HashMap::new()),
                shards: ShardSet::new(n_shards),
                log_config,
                stopped: AtomicBool::new(false),
                epoch: Instant::now(),
                timelines: Mutex::new(Vec::new()),
                failover_events: Mutex::new(Vec::new()),
                coordinator_ring: Mutex::new(ring),
                racks: Mutex::new(HashMap::new()),
                rack_constraint_violations: AtomicU64::new(0),
                departed: Mutex::new(HashMap::new()),
            }),
        }
    }

    /// [`BrokerCluster::new`] with `racks` failure domains: the broker
    /// node at position `i` of `broker_nodes` is labeled rack
    /// `i % racks`.  Replica placement becomes rack-anti-affine (leader
    /// and followers spread across distinct domains where possible) and
    /// [`BrokerCluster::kill_rack`] can take a whole domain down
    /// atomically.
    pub fn with_racks(machine: Machine, broker_nodes: Vec<NodeId>, racks: usize) -> Self {
        let c = Self::new(machine, broker_nodes);
        c.set_racks(racks);
        c
    }

    /// (Re)label the alive brokers into `racks` failure domains, node
    /// at membership position `i` → rack `i % racks` (0 clears every
    /// label).  Labels steer *subsequent* replica placement — topic
    /// creation, heal-path refills, reassignment — and persist across
    /// node death, so a killed broker re-joins its old domain.
    /// Existing replica sets are not reshuffled by relabeling alone;
    /// [`BrokerCluster::reassign_replicas`] migrates them on demand.
    pub fn set_racks(&self, racks: usize) {
        let _control = self.inner.control.lock().unwrap();
        let brokers = self.inner.broker_nodes.load();
        let mut map = self.inner.racks.lock().unwrap();
        map.clear();
        if racks == 0 {
            return;
        }
        for (i, b) in brokers.iter().enumerate() {
            map.insert(*b, i % racks);
        }
    }

    /// The failure domain `node` is labeled with (`None` when unracked
    /// or unknown).  Answers for dead nodes too: labels survive death
    /// so a re-join lands back in the old domain.
    pub fn rack_of(&self, node: NodeId) -> Option<usize> {
        self.inner.racks.lock().unwrap().get(&node).copied()
    }

    /// How many replica placements were forced to co-locate two
    /// replicas in one rack because no anti-affine slot existed (the
    /// explicit fallback counter: rack constraints are best-effort, a
    /// tier with fewer domains than the factor still places every
    /// replica).  Cumulative across all placement passes.
    pub fn rack_constraint_violations(&self) -> u64 {
        self.inner.rack_constraint_violations.load(Ordering::Relaxed)
    }

    /// Number of data-plane shards (fixed at creation).
    pub fn n_shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Point-in-time counters of every data-plane shard — parked-
    /// fetcher queue depth (current + peak), doorbell ring/notify
    /// counts, and the quiesce flag.  The autoscale probe exports the
    /// depths as a planner signal (a persistently deep shard next to
    /// idle siblings means partitions hash unevenly onto shards).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.inner.shards.stats()
    }

    /// Chaos hook: quiesce the shard owning `topic`/`partition`, as a
    /// crashed repartition would, and return the shard id.  Parked
    /// fetchers downgrade to bounded waits and surface
    /// [`Error::ShardQuiesced`] after the grace window instead of
    /// sleeping forever — see [`BrokerCluster::resume_partition_shard`].
    pub fn quiesce_partition_shard(&self, topic: &str, partition: usize) -> Result<usize> {
        let t = self.topic(topic)?;
        let p = t.partitions.get(partition).ok_or_else(|| {
            Error::Broker(format!("{topic}/{partition}: no such partition"))
        })?;
        p.shard.quiesce();
        Ok(p.shard.id())
    }

    /// Chaos hook: resume the shard owning `topic`/`partition` (undo
    /// [`BrokerCluster::quiesce_partition_shard`]), waking parked
    /// fetchers back to full-length waits.  Returns the shard id.
    pub fn resume_partition_shard(&self, topic: &str, partition: usize) -> Result<usize> {
        let t = self.topic(topic)?;
        let p = t.partitions.get(partition).ok_or_else(|| {
            Error::Broker(format!("{topic}/{partition}: no such partition"))
        })?;
        p.shard.resume();
        Ok(p.shard.id())
    }

    pub fn machine(&self) -> &Machine {
        &self.inner.machine
    }

    pub fn broker_nodes(&self) -> Vec<NodeId> {
        self.inner.broker_nodes.load().as_ref().clone()
    }

    /// Per-broker-node I/O counters and capacities — the broker-tier
    /// saturation signals.  Every produce/fetch pays NIC and disk
    /// token-bucket costs on the nodes involved; exporting the raw
    /// counters (plus each bucket's configured rate) lets the autoscale
    /// probe derive first-class per-node utilization gauges by finite
    /// difference, so the planner can weigh broker-tier pressure
    /// against processing-tier lag.
    pub fn broker_io(&self) -> Vec<BrokerIoStat> {
        self.broker_nodes()
            .into_iter()
            .map(|id| {
                let node = self.inner.machine.node(id);
                BrokerIoStat {
                    node: id,
                    nic_in_bytes: node.ingress.acquired_bytes(),
                    nic_out_bytes: node.egress.acquired_bytes(),
                    disk_bytes: node.disk.acquired_bytes(),
                    nic_rate: node.ingress.rate(),
                    disk_rate: node.disk.rate(),
                }
            })
            .collect()
    }

    fn now_ns(&self) -> u64 {
        self.inner.epoch.elapsed().as_nanos() as u64
    }

    /// Nanoseconds since this cluster's epoch — the clock record
    /// timestamps are stamped with (used for end-to-end latency probes).
    pub fn elapsed_ns(&self) -> u64 {
        self.now_ns()
    }

    /// Wall-clock ns since Unix epoch (for cross-component latency stamps).
    pub fn wallclock_ns() -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64
    }

    pub(super) fn check_running(&self) -> Result<()> {
        if self.inner.stopped.load(Ordering::Relaxed) {
            return Err(Error::Broker("broker cluster is stopped".into()));
        }
        Ok(())
    }

    /// Create an unreplicated topic (`factor` 1) with `partitions`
    /// partitions; leaders assigned round-robin over broker nodes.
    /// Errors if the topic exists.
    pub fn create_topic(&self, name: &str, partitions: usize) -> Result<()> {
        self.create_topic_replicated(name, partitions, ReplicationConfig::default())
    }

    /// [`BrokerCluster::create_topic`] with a per-partition replica
    /// set: partition `i` is led by broker `i % n` with followers on
    /// the next `factor - 1` brokers of the ring, each adopting the
    /// leader's shared-slab segments (see [`super::replication`]).
    /// Rejects a factor of 0 or one exceeding the broker tier.
    pub fn create_topic_replicated(
        &self,
        name: &str,
        partitions: usize,
        replication: ReplicationConfig,
    ) -> Result<()> {
        self.check_running()?;
        if partitions == 0 {
            return Err(Error::Broker("topic needs >= 1 partition".into()));
        }
        let _control = self.inner.control.lock().unwrap();
        let brokers = self.inner.broker_nodes.load();
        replication.validate(brokers.len())?;
        let topics = self.inner.topics.load();
        if topics.contains_key(name) {
            return Err(Error::Broker(format!("topic {name} already exists")));
        }
        let parts: Vec<Arc<Partition>> = (0..partitions)
            .map(|i| {
                Arc::new(Partition::new(
                    i,
                    i % brokers.len(),
                    0,
                    self.inner.log_config,
                    self.inner.shards.shard_for(i),
                ))
            })
            .collect();
        self.assign_replica_sets(&parts, replication.factor, &brokers);
        let mut next = topics.as_ref().clone();
        next.insert(
            name.to_string(),
            Arc::new(Topic {
                name: name.to_string(),
                partitions: parts,
                active: partitions,
                epoch: 0,
                transitions: Vec::new(),
                replication,
            }),
        );
        self.inner.topics.store(Arc::new(next));
        Ok(())
    }

    /// Resolve a topic handle from the current snapshot — no global
    /// lock on this path.  Hot callers (producers, consumers, the
    /// micro-batch driver) cache the returned `Arc` and revalidate it
    /// lock-free via [`Topic::is_current`].
    pub fn topic(&self, name: &str) -> Result<Arc<Topic>> {
        self.inner
            .topics
            .load()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Broker(format!("unknown topic {name}")))
    }

    /// Partitions accepting new writes (producer routing / engine task
    /// parallelism).  After a shrink this is smaller than the number of
    /// still-readable partitions; see [`BrokerCluster::total_partitions`].
    pub fn partition_count(&self, topic: &str) -> Result<usize> {
        Ok(self.topic(topic)?.active)
    }

    /// All partitions ever created, including suffixes retired by a
    /// shrink that consumer groups may still be draining.
    pub fn total_partitions(&self, topic: &str) -> Result<usize> {
        Ok(self.topic(topic)?.partitions.len())
    }

    /// Current repartition epoch of a topic (0 until the first resize).
    pub fn topic_epoch(&self, topic: &str) -> Result<u64> {
        Ok(self.topic(topic)?.epoch)
    }

    /// Leader broker *node id* for a partition of an already-resolved
    /// topic handle.
    fn leader_of(&self, t: &Topic, partition: usize) -> Result<NodeId> {
        let p = t.partitions.get(partition).ok_or_else(|| {
            Error::Broker(format!("{}/{partition}: no such partition", t.name))
        })?;
        let brokers = self.inner.broker_nodes.load();
        Ok(brokers[p.leader_index() % brokers.len()])
    }

    /// Leader broker *node id* for a topic partition.
    pub fn leader_node(&self, topic: &str, partition: usize) -> Result<NodeId> {
        let t = self.topic(topic)?;
        self.leader_of(&t, partition)
    }

    /// Produce a batch of values to a partition from `from_node`.
    ///
    /// Pays: producer-node egress, leader ingress, leader disk. Returns
    /// the batch base offset.
    pub fn produce(
        &self,
        topic: &str,
        partition: usize,
        from_node: NodeId,
        values: &[Vec<u8>],
    ) -> Result<u64> {
        let t = self.topic(topic)?;
        self.produce_to(&t, partition, from_node, values)
    }

    /// [`BrokerCluster::produce`] against a cached topic handle — the
    /// producer hot path, which never touches the topics snapshot.  A
    /// stale handle is harmless: the per-partition epoch fence rejects
    /// the append ([`Error::StaleEpoch`]) and the caller re-resolves.
    pub fn produce_to(
        &self,
        t: &Topic,
        partition: usize,
        from_node: NodeId,
        values: &[Vec<u8>],
    ) -> Result<u64> {
        self.check_running()?;
        if partition >= t.active {
            return if partition < t.partitions.len() {
                Err(Error::StaleEpoch(format!(
                    "{}/{partition}: partition retired at epoch {}",
                    t.name, t.epoch
                )))
            } else {
                Err(Error::Broker(format!(
                    "{}/{partition}: no such partition",
                    t.name
                )))
            };
        }
        let p = &t.partitions[partition];
        let leader = self.leader_of(t, partition)?;
        let bytes: usize = values.iter().map(|v| v.len()).sum();

        // Quorum acks sacrifice availability for durability: while the
        // ISR is below `min_insync`, reject the produce instead of
        // acking a record a node death could lose.  A heartbeat pass
        // runs first so a follower whose lag cleared re-enters the ISR
        // and lifts the rejection without a successful produce.
        let rep = t.replication;
        if rep.ack_mode == AckMode::Quorum {
            self.sync_partition_followers(p, &rep, 0);
            let in_sync = p.replicas.lock().unwrap().isr.len();
            if in_sync < rep.min_insync {
                return Err(Error::NotEnoughInSyncReplicas {
                    topic: t.name.clone(),
                    partition,
                    isr: in_sync,
                    min_insync: rep.min_insync,
                });
            }
        }

        // Data-plane costs: sender NIC out, leader NIC in, leader disk.
        self.inner.machine.node(from_node).egress.acquire(bytes);
        self.inner.machine.node(leader).ingress.acquire(bytes);
        self.inner.machine.node(leader).disk.acquire(bytes);

        let ts = self.now_ns();
        // Epoch fence, checked under the log's writer lock: if a
        // repartition sealed this log after we routed (the topic handle
        // is already stale), the append must not land above the fence —
        // the caller re-routes under the new partition set instead.
        let base = p.log.append_batch_fenced(
            values.iter().map(|v| v.as_slice()),
            ts,
            || {
                if p.epoch.load(Ordering::Acquire) != t.epoch {
                    return Err(Error::StaleEpoch(format!(
                        "{}/{partition}: routed at epoch {}, log sealed at epoch {}",
                        t.name,
                        t.epoch,
                        p.epoch.load(Ordering::Acquire)
                    )));
                }
                Ok(())
            },
        )?;
        // Async in-process replication with a modeled lag: each
        // follower adopts the leader's segment `Arc`s (zero payload
        // copies) and advances its applied watermark as far as its
        // injected lag allows, paying the modeled inter-broker stream
        // costs — leader egress, follower ingress, follower disk — for
        // the bytes it applies.  Under Quorum, in-sync followers are
        // driven to full catch-up *before* the ack returns (latency
        // rises with follower lag); under Leader the catch-up is
        // deferred and the produce path stays flat.  The ISR
        // shrinks/expands here from each follower's watermark gap.
        self.sync_partition_followers(p, &rep, bytes);
        p.high_watermark
            .fetch_max(base + values.len() as u64, Ordering::AcqRel);
        p.notify_data();
        Ok(base)
    }

    /// Fetch records from a partition starting at `offset`, blocking up
    /// to `timeout` for data.  Pays leader egress + consumer ingress for
    /// the returned bytes.
    pub fn fetch(
        &self,
        topic: &str,
        partition: usize,
        offset: u64,
        max_bytes: usize,
        to_node: NodeId,
        timeout: Duration,
    ) -> Result<Vec<Record>> {
        let t = self.topic(topic)?;
        self.fetch_from(&t, partition, offset, max_bytes, to_node, timeout)
    }

    /// [`BrokerCluster::fetch`] against a cached topic handle — the
    /// consumer hot path.  Reads are always safe on a stale handle
    /// (partition ids are stable and logs are shared across snapshots).
    /// The returned records are zero-copy slab views; the modeled
    /// network cost is still charged per byte at this boundary.
    pub fn fetch_from(
        &self,
        t: &Topic,
        partition: usize,
        offset: u64,
        max_bytes: usize,
        to_node: NodeId,
        timeout: Duration,
    ) -> Result<Vec<Record>> {
        self.check_running()?;
        let p = t
            .partitions
            .get(partition)
            .ok_or_else(|| {
                Error::Broker(format!("{}/{partition}: no such partition", t.name))
            })?
            .clone();

        // Follower-fetch (KIP-392-style read locality): when the topic
        // opts in and the consuming node hosts an *in-sync* follower of
        // this partition, serve from that follower instead of the
        // leader — fenced by the follower's applied watermark, so a
        // lagging replica can never hand out records it has not
        // replicated yet.
        let follower_serve = |p: &Partition| -> Option<u64> {
            if !t.replication.follower_fetch {
                return None;
            }
            let set = p.replicas.lock().unwrap();
            if set.nodes.first() == Some(&to_node) || !set.isr.contains(&to_node) {
                return None;
            }
            set.mirrors.get(&to_node).map(|m| m.high_watermark())
        };

        let deadline = Instant::now() + timeout;
        // When this fetch first observed its shard quiesced (a
        // repartition sealing the shard's partitions): waits downgrade
        // to bounded slices and the fetch errors out cleanly once the
        // quiesce outlives the grace window, instead of sleeping the
        // full (possibly unbounded) timeout on a shard nobody will
        // ring again.
        let mut quiesced_since: Option<Instant> = None;
        let records = loop {
            // Visibility is capped at the replication high watermark:
            // a record is never served before it is on every alive
            // replica.  The watermark is loaded *before* the segment
            // read, so a concurrent produce can only hide records this
            // pass (the loop re-reads), never expose unreplicated ones.
            let mut hw = p.high_watermark.load(Ordering::Acquire);
            if let Some(watermark) = follower_serve(&p) {
                hw = hw.min(watermark);
            }
            // Lock-free read against the published segment snapshot —
            // concurrent producers are never blocked by this.
            let mut recs = p.log.read(offset, max_bytes)?;
            if let Some(cut) = recs.iter().position(|r| r.offset >= hw) {
                recs.truncate(cut);
            }
            if !recs.is_empty() {
                break recs;
            }
            let now = Instant::now();
            if now >= deadline {
                break Vec::new();
            }
            // Park on the owning shard's doorbell.  The park (gauge
            // increment + SeqCst fence) must precede the watermark
            // re-check: it pairs with the producer's publish-then-ring
            // ordering so either the producer sees us parked and
            // notifies, or we see its watermark and never sleep.  The
            // guard deregisters on every exit path (wake, timeout,
            // error, `continue`).
            let shard = &p.shard;
            let _parked = shard.park();
            let guard = shard.lock();
            // Re-check under the doorbell lock: an append that landed
            // between the read above and this acquisition already
            // published its watermark, so we must not sleep through
            // its (possibly coalesced-away) ring.
            if p.high_watermark.load(Ordering::Acquire) > offset {
                continue;
            }
            if self.inner.stopped.load(Ordering::Relaxed) {
                return Err(Error::Broker("broker cluster is stopped".into()));
            }
            let wait = if shard.is_quiesced() {
                let since = *quiesced_since.get_or_insert(now);
                if now.duration_since(since) >= QUIESCE_WAIT_MAX {
                    return Err(Error::ShardQuiesced(format!(
                        "{}/{partition}: shard {} quiesced > {}ms mid-repartition",
                        t.name,
                        shard.id(),
                        QUIESCE_WAIT_MAX.as_millis()
                    )));
                }
                QUIESCE_SLICE.min(deadline - now)
            } else {
                quiesced_since = None;
                deadline - now
            };
            let guard = shard.wait(guard, wait)?;
            drop(guard);
            if self.inner.stopped.load(Ordering::Relaxed) {
                return Err(Error::Broker("broker cluster is stopped".into()));
            }
        };
        if !records.is_empty() {
            // Resolve the serving broker only now, *after* any blocking
            // wait: a failover while this fetcher was parked means the
            // bytes come from (and are billed to) the promoted leader,
            // not the node that died under us.  A local in-sync
            // follower serves (and is billed) instead of the leader,
            // which is the whole locality win: the leader's egress is
            // untouched by this consumer.
            let source = if follower_serve(&p).is_some() {
                to_node
            } else {
                self.leader_of(t, partition)?
            };
            let bytes: usize = records.iter().map(|r| r.value.len()).sum();
            self.inner.machine.node(source).egress.acquire(bytes);
            self.inner.machine.node(to_node).ingress.acquire(bytes);
        }
        Ok(records)
    }

    /// High watermark of a partition.
    pub fn end_offset(&self, topic: &str, partition: usize) -> Result<u64> {
        let t = self.topic(topic)?;
        Ok(t.partitions
            .get(partition)
            .ok_or_else(|| Error::Broker(format!("{topic}/{partition}: no such partition")))?
            .end_offset())
    }

    /// Add broker nodes at runtime (pilot extend): leaders rebalance
    /// round-robin over the enlarged broker set, and every partition's
    /// replica set is refilled — the path that heals degraded
    /// replication after a node death.
    pub fn add_brokers(&self, nodes: Vec<NodeId>) {
        let _control = self.inner.control.lock().unwrap();
        {
            // Coordinator placement hashes over the stable first-seen
            // ring: new nodes append slots, rejoining nodes keep theirs.
            let mut ring = self.inner.coordinator_ring.lock().unwrap();
            for n in &nodes {
                if !ring.contains(n) {
                    ring.push(*n);
                }
            }
        }
        {
            // A node added through the heal path adopts fully-caught-up
            // mirrors below, so any retained divergence state from an
            // earlier death is obsolete (the honest-truncation path is
            // `rejoin_broker`).
            let mut departed = self.inner.departed.lock().unwrap();
            for n in &nodes {
                departed.remove(n);
            }
        }
        let mut brokers = self.inner.broker_nodes.load().as_ref().clone();
        brokers.extend(nodes);
        let n = brokers.len();
        let brokers = Arc::new(brokers);
        self.inner.broker_nodes.store(brokers.clone());
        for topic in self.inner.topics.load().values() {
            for (i, p) in topic.partitions.iter().enumerate() {
                p.leader.store(i % n, Ordering::Relaxed);
            }
            self.assign_replica_sets(&topic.partitions, topic.replication.factor, &brokers);
        }
    }

    /// Remove broker nodes (pilot shrink): partition leadership
    /// rebalances over the remaining brokers (Kafka partition
    /// reassignment).  The last broker cannot be removed.
    pub fn remove_brokers(&self, nodes: &[NodeId]) -> Result<()> {
        let _control = self.inner.control.lock().unwrap();
        let mut brokers = self.inner.broker_nodes.load().as_ref().clone();
        if brokers.iter().filter(|b| !nodes.contains(b)).count() == 0 {
            return Err(Error::Broker("cannot remove the last broker".into()));
        }
        brokers.retain(|b| !nodes.contains(b));
        let n = brokers.len();
        let brokers = Arc::new(brokers);
        self.inner.broker_nodes.store(brokers.clone());
        for topic in self.inner.topics.load().values() {
            for (i, p) in topic.partitions.iter().enumerate() {
                p.leader.store(i % n, Ordering::Relaxed);
            }
            self.assign_replica_sets(&topic.partitions, topic.replication.factor, &brokers);
        }
        Ok(())
    }

    /// Stop the cluster: producers/consumers error out, fetchers wake.
    /// One forced ring per shard replaces the old per-partition notify
    /// loop — every parked fetcher lives on some shard's doorbell.
    pub fn stop(&self) {
        self.inner.stopped.store(true, Ordering::Relaxed);
        self.inner.shards.ring_all();
    }

    pub fn is_stopped(&self) -> bool {
        self.inner.stopped.load(Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // Consumer-group coordination
    // ------------------------------------------------------------------

    /// Join `group` for `topic`; returns (member_id, generation).
    pub fn group_join(&self, group: &str, topic: &str) -> (u64, u64) {
        let mut groups = self.inner.groups.lock().unwrap();
        let st = groups
            .entry((group.to_string(), topic.to_string()))
            .or_default();
        let id = st.next_member_id;
        st.next_member_id += 1;
        st.members.push(id);
        st.generation += 1;
        (id, st.generation)
    }

    /// Leave a group (consumer drop / shrink); bumps the generation.
    pub fn group_leave(&self, group: &str, topic: &str, member: u64) {
        let mut groups = self.inner.groups.lock().unwrap();
        if let Some(st) = groups.get_mut(&(group.to_string(), topic.to_string())) {
            st.members.retain(|m| *m != member);
            st.generation += 1;
        }
    }

    /// Current generation + range assignment for `member`.
    ///
    /// Convenience over [`BrokerCluster::group_serve_plan`] for callers
    /// that only need the partition list.
    pub fn group_assignment(
        &self,
        group: &str,
        topic: &str,
        member: u64,
    ) -> Result<(u64, Vec<usize>)> {
        let plan = self.group_serve_plan(group, topic, member)?;
        Ok((plan.generation, plan.partitions))
    }

    /// Everything a group member needs to serve its share of a topic:
    /// the membership generation, the epoch the group is serving, the
    /// assigned partition ids, and — while the group is draining toward
    /// a newer partition-set epoch — per-partition fetch ceilings
    /// (offsets the member must not read past until the whole group has
    /// committed up to every fence).
    ///
    /// Opportunistically advances the group's epoch when every fence of
    /// the next transition is already committed (e.g. a repartition of
    /// an already-drained topic), bumping the generation so other
    /// members rebalance too.
    pub fn group_serve_plan(
        &self,
        group: &str,
        topic: &str,
        member: u64,
    ) -> Result<super::repartition::ServePlan> {
        // The topic handle must be read before the groups lock (lock
        // order: topic snapshot, then groups — same as repartition), so
        // a repartition can complete between the two acquisitions.  If
        // it does, the plan below would pair the *bumped* generation
        // with the stale topic view (no fences) and the member would
        // never re-refresh — so re-read the topic afterwards and retry
        // until the epoch is stable across the computation.
        loop {
            let t = self.topic(topic)?;
            let plan = self.serve_plan_for(&t, group, topic, member)?;
            if self.topic(topic)?.epoch == t.epoch {
                return Ok(plan);
            }
        }
    }

    fn serve_plan_for(
        &self,
        t: &Topic,
        group: &str,
        topic: &str,
        member: u64,
    ) -> Result<super::repartition::ServePlan> {
        let mut groups = self.inner.groups.lock().unwrap();
        let st = groups
            .get_mut(&(group.to_string(), topic.to_string()))
            .ok_or_else(|| Error::Broker(format!("unknown group {group}")))?;
        Self::advance_group_epoch(t, st);
        // The serve domain: while draining, every partition that can
        // hold records from the group's epoch (capped at the next
        // transition's fences); once caught up, the active set.
        let (domain, fences): (usize, Option<&[u64]>) = if st.epoch < t.epoch {
            let tr = &t.transitions[st.epoch as usize];
            (tr.fences.len(), Some(&tr.fences))
        } else {
            (t.active, None)
        };
        let n_members = st.members.len().max(1);
        let rank = st
            .members
            .iter()
            .position(|m| *m == member)
            .ok_or_else(|| Error::Broker(format!("member {member} left group {group}")))?;
        // Range assignment: contiguous chunks, first members get extras.
        let per = domain / n_members;
        let extra = domain % n_members;
        let start = rank * per + rank.min(extra);
        let count = per + usize::from(rank < extra);
        let partitions: Vec<usize> = (start..start + count).collect();
        let mut ceilings = Vec::with_capacity(partitions.len());
        for p in &partitions {
            ceilings.push(fences.map(|f| f[*p]));
        }
        Ok(super::repartition::ServePlan {
            generation: st.generation,
            epoch: st.epoch,
            topic_epoch: t.epoch,
            partitions,
            ceilings,
        })
    }

    /// The partition-set epoch `group` is currently serving on `topic`
    /// (trails [`BrokerCluster::topic_epoch`] while the group drains).
    pub fn group_epoch(&self, group: &str, topic: &str) -> u64 {
        let groups = self.inner.groups.lock().unwrap();
        groups
            .get(&(group.to_string(), topic.to_string()))
            .map(|st| st.epoch)
            .unwrap_or(0)
    }

    /// Advance `st` through every transition whose fences are all
    /// committed; each advance is a rebalance (generation bump).
    fn advance_group_epoch(t: &Topic, st: &mut GroupState) {
        while st.epoch < t.epoch {
            let tr = &t.transitions[st.epoch as usize];
            let drained = tr
                .fences
                .iter()
                .enumerate()
                .all(|(p, fence)| st.offsets.get(&p).copied().unwrap_or(0) >= *fence);
            if !drained {
                break;
            }
            st.epoch += 1;
            st.generation += 1;
        }
    }

    /// Committed offset for a partition (0 if none committed yet).
    pub fn committed(&self, group: &str, topic: &str, partition: usize) -> u64 {
        let groups = self.inner.groups.lock().unwrap();
        groups
            .get(&(group.to_string(), topic.to_string()))
            .and_then(|st| st.offsets.get(&partition).copied())
            .unwrap_or(0)
    }

    /// Commit an offset (next offset to consume) for a partition.
    ///
    /// When the group is draining toward a newer partition-set epoch,
    /// a commit that satisfies the last outstanding fence advances the
    /// group's epoch (and bumps its generation so members rebalance
    /// onto the new partition set).
    pub fn commit(&self, group: &str, topic: &str, partition: usize, offset: u64) {
        // Topic handle fetched before the groups lock (lock order:
        // topic snapshot, then groups — same as repartition_topic).
        let t = self.topic(topic).ok();
        let mut groups = self.inner.groups.lock().unwrap();
        let st = groups
            .entry((group.to_string(), topic.to_string()))
            .or_default();
        let entry = st.offsets.entry(partition).or_insert(0);
        *entry = (*entry).max(offset);
        if let Some(t) = t {
            Self::advance_group_epoch(&t, st);
        }
    }

    /// Total committed lag across all partitions of a topic for a group
    /// (end offsets minus committed offsets) — a backpressure signal.
    pub fn group_lag(&self, group: &str, topic: &str) -> Result<u64> {
        Ok(self.group_lag_per_partition(group, topic)?.iter().sum())
    }

    /// Per-partition `(end offset, committed offset)` for a group in
    /// one topic pass — the single source every lag computation (and
    /// the autoscaler's signal probe) derives from.
    pub fn group_progress(&self, group: &str, topic: &str) -> Result<Vec<(u64, u64)>> {
        let t = self.topic(topic)?;
        Ok(t.partitions
            .iter()
            .enumerate()
            .map(|(i, p)| (p.end_offset(), self.committed(group, topic, i)))
            .collect())
    }

    /// Committed lag broken out per partition — the item sizes the
    /// autoscaler's bin-packing policy packs onto processing nodes.
    pub fn group_lag_per_partition(&self, group: &str, topic: &str) -> Result<Vec<u64>> {
        Ok(self
            .group_progress(group, topic)?
            .iter()
            .map(|(end, committed)| end.saturating_sub(*committed))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::log::copytrack;
    use crate::cluster::Machine;

    fn cluster(brokers: usize) -> BrokerCluster {
        let machine = Machine::unthrottled(brokers + 2);
        let nodes = (0..brokers).collect();
        BrokerCluster::new(machine, nodes)
    }

    #[test]
    fn produce_fetch_roundtrip() {
        let c = cluster(1);
        c.create_topic("t", 2).unwrap();
        let base = c
            .produce("t", 0, 1, &[b"a".to_vec(), b"b".to_vec()])
            .unwrap();
        assert_eq!(base, 0);
        let recs = c
            .fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].value, b"b");
        // Other partition untouched.
        assert_eq!(c.end_offset("t", 1).unwrap(), 0);
    }

    #[test]
    fn fetch_blocks_until_produce() {
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.fetch("t", 0, 0, usize::MAX, 1, Duration::from_secs(5))
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(50));
        c.produce("t", 0, 1, &[b"late".to_vec()]).unwrap();
        let recs = h.join().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].value, b"late");
    }

    #[test]
    fn fetch_timeout_returns_empty() {
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        let recs = c
            .fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(20))
            .unwrap();
        assert!(recs.is_empty());
    }

    #[test]
    fn unknown_topic_and_partition_error() {
        let c = cluster(1);
        assert!(c.produce("nope", 0, 0, &[vec![1]]).is_err());
        c.create_topic("t", 1).unwrap();
        assert!(c.produce("t", 5, 0, &[vec![1]]).is_err());
        assert!(c.create_topic("t", 1).is_err(), "duplicate topic");
    }

    #[test]
    fn leaders_round_robin_and_rebalance() {
        let c = cluster(2);
        c.create_topic("t", 4).unwrap();
        let leaders: Vec<NodeId> = (0..4).map(|p| c.leader_node("t", p).unwrap()).collect();
        assert_eq!(leaders, vec![0, 1, 0, 1]);
        c.add_brokers(vec![2, 3]);
        let leaders: Vec<NodeId> = (0..4).map(|p| c.leader_node("t", p).unwrap()).collect();
        assert_eq!(leaders, vec![0, 1, 2, 3]);
    }

    #[test]
    fn stop_wakes_blocked_fetchers() {
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.fetch("t", 0, 0, usize::MAX, 1, Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(50));
        c.stop();
        assert!(h.join().unwrap().is_err());
        assert!(c.produce("t", 0, 0, &[vec![1]]).is_err());
    }

    #[test]
    fn broker_io_tracks_data_plane_bytes() {
        let c = cluster(2);
        c.create_topic("t", 2).unwrap();
        let io0 = c.broker_io();
        assert_eq!(io0.len(), 2);
        assert!(io0.iter().all(|s| s.nic_rate.is_none()), "test machine unthrottled");
        // Partition 0 leads on broker 0: its ingress + disk move.
        c.produce("t", 0, 2, &[vec![0u8; 100]]).unwrap();
        let io1 = c.broker_io();
        assert_eq!(io1[0].nic_in_bytes - io0[0].nic_in_bytes, 100);
        assert_eq!(io1[0].nic_out_bytes, io0[0].nic_out_bytes);
        assert_eq!(io1[0].disk_bytes - io0[0].disk_bytes, 100);
        assert_eq!(io1[1].nic_in_bytes, io0[1].nic_in_bytes, "other broker untouched");
        // A fetch pays leader egress on the same node — the modeled
        // per-byte network cost survives the zero-copy fetch path.
        c.fetch("t", 0, 0, usize::MAX, 2, Duration::from_millis(10)).unwrap();
        let io2 = c.broker_io();
        assert_eq!(io2[0].nic_out_bytes - io1[0].nic_out_bytes, 100);
        assert_eq!(io2[0].nic_in_bytes, io1[0].nic_in_bytes);
    }

    #[test]
    fn fetch_performs_no_payload_copies() {
        // The zero-copy acceptance check: a produce→fetch roundtrip
        // must not materialize payload bytes anywhere on the fetch
        // path (debug builds count every materialization).
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        c.produce("t", 0, 1, &[vec![5u8; 4096], vec![6u8; 4096]])
            .unwrap();
        let before = copytrack::payload_copies();
        let recs = c
            .fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].value, vec![5u8; 4096]);
        assert_eq!(
            copytrack::payload_copies(),
            before,
            "fetch must return views, not copies"
        );
    }

    #[test]
    fn cached_handle_produce_fetch_and_staleness() {
        // The hot-path variants work against a cached Arc<Topic>, and
        // a repartition flips the handle's validity probe so clients
        // know to re-resolve.
        let c = cluster(1);
        c.create_topic("t", 2).unwrap();
        let t = c.topic("t").unwrap();
        assert!(t.is_current());
        c.produce_to(&t, 0, 1, &[b"via-handle".to_vec()]).unwrap();
        let recs = c
            .fetch_from(&t, 0, 0, usize::MAX, 1, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs[0].value, b"via-handle");
        c.repartition_topic("t", 4).unwrap();
        assert!(!t.is_current(), "repartition invalidates cached handles");
        // Stale produce is fenced; stale fetch still reads.
        assert!(matches!(
            c.produce_to(&t, 0, 1, &[vec![1]]),
            Err(Error::StaleEpoch(_))
        ));
        let recs = c
            .fetch_from(&t, 0, 0, usize::MAX, 1, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn group_assignment_covers_all_partitions() {
        let c = cluster(1);
        c.create_topic("t", 7).unwrap();
        let (m1, _) = c.group_join("g", "t");
        let (m2, _) = c.group_join("g", "t");
        let (m3, _) = c.group_join("g", "t");
        let mut all: Vec<usize> = Vec::new();
        for m in [m1, m2, m3] {
            let (_, parts) = c.group_assignment("g", "t", m).unwrap();
            all.extend(parts);
        }
        all.sort();
        assert_eq!(all, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn group_leave_bumps_generation_and_reassigns() {
        let c = cluster(1);
        c.create_topic("t", 4).unwrap();
        let (m1, g1) = c.group_join("g", "t");
        let (m2, g2) = c.group_join("g", "t");
        assert!(g2 > g1);
        c.group_leave("g", "t", m1);
        let (g3, parts) = c.group_assignment("g", "t", m2).unwrap();
        assert!(g3 > g2);
        assert_eq!(parts, vec![0, 1, 2, 3], "sole member owns everything");
        assert!(c.group_assignment("g", "t", m1).is_err());
    }

    #[test]
    fn commit_is_monotonic_and_lag_tracks() {
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        c.produce("t", 0, 0, &[vec![0], vec![1], vec![2]]).unwrap();
        c.group_join("g", "t");
        assert_eq!(c.group_lag("g", "t").unwrap(), 3);
        c.commit("g", "t", 0, 2);
        assert_eq!(c.committed("g", "t", 0), 2);
        c.commit("g", "t", 0, 1); // stale commit ignored
        assert_eq!(c.committed("g", "t", 0), 2);
        assert_eq!(c.group_lag("g", "t").unwrap(), 1);
    }

    #[test]
    fn partitions_map_onto_shards_and_stats_export() {
        let machine = Machine::unthrottled(3);
        let c = BrokerCluster::with_shards(machine, vec![0], LogConfig::default(), 4);
        assert_eq!(c.n_shards(), 4);
        c.create_topic("t", 16).unwrap();
        let t = c.topic("t").unwrap();
        for (i, p) in t.partitions.iter().enumerate() {
            assert_eq!(p.shard_id(), super::super::shard::shard_of(i, 4));
        }
        let stats = c.shard_stats();
        assert_eq!(stats.len(), 4);
        assert!(stats.iter().all(|s| s.parked_fetchers == 0 && !s.quiesced));
        // One batched produce rings the owning shard exactly once, and
        // with no fetchers parked the ring coalesces away (no notify).
        c.produce("t", 0, 1, &[vec![1], vec![2], vec![3]]).unwrap();
        let sid = t.partitions[0].shard_id();
        let stats = c.shard_stats();
        assert_eq!(stats[sid].rings, 1, "one ring per append batch");
        assert_eq!(stats[sid].notifies, 0, "coalesced: nobody parked");
    }

    #[test]
    fn quiesced_shard_fetch_errors_cleanly_after_grace() {
        let c = cluster(1);
        c.create_topic("t", 1).unwrap();
        let sid = c.quiesce_partition_shard("t", 0).unwrap();
        assert!(c.shard_stats()[sid].quiesced);
        // A short fetch still times out normally (Ok-empty) — the
        // quiesce grace only cuts waits that would outlive it.
        let recs = c
            .fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(20))
            .unwrap();
        assert!(recs.is_empty());
        // A long blocking fetch surfaces the clean quiesce error after
        // the bounded grace window instead of sleeping 30 s.
        let start = Instant::now();
        let err = c.fetch("t", 0, 0, usize::MAX, 1, Duration::from_secs(30));
        assert!(matches!(err, Err(Error::ShardQuiesced(_))), "{err:?}");
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "bounded wait, not the caller timeout"
        );
        c.resume_partition_shard("t", 0).unwrap();
        assert!(!c.shard_stats()[sid].quiesced);
        // Resumed shard serves blocking fetches again.
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            c2.fetch("t", 0, 0, usize::MAX, 1, Duration::from_secs(5))
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        c.produce("t", 0, 1, &[b"back".to_vec()]).unwrap();
        assert_eq!(h.join().unwrap().len(), 1);
    }

    #[test]
    fn per_partition_lag_breaks_out_by_partition() {
        let c = cluster(1);
        c.create_topic("t", 3).unwrap();
        c.produce("t", 0, 0, &[vec![0], vec![1]]).unwrap();
        c.produce("t", 2, 0, &[vec![2]]).unwrap();
        c.group_join("g", "t");
        assert_eq!(c.group_lag_per_partition("g", "t").unwrap(), vec![2, 0, 1]);
        c.commit("g", "t", 0, 2);
        assert_eq!(c.group_lag_per_partition("g", "t").unwrap(), vec![0, 0, 1]);
        assert!(c.group_lag_per_partition("g", "nope").is_err());
    }
}
