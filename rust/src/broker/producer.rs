//! Producer client: batching, partitioning, metrics.
//!
//! Mirrors the PyKafka producer the paper's MASS app uses (§6.3):
//! records accumulate into per-partition batches and flush when the
//! batch size or linger limit is hit.  Sends are synchronous once a
//! batch flushes — backpressure arrives naturally as blocking time on
//! the broker-side token buckets (NIC/disk), which is exactly how a
//! saturated Kafka broker pushes back on `acks=all` producers.
//!
//! Fast path (§Perf L3): the key→partition route is resolved at append
//! time into a 64-bit [`key_hash`] — batches carry `(route, value)`
//! instead of an owned key `Vec`, so keyed sends allocate nothing
//! beyond the payload.  A topic resize re-routes pending records by
//! re-jump-hashing the stored route under the new partition count
//! (per-key order is preserved: the hash determines the partition
//! deterministically).  Flushes go through the cached topic handle
//! ([`BrokerCluster::produce_to`]), so the send path never touches the
//! cluster's topics snapshot, let alone a global lock.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::metrics::RateMeter;

use super::cluster::BrokerCluster;
use super::repartition::{jump_hash, key_hash};

/// Acknowledgement summary a [`Producer::flush`] returns: everything
/// the broker acked since the previous `flush` call (send-triggered
/// batch flushes included).  Acks are *batched* — one entry per
/// append batch, settled when the batch's `produce_to` returns (which
/// under [`super::AckMode::Quorum`] is itself one aggregated
/// quorum-settlement pass per batch, not per record) — so the producer
/// hot path never waits on per-record ack traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AckBatch {
    /// Append batches acked.
    pub batches: u64,
    /// Records acked across those batches.
    pub records: u64,
    /// Payload bytes acked.
    pub bytes: u64,
}

impl AckBatch {
    fn absorb(&mut self, records: u64, bytes: u64) {
        self.batches += 1;
        self.records += records;
        self.bytes += bytes;
    }
}

/// Partition selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Rotate through partitions (the MASS default).
    RoundRobin,
    /// Jump-consistent-hash a caller-provided key
    /// ([`super::repartition::key_partition`]): stable per key, and a
    /// topic resize moves only ~1/new_count of the key space per added
    /// partition.
    Keyed,
    /// Always the given partition.
    Fixed(usize),
}

/// Producer configuration.
#[derive(Debug, Clone)]
pub struct ProducerConfig {
    /// Flush a partition batch when it reaches this many payload bytes.
    pub batch_bytes: usize,
    /// Flush any non-empty batch older than this.
    pub linger: std::time::Duration,
    pub partitioner: Partitioner,
}

impl Default for ProducerConfig {
    fn default() -> Self {
        ProducerConfig {
            batch_bytes: 1 << 20, // 1 MB
            linger: std::time::Duration::from_millis(50),
            partitioner: Partitioner::RoundRobin,
        }
    }
}

/// A pending per-partition batch.  Records keep their key's *route
/// hash* (not the key bytes) so that a topic resize can re-route
/// not-yet-flushed records through the new key mapping (flushing them
/// under stale routing would break per-key order across the
/// repartition fence).
struct Batch {
    records: Vec<(Option<u64>, Vec<u8>)>,
    bytes: usize,
    opened: Instant,
}

impl Batch {
    fn new() -> Self {
        Batch {
            records: Vec::new(),
            bytes: 0,
            opened: Instant::now(),
        }
    }
}

/// A producer bound to one topic, sending from one (simulated) node.
pub struct Producer {
    cluster: BrokerCluster,
    topic: String,
    /// Cached topic handle; revalidated lock-free on every send via the
    /// partition epoch atomic (see `refresh_partitions`).
    topic_handle: Arc<super::cluster::Topic>,
    node: NodeId,
    config: ProducerConfig,
    n_partitions: usize,
    batches: Vec<Batch>,
    rr_next: usize,
    /// Acks accumulated since the last [`Producer::flush`] (one entry
    /// per settled append batch) — drained by `flush`.
    acked: AckBatch,
    pub metrics: Arc<RateMeter>,
}

impl Producer {
    pub fn new(
        cluster: BrokerCluster,
        topic: &str,
        node: NodeId,
        config: ProducerConfig,
    ) -> Result<Self> {
        let topic_handle = cluster.topic(topic)?;
        let n_partitions = topic_handle.active_partitions();
        Ok(Producer {
            cluster,
            topic: topic.to_string(),
            topic_handle,
            node,
            config,
            n_partitions,
            batches: (0..n_partitions).map(|_| Batch::new()).collect(),
            rr_next: 0,
            acked: AckBatch::default(),
            metrics: Arc::new(RateMeter::new()),
        })
    }

    /// Keep routing in sync with the live partition count (it moves
    /// when the autoscaler repartitions).  The fast path is lock-free:
    /// every repartition bumps partition 0's epoch atomic (shared with
    /// our cached handle), so a matching epoch proves the cache is
    /// current without touching the topics snapshot on the send hot
    /// path.  On a change, every pending record is re-routed through
    /// the *new* partition mapping — per-batch order is preserved, and
    /// keyed records land where their route hash now maps, keeping
    /// per-key order across the epoch fence.
    fn refresh_partitions(&mut self) -> Result<()> {
        if self.topic_handle.is_current() {
            return Ok(());
        }
        self.topic_handle = self.cluster.topic(&self.topic)?;
        let n = self.topic_handle.active_partitions();
        if n == self.n_partitions {
            return Ok(());
        }
        let pending: Vec<(Option<u64>, Vec<u8>)> = self
            .batches
            .iter_mut()
            .flat_map(|b| std::mem::take(&mut b.records))
            .collect();
        self.n_partitions = n;
        self.batches = (0..n).map(|_| Batch::new()).collect();
        self.rr_next = 0;
        for (route, value) in pending {
            // Recursion is benign: the count now matches, so the nested
            // refresh is a no-op unless another resize races in.
            self.send_routed(route, value)?;
        }
        Ok(())
    }

    fn partition_for(&mut self, route: Option<u64>) -> usize {
        match self.config.partitioner {
            Partitioner::Fixed(p) => p % self.n_partitions,
            // A keyed producer with an *unkeyed* record round-robins:
            // the old fallback (hash of the empty key) silently pinned
            // every keyless record to one partition, which turned
            // chained stages with occasional unkeyed emissions into a
            // single-partition hotspot.
            Partitioner::Keyed => match route {
                Some(r) => jump_hash(r, self.n_partitions),
                None => {
                    let p = self.rr_next;
                    self.rr_next = (self.rr_next + 1) % self.n_partitions;
                    p
                }
            },
            Partitioner::RoundRobin => {
                let p = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.n_partitions;
                p
            }
        }
    }

    /// Queue one record; flushes the target partition's batch if full or
    /// lingered out.  Returns true if a flush happened.
    ///
    /// The key is hashed once here — only the 8-byte route travels with
    /// the record from this point on.
    pub fn send(&mut self, key: Option<&[u8]>, value: Vec<u8>) -> Result<bool> {
        self.send_routed(key.map(key_hash), value)
    }

    /// Queue one record under a pre-computed route (`pub(crate)` for
    /// the micro-batch emitter, which hashes keys once at emit time).
    pub(crate) fn send_routed(&mut self, route: Option<u64>, value: Vec<u8>) -> Result<bool> {
        self.refresh_partitions()?;
        let p = self.partition_for(route);
        let batch = &mut self.batches[p];
        if batch.records.is_empty() {
            batch.opened = Instant::now();
        }
        batch.bytes += value.len();
        batch.records.push((route, value));
        if batch.bytes >= self.config.batch_bytes || batch.opened.elapsed() >= self.config.linger
        {
            self.flush_partition(p)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn flush_partition(&mut self, p: usize) -> Result<()> {
        if self.batches[p].records.is_empty() {
            return Ok(());
        }
        let batch = std::mem::replace(&mut self.batches[p], Batch::new());
        let (routes, values): (Vec<Option<u64>>, Vec<Vec<u8>>) =
            batch.records.into_iter().unzip();
        match self
            .cluster
            .produce_to(&self.topic_handle, p, self.node, &values)
        {
            Ok(_) => {
                self.metrics
                    .record_many(values.len() as u64, batch.bytes as u64);
                self.acked.absorb(values.len() as u64, batch.bytes as u64);
                Ok(())
            }
            // The produce raced a repartition (partition retired, or the
            // log was sealed after routing): re-send every record, which
            // refreshes the routing table and re-maps routes onto the
            // new partition set.
            Err(Error::StaleEpoch(_)) => {
                for (route, value) in routes.into_iter().zip(values) {
                    self.send_routed(route, value)?;
                }
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Flush every pending batch and return the [`AckBatch`] — every
    /// batch/record/byte the broker acked since the previous flush
    /// (including send-triggered flushes in between).  Re-checks the
    /// partition count first (a resize since the last send must
    /// re-route pending records, not flush them under stale routing),
    /// and runs repeated passes because a stale-epoch re-route may
    /// re-queue records into batches an earlier pass already flushed.
    pub fn flush(&mut self) -> Result<AckBatch> {
        self.refresh_partitions()?;
        loop {
            let dirty: Vec<usize> = self
                .batches
                .iter()
                .enumerate()
                .filter(|(_, b)| !b.records.is_empty())
                .map(|(i, _)| i)
                .collect();
            if dirty.is_empty() {
                return Ok(std::mem::take(&mut self.acked));
            }
            for p in dirty {
                self.flush_partition(p)?;
            }
        }
    }

    pub fn topic(&self) -> &str {
        &self.topic
    }

    pub fn node(&self) -> NodeId {
        self.node
    }
}

impl Drop for Producer {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn setup(partitions: usize) -> BrokerCluster {
        let c = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        c.create_topic("t", partitions).unwrap();
        c
    }

    #[test]
    fn round_robin_spreads_over_partitions() {
        let c = setup(3);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1, // flush every record
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..9u8 {
            p.send(None, vec![i]).unwrap();
        }
        for part in 0..3 {
            assert_eq!(c.end_offset("t", part).unwrap(), 3, "partition {part}");
        }
        assert_eq!(p.metrics.messages(), 9);
    }

    #[test]
    fn keyed_partitioning_is_stable() {
        let c = setup(4);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        for _ in 0..5 {
            p.send(Some(b"same-key"), vec![0]).unwrap();
        }
        let counts: Vec<u64> = (0..4).map(|i| c.end_offset("t", i).unwrap()).collect();
        assert_eq!(counts.iter().sum::<u64>(), 5);
        assert_eq!(counts.iter().filter(|c| **c > 0).count(), 1, "{counts:?}");
    }

    #[test]
    fn keyed_producer_round_robins_unkeyed_records() {
        // Keyless records through a keyed producer used to hash the
        // empty key — a constant route pinning them all to one
        // partition.  They must spread round-robin instead.
        let c = setup(3);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..9u8 {
            p.send(None, vec![i]).unwrap();
        }
        let counts: Vec<u64> = (0..3).map(|i| c.end_offset("t", i).unwrap()).collect();
        assert_eq!(counts, vec![3, 3, 3], "unkeyed sends must round-robin");
    }

    #[test]
    fn keyed_route_matches_key_partition() {
        // The stored route must land exactly where key_partition says
        // the key lives — applications predicting placements and the
        // producer's batch routing agree.
        let c = setup(8);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        for key in [b"alpha".as_slice(), b"beta", b"gamma", b""] {
            p.send(Some(key), key.to_vec()).unwrap();
            let expect = super::super::repartition::key_partition(key, 8);
            assert!(
                c.end_offset("t", expect).unwrap() > 0,
                "key {key:?} should land on partition {expect}"
            );
        }
    }

    #[test]
    fn batching_defers_until_flush() {
        let c = setup(1);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: usize::MAX,
                linger: Duration::from_secs(3600),
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..10u8 {
            p.send(None, vec![i]).unwrap();
        }
        assert_eq!(c.end_offset("t", 0).unwrap(), 0, "nothing flushed yet");
        let acked = p.flush().unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 10);
        assert_eq!(acked.batches, 1, "10 records settle as one batched ack");
        assert_eq!(acked.records, 10);
        assert_eq!(acked.bytes, 10);
    }

    #[test]
    fn flush_drains_accumulated_ack_batches() {
        let c = setup(2);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 4, // send-triggered flush every 2 records
                linger: Duration::from_secs(3600),
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..10u8 {
            p.send(None, vec![i, i]).unwrap();
        }
        // Send-triggered flushes accumulate into the same AckBatch the
        // next explicit flush drains — acks are visible per flush, not
        // per record.
        let acked = p.flush().unwrap();
        assert_eq!(acked.records, 10);
        assert_eq!(acked.bytes, 20);
        assert!(acked.batches >= 2, "round-robin over 2 partitions: {acked:?}");
        // Drained: an immediate re-flush acks nothing.
        assert_eq!(p.flush().unwrap(), AckBatch::default());
    }

    #[test]
    fn producer_follows_repartition() {
        let c = setup(2);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1, // flush every record
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..4u8 {
            p.send(None, vec![i]).unwrap();
        }
        // Grow the topic mid-stream: the producer's next send re-reads
        // the live partition count and spreads over all 4 partitions.
        c.repartition_topic("t", 4).unwrap();
        for i in 0..8u8 {
            p.send(None, vec![i]).unwrap();
        }
        let counts: Vec<u64> = (0..4).map(|i| c.end_offset("t", i).unwrap()).collect();
        assert_eq!(counts.iter().sum::<u64>(), 12);
        assert!(counts.iter().all(|n| *n > 0), "{counts:?}");
        // Shrink: pending routing collapses back onto the active prefix.
        c.repartition_topic("t", 1).unwrap();
        for i in 0..3u8 {
            p.send(None, vec![i]).unwrap();
        }
        assert_eq!(c.end_offset("t", 0).unwrap(), counts[0] + 3);
        assert_eq!(p.metrics.messages(), 15);
    }

    #[test]
    fn pending_keyed_records_reroute_on_resize() {
        // Records batched before a resize must land where their key
        // maps under the *new* partition count — the stored route hash
        // re-jump-hashes without the original key bytes.
        let c = setup(2);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: usize::MAX,
                linger: Duration::from_secs(3600),
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        let keys = [b"k1".as_slice(), b"k2", b"k3", b"k4", b"k5"];
        for key in keys {
            p.send(Some(key), key.to_vec()).unwrap();
        }
        c.repartition_topic("t", 8).unwrap();
        p.flush().unwrap();
        for key in keys {
            let expect = super::super::repartition::key_partition(key, 8);
            let recs = c
                .fetch("t", expect, 0, usize::MAX, 1, Duration::from_millis(10))
                .unwrap();
            assert!(
                recs.iter().any(|r| r.value == key),
                "key {key:?} must land on its new partition {expect}"
            );
        }
    }

    #[test]
    fn drop_flushes_pending() {
        let c = setup(1);
        {
            let mut p = Producer::new(
                c.clone(),
                "t",
                1,
                ProducerConfig {
                    batch_bytes: usize::MAX,
                    linger: Duration::from_secs(3600),
                    ..Default::default()
                },
            )
            .unwrap();
            p.send(None, vec![42]).unwrap();
        }
        assert_eq!(c.end_offset("t", 0).unwrap(), 1);
    }
}
