//! Producer client: batching, partitioning, metrics.
//!
//! Mirrors the PyKafka producer the paper's MASS app uses (§6.3):
//! records accumulate into per-partition batches and flush when the
//! batch size or linger limit is hit.  Sends are synchronous once a
//! batch flushes — backpressure arrives naturally as blocking time on
//! the broker-side token buckets (NIC/disk), which is exactly how a
//! saturated Kafka broker pushes back on `acks=all` producers.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::NodeId;
use crate::error::Result;
use crate::metrics::RateMeter;

use super::cluster::BrokerCluster;

/// Partition selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Rotate through partitions (the MASS default).
    RoundRobin,
    /// Hash a caller-provided key.
    Keyed,
    /// Always the given partition.
    Fixed(usize),
}

/// Producer configuration.
#[derive(Debug, Clone)]
pub struct ProducerConfig {
    /// Flush a partition batch when it reaches this many payload bytes.
    pub batch_bytes: usize,
    /// Flush any non-empty batch older than this.
    pub linger: std::time::Duration,
    pub partitioner: Partitioner,
}

impl Default for ProducerConfig {
    fn default() -> Self {
        ProducerConfig {
            batch_bytes: 1 << 20, // 1 MB
            linger: std::time::Duration::from_millis(50),
            partitioner: Partitioner::RoundRobin,
        }
    }
}

struct Batch {
    values: Vec<Vec<u8>>,
    bytes: usize,
    opened: Instant,
}

impl Batch {
    fn new() -> Self {
        Batch {
            values: Vec::new(),
            bytes: 0,
            opened: Instant::now(),
        }
    }
}

/// A producer bound to one topic, sending from one (simulated) node.
pub struct Producer {
    cluster: BrokerCluster,
    topic: String,
    node: NodeId,
    config: ProducerConfig,
    n_partitions: usize,
    batches: Vec<Batch>,
    rr_next: usize,
    pub metrics: Arc<RateMeter>,
}

impl Producer {
    pub fn new(
        cluster: BrokerCluster,
        topic: &str,
        node: NodeId,
        config: ProducerConfig,
    ) -> Result<Self> {
        let n_partitions = cluster.partition_count(topic)?;
        Ok(Producer {
            cluster,
            topic: topic.to_string(),
            node,
            config,
            n_partitions,
            batches: (0..n_partitions).map(|_| Batch::new()).collect(),
            rr_next: 0,
            metrics: Arc::new(RateMeter::new()),
        })
    }

    fn partition_for(&mut self, key: Option<&[u8]>) -> usize {
        match self.config.partitioner {
            Partitioner::Fixed(p) => p % self.n_partitions,
            Partitioner::Keyed => {
                let key = key.unwrap_or(b"");
                // FNV-1a
                let mut h: u64 = 0xcbf29ce484222325;
                for b in key {
                    h ^= *b as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
                (h % self.n_partitions as u64) as usize
            }
            Partitioner::RoundRobin => {
                let p = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.n_partitions;
                p
            }
        }
    }

    /// Queue one record; flushes the target partition's batch if full or
    /// lingered out.  Returns true if a flush happened.
    pub fn send(&mut self, key: Option<&[u8]>, value: Vec<u8>) -> Result<bool> {
        let p = self.partition_for(key);
        let batch = &mut self.batches[p];
        if batch.values.is_empty() {
            batch.opened = Instant::now();
        }
        batch.bytes += value.len();
        batch.values.push(value);
        if batch.bytes >= self.config.batch_bytes || batch.opened.elapsed() >= self.config.linger
        {
            self.flush_partition(p)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn flush_partition(&mut self, p: usize) -> Result<()> {
        if self.batches[p].values.is_empty() {
            return Ok(());
        }
        let batch = std::mem::replace(&mut self.batches[p], Batch::new());
        self.cluster
            .produce(&self.topic, p, self.node, &batch.values)?;
        self.metrics
            .record_many(batch.values.len() as u64, batch.bytes as u64);
        Ok(())
    }

    /// Flush every pending batch.
    pub fn flush(&mut self) -> Result<()> {
        for p in 0..self.n_partitions {
            self.flush_partition(p)?;
        }
        Ok(())
    }

    pub fn topic(&self) -> &str {
        &self.topic
    }

    pub fn node(&self) -> NodeId {
        self.node
    }
}

impl Drop for Producer {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn setup(partitions: usize) -> BrokerCluster {
        let c = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        c.create_topic("t", partitions).unwrap();
        c
    }

    #[test]
    fn round_robin_spreads_over_partitions() {
        let c = setup(3);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1, // flush every record
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..9u8 {
            p.send(None, vec![i]).unwrap();
        }
        for part in 0..3 {
            assert_eq!(c.end_offset("t", part).unwrap(), 3, "partition {part}");
        }
        assert_eq!(p.metrics.messages(), 9);
    }

    #[test]
    fn keyed_partitioning_is_stable() {
        let c = setup(4);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: 1,
                partitioner: Partitioner::Keyed,
                ..Default::default()
            },
        )
        .unwrap();
        for _ in 0..5 {
            p.send(Some(b"same-key"), vec![0]).unwrap();
        }
        let counts: Vec<u64> = (0..4).map(|i| c.end_offset("t", i).unwrap()).collect();
        assert_eq!(counts.iter().sum::<u64>(), 5);
        assert_eq!(counts.iter().filter(|c| **c > 0).count(), 1, "{counts:?}");
    }

    #[test]
    fn batching_defers_until_flush() {
        let c = setup(1);
        let mut p = Producer::new(
            c.clone(),
            "t",
            1,
            ProducerConfig {
                batch_bytes: usize::MAX,
                linger: Duration::from_secs(3600),
                ..Default::default()
            },
        )
        .unwrap();
        for i in 0..10u8 {
            p.send(None, vec![i]).unwrap();
        }
        assert_eq!(c.end_offset("t", 0).unwrap(), 0, "nothing flushed yet");
        p.flush().unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 10);
    }

    #[test]
    fn drop_flushes_pending() {
        let c = setup(1);
        {
            let mut p = Producer::new(
                c.clone(),
                "t",
                1,
                ProducerConfig {
                    batch_bytes: usize::MAX,
                    linger: Duration::from_secs(3600),
                    ..Default::default()
                },
            )
            .unwrap();
            p.send(None, vec![42]).unwrap();
        }
        assert_eq!(c.end_offset("t", 0).unwrap(), 1);
    }
}
