//! Cloud message-broker latency models (Amazon Kinesis, Google Pub/Sub).
//!
//! The paper's Figure 7 compares on-premise Kafka latency with two
//! cloud "platform as a service" brokers.  We cannot call the real
//! services, so this module substitutes calibrated delay models
//! (DESIGN.md §3): a record becomes visible to consumers only after a
//! WAN round trip plus a service-time sample drawn from a lognormal
//! distribution whose mean matches the paper's measurements
//! (Kinesis ≈ 0.5 s end-to-end, Pub/Sub ≈ 6.2 s mean).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::util::Rng;

/// Latency model parameters for one cloud service.
#[derive(Debug, Clone, Copy)]
pub struct CloudLatencyModel {
    /// One-way WAN latency, seconds (producer -> region).
    pub wan_secs: f64,
    /// Lognormal mu of internal service time (log-seconds).
    pub mu: f64,
    /// Lognormal sigma of internal service time.
    pub sigma: f64,
}

impl CloudLatencyModel {
    /// Amazon Kinesis in us-east-1 as measured in Fig 7: end-to-end
    /// latency a few hundred ms with a long tail.
    pub fn kinesis() -> Self {
        // median ≈ exp(-1.1) ≈ 0.33 s, mean ≈ 0.39 s + 2x WAN 0.04 s.
        CloudLatencyModel {
            wan_secs: 0.04,
            mu: -1.1,
            sigma: 0.55,
        }
    }

    /// Google Pub/Sub as measured in Fig 7: ~6.2 s mean latency.
    pub fn pubsub() -> Self {
        // median ≈ exp(1.75) ≈ 5.75 s, mean ≈ 6.2 s.
        CloudLatencyModel {
            wan_secs: 0.05,
            mu: 1.75,
            sigma: 0.40,
        }
    }

    fn sample_total(&self, rng: &mut Rng) -> f64 {
        2.0 * self.wan_secs + rng.lognormal(self.mu, self.sigma)
    }
}

struct Pending {
    visible_at: Instant,
    produced_at_ns: u64,
    value: Vec<u8>,
}

struct CloudInner {
    model: CloudLatencyModel,
    rng: Rng,
    queue: VecDeque<Pending>,
    epoch: Instant,
}

/// A delay-modeled cloud broker stream (single shard/subscription view).
#[derive(Clone)]
pub struct CloudBroker {
    name: String,
    inner: Arc<Mutex<CloudInner>>,
}

/// A record delivered by a cloud broker poll.
#[derive(Debug, Clone)]
pub struct CloudRecord {
    /// Producer timestamp, ns since broker epoch.
    pub produced_at_ns: u64,
    /// Delivery timestamp, ns since broker epoch.
    pub delivered_at_ns: u64,
    pub value: Vec<u8>,
}

impl CloudRecord {
    /// End-to-end latency in seconds.
    pub fn latency_secs(&self) -> f64 {
        (self.delivered_at_ns.saturating_sub(self.produced_at_ns)) as f64 / 1e9
    }
}

impl CloudBroker {
    pub fn new(name: &str, model: CloudLatencyModel, seed: u64) -> Self {
        CloudBroker {
            name: name.to_string(),
            inner: Arc::new(Mutex::new(CloudInner {
                model,
                rng: Rng::seed_from(seed),
                queue: VecDeque::new(),
                epoch: Instant::now(),
            })),
        }
    }

    pub fn kinesis(seed: u64) -> Self {
        Self::new("kinesis", CloudLatencyModel::kinesis(), seed)
    }

    pub fn pubsub(seed: u64) -> Self {
        Self::new("pubsub", CloudLatencyModel::pubsub(), seed)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Publish a record; it becomes visible after the sampled delay.
    pub fn publish(&self, value: Vec<u8>) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let now = Instant::now();
        let model = inner.model;
        let delay = model.sample_total(&mut inner.rng);
        let produced_at_ns = now.duration_since(inner.epoch).as_nanos() as u64;
        let pending = Pending {
            visible_at: now + Duration::from_secs_f64(delay),
            produced_at_ns,
            value,
        };
        // Keep the queue ordered by visibility time (delays vary).
        let pos = inner
            .queue
            .iter()
            .position(|p| p.visible_at > pending.visible_at)
            .unwrap_or(inner.queue.len());
        inner.queue.insert(pos, pending);
        Ok(())
    }

    /// Poll all currently-visible records.
    pub fn poll(&self) -> Vec<CloudRecord> {
        let mut inner = self.inner.lock().unwrap();
        let now = Instant::now();
        let epoch = inner.epoch;
        let mut out = Vec::new();
        while let Some(front) = inner.queue.front() {
            if front.visible_at > now {
                break;
            }
            let p = inner.queue.pop_front().unwrap();
            out.push(CloudRecord {
                produced_at_ns: p.produced_at_ns,
                delivered_at_ns: now.duration_since(epoch).as_nanos() as u64,
                value: p.value,
            });
        }
        out
    }

    /// Records not yet visible (diagnostics).
    pub fn in_flight(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Sample `n` end-to-end latencies from the model *without* waiting
    /// in real time (used by the simulation plane for Fig 7).
    pub fn sample_latencies(&self, n: usize) -> Vec<f64> {
        let mut inner = self.inner.lock().unwrap();
        let model = inner.model;
        (0..n).map(|_| model.sample_total(&mut inner.rng)).collect()
    }

    /// Expected mean end-to-end latency of the model, seconds.
    pub fn model_mean_secs(&self) -> f64 {
        let m = self.inner.lock().unwrap().model;
        2.0 * m.wan_secs + (m.mu + m.sigma * m.sigma / 2.0).exp()
    }
}

impl std::fmt::Debug for CloudBroker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CloudBroker")
            .field("name", &self.name)
            .field("in_flight", &self.in_flight())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_not_visible_before_delay() {
        let b = CloudBroker::pubsub(1);
        b.publish(vec![1, 2, 3]).unwrap();
        assert!(b.poll().is_empty(), "pub/sub latency is seconds, not 0");
        assert_eq!(b.in_flight(), 1);
    }

    #[test]
    fn sampled_latencies_match_model_means() {
        let kinesis = CloudBroker::kinesis(7);
        let pubsub = CloudBroker::pubsub(7);
        let k: Vec<f64> = kinesis.sample_latencies(4000);
        let p: Vec<f64> = pubsub.sample_latencies(4000);
        let k_mean = k.iter().sum::<f64>() / k.len() as f64;
        let p_mean = p.iter().sum::<f64>() / p.len() as f64;
        // Paper: Kinesis sub-second, Pub/Sub ≈ 6.2 s mean.
        assert!(k_mean > 0.2 && k_mean < 0.8, "kinesis mean {k_mean}");
        assert!(p_mean > 5.0 && p_mean < 7.5, "pubsub mean {p_mean}");
        assert!((kinesis.model_mean_secs() - k_mean).abs() < 0.1);
        assert!((pubsub.model_mean_secs() - p_mean).abs() < 0.5);
    }

    #[test]
    fn fast_model_delivers_in_order_of_visibility() {
        let b = CloudBroker::new(
            "fast",
            CloudLatencyModel {
                wan_secs: 0.001,
                mu: -6.0, // ~2.5 ms
                sigma: 0.3,
            },
            3,
        );
        for i in 0..5u8 {
            b.publish(vec![i]).unwrap();
        }
        std::thread::sleep(Duration::from_millis(50));
        let recs = b.poll();
        assert_eq!(recs.len(), 5);
        for w in recs.windows(2) {
            assert!(w[0].delivered_at_ns <= w[1].delivered_at_ns);
        }
        for r in recs {
            assert!(r.latency_secs() > 0.0);
        }
    }
}
