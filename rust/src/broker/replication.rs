//! Per-partition replication, the ISR lag model, and crash-tolerant
//! failover.
//!
//! Every partition carries a replica set — a leader plus `factor - 1`
//! followers — layered over the shared-slab segments of [`super::log`]:
//! followers replicate by adopting the leader's segment `Arc`s
//! ([`super::log::LogMirror`]), so in-process replication moves zero
//! payload bytes while still paying the modeled leader-egress /
//! follower-ingress / follower-disk costs a real inter-broker
//! replication stream would.
//!
//! Replication is *asynchronous* with a deterministic lag model: each
//! follower applies the leader's records up to its own high watermark,
//! which may trail the leader's end offset by an injected per-follower
//! lag ([`BrokerCluster::inject_follower_lag`] models a slow NIC/disk).
//! The leader tracks an explicit **in-sync-replica (ISR)** set: a
//! follower whose watermark gap exceeds the topic's
//! [`ReplicationConfig::replica_lag_max`] is ejected from the ISR and
//! re-admitted when it catches back up.  Produces are *acked* under a
//! configurable [`AckMode`]:
//!
//! * [`AckMode::Leader`] — acked once the leader appended; followers
//!   catch up asynchronously (their IO is billed as the deferred
//!   catch-up happens), so produce latency stays flat while a follower
//!   lags, like Kafka `acks=1`.
//! * [`AckMode::Quorum`] — acked only after every *ISR* follower has
//!   fully applied the batch (their IO is billed synchronously on the
//!   produce path, so latency rises with follower lag), and *rejected*
//!   while the ISR is smaller than `min_insync` (Kafka `acks=all` +
//!   `min.insync.replicas`): availability and latency are sacrificed so
//!   that no acked record can ever be lost to a node death.
//!
//! [`BrokerCluster::kill_broker`] models a broker node crash: the node
//! leaves the membership, every partition it led fails over — to the
//! first surviving *ISR* follower in replica-set order, falling back to
//! any surviving follower (an unclean election) when no ISR member
//! survives.  Records above the promoted follower's watermark are
//! counted as `lost_records` on the [`FailoverReport`], the queued
//! [`FailoverEvent`], and the [`ScalingAction::Failover`] event
//! recorded on every attached [`ScalingTimeline`] — so the sim
//! quantifies the durability-vs-latency trade per [`AckMode`].
//! Consumer-group offsets survive untouched (the group coordinator
//! state is modeled as replicated), and blocked fetchers wake against
//! the new leader, so recovery time lands on the same timeline as every
//! other scaling action (Luckow & Jha: startup/recovery time is a
//! first-class performance axis).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::metrics::{ScalingAction, ScalingEvent, ScalingTimeline};

use super::cluster::{BrokerCluster, Partition};
use super::log::LogMirror;

/// When a produce is acknowledged (and what happens while degraded).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AckMode {
    /// Ack after the leader append; followers replicate asynchronously.
    /// Keeps accepting writes (and keeps latency flat) while degraded
    /// or lagging — at the cost of losing a lagging follower's gap on
    /// unclean failover.
    #[default]
    Leader,
    /// Ack only after every in-sync follower applied the batch, and
    /// only while the ISR holds at least `min_insync` replicas; reject
    /// produces otherwise.  No acked record can be lost to failover.
    Quorum,
}

impl AckMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "leader" => Ok(AckMode::Leader),
            "quorum" => Ok(AckMode::Quorum),
            other => Err(Error::Config(format!(
                "unknown ack_mode '{other}' (expected: leader, quorum)"
            ))),
        }
    }
}

impl std::fmt::Display for AckMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AckMode::Leader => write!(f, "leader"),
            AckMode::Quorum => write!(f, "quorum"),
        }
    }
}

/// Per-topic replication configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicationConfig {
    /// Replicas per partition (leader included).  1 = unreplicated.
    pub factor: usize,
    pub ack_mode: AckMode,
    /// Minimum in-sync replicas a [`AckMode::Quorum`] produce requires.
    pub min_insync: usize,
    /// Largest watermark gap (in records) a follower may accumulate
    /// before it is ejected from the ISR.  0 = strict: any gap ejects.
    pub replica_lag_max: u64,
    /// Serve fetches from an in-sync follower co-located with the
    /// consumer (KIP-392-style read locality), fenced by that
    /// follower's high watermark.  Off by default: all fetches hit the
    /// leader.
    pub follower_fetch: bool,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            factor: 1,
            ack_mode: AckMode::Leader,
            min_insync: 1,
            replica_lag_max: 0,
            follower_fetch: false,
        }
    }
}

impl ReplicationConfig {
    pub fn new(factor: usize) -> Self {
        ReplicationConfig { factor, ..Default::default() }
    }

    pub fn with_ack_mode(mut self, mode: AckMode) -> Self {
        self.ack_mode = mode;
        self
    }

    pub fn with_min_insync(mut self, min_insync: usize) -> Self {
        self.min_insync = min_insync;
        self
    }

    pub fn with_replica_lag_max(mut self, records: u64) -> Self {
        self.replica_lag_max = records;
        self
    }

    pub fn with_follower_fetch(mut self, enabled: bool) -> Self {
        self.follower_fetch = enabled;
        self
    }

    /// Validate against a broker-tier size (spec builders and topic
    /// creation share this, so both reject the same configs).
    pub fn validate(&self, broker_nodes: usize) -> Result<()> {
        if self.factor == 0 {
            return Err(Error::Config("replication factor must be >= 1".into()));
        }
        if self.factor > broker_nodes {
            return Err(Error::Config(format!(
                "replication factor {} exceeds the broker tier's {broker_nodes} node{}",
                self.factor,
                if broker_nodes == 1 { "" } else { "s" }
            )));
        }
        if self.min_insync == 0 || self.min_insync > self.factor {
            return Err(Error::Config(format!(
                "min_insync {} must be in 1..=factor ({})",
                self.min_insync, self.factor
            )));
        }
        Ok(())
    }
}

/// One partition's replica set: node ids in priority order (leader
/// first; failover promotes the first surviving *in-sync* entry) plus
/// each follower's adopted [`LogMirror`] and the per-follower lag
/// model.
#[derive(Debug, Default)]
pub(super) struct ReplicaSet {
    pub(super) nodes: Vec<NodeId>,
    pub(super) mirrors: HashMap<NodeId, LogMirror>,
    /// In-sync replicas (the leader is always a member).  Recomputed on
    /// every replication pass from each follower's watermark gap *and*
    /// injected lag vs the topic's `replica_lag_max`;
    /// [`AckMode::Quorum`] acks against this set.
    pub(super) isr: Vec<NodeId>,
    /// Injected lag in records per follower — the deterministic stand-in
    /// for a slow replication NIC/disk.  A held follower's watermark
    /// trails the leader's end offset by this many records.
    pub(super) held: HashMap<NodeId, u64>,
    /// Leader bytes appended but not yet applied per follower; drained
    /// (and billed to the follower's NIC/disk throttles) as the
    /// follower catches up.
    pub(super) pending_bytes: HashMap<NodeId, u64>,
}

/// What one [`BrokerCluster::kill_broker`] did, for assertions and logs.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub killed: NodeId,
    /// Partitions whose leadership moved to a surviving follower from
    /// the replica set (planned, replicated failover).
    pub promoted: usize,
    /// Partitions the dead node led with no replica to promote
    /// (factor 1): reassigned round-robin; their unconsumed tail above
    /// the last committed offset had no other home and is the data-loss
    /// exposure an unreplicated topic accepts.
    pub unreplicated: usize,
    /// Partitions (across all topics) inspected during the failover.
    pub partitions: usize,
    /// Acked records above the promoted followers' high watermarks —
    /// the unclean-leader-election loss.  Always 0 when every promoted
    /// follower was fully caught up (which [`AckMode::Quorum`]
    /// guarantees for acked records).
    pub lost_records: u64,
    /// Promotions whose follower was not in the ISR at kill time
    /// (unclean elections proper).
    pub unclean_elections: usize,
    /// Wall-clock seconds the failover took (membership edit, leader
    /// promotion, replica reassignment, fetcher wakeup).
    pub recovery_secs: f64,
}

/// A queued failover notification the autoscale controller drains
/// ([`BrokerCluster::take_failover_events`]) so node death enters the
/// control loop as a first-class signal.
#[derive(Debug, Clone)]
pub struct FailoverEvent {
    /// Seconds since the cluster's epoch.
    pub at_secs: f64,
    pub killed: NodeId,
    pub promoted: usize,
    pub unreplicated: usize,
    /// Records lost to unclean promotions (see [`FailoverReport`]).
    pub lost_records: u64,
    pub recovery_secs: f64,
}

/// Per-partition state a killed broker retains on its (modeled) local
/// disk, keyed by `(topic, partition id)`: the log mirror it held at
/// death plus the *divergence fence* — the last offset the surviving
/// leader epoch agrees with.  [`BrokerCluster::rejoin_broker`]
/// truncates the retained mirror to the fence (KIP-101-style: a
/// returning replica drops the tail the new leader's epoch never
/// acked) before re-admitting the node as a follower.
#[derive(Debug, Default)]
pub(super) struct DepartedBroker {
    pub(super) retained: HashMap<(String, usize), (LogMirror, u64)>,
}

/// What one [`BrokerCluster::rejoin_broker`] did, for assertions and
/// logs (the timeline analogue is [`ScalingAction::Rejoin`]).
#[derive(Debug, Clone)]
pub struct RejoinReport {
    pub node: NodeId,
    /// Partitions that re-admitted the node as a follower — only sets
    /// still below the topic's factor have an open slot; a set the
    /// survivors already refilled leaves the returning node idle until
    /// [`BrokerCluster::reassign_replicas`] moves work onto it.
    pub rejoined: usize,
    /// Partitions whose retained state the node carried back.
    pub partitions: usize,
    /// Records truncated off retained mirrors as leader-epoch
    /// divergence.  These were already charged as `lost_records` at
    /// kill time (or never acked); truncation is the returning
    /// replica reconciling with that verdict, not a new loss.
    pub truncated_records: u64,
    /// Wall-clock seconds the rejoin took.
    pub recovery_secs: f64,
}

impl BrokerCluster {
    /// Recompute every partition's replica set against `brokers`:
    /// leader = the partition's current leader index, followers = the
    /// next `factor - 1` brokers on the ring (capped at the tier size —
    /// a tier smaller than the factor leaves partitions
    /// *under-replicated*, visible through
    /// [`BrokerCluster::under_replicated`]).  Followers adopt the
    /// leader log's current segments fully caught up (the heal path),
    /// so the ISR resets to the full replica set; an injected lag
    /// re-ejects a slow follower on its next produce.
    ///
    /// With failure domains labeled ([`BrokerCluster::set_racks`])
    /// placement is rack-anti-affine: follower slots walk the ring
    /// from the leader preferring brokers in racks no earlier replica
    /// occupies, so a whole-rack loss cannot take out every replica of
    /// a partition.  When the tier has fewer usable domains than the
    /// factor the walk falls back to ring order — every replica is
    /// still placed, and each forced co-location bumps the explicit
    /// [`BrokerCluster::rack_constraint_violations`] counter.  Unracked
    /// clusters keep the exact historical ring-order placement.
    pub(super) fn assign_replica_sets(
        &self,
        partitions: &[Arc<Partition>],
        factor: usize,
        brokers: &[NodeId],
    ) {
        let racks = self.inner.racks.lock().unwrap().clone();
        let n = brokers.len().max(1);
        for p in partitions {
            let leader_idx = p.leader_index() % n;
            let slots = factor.min(n);
            let nodes: Vec<NodeId> = if racks.is_empty() {
                (0..slots).map(|k| brokers[(leader_idx + k) % n]).collect()
            } else {
                let leader = brokers[leader_idx];
                let mut nodes = vec![leader];
                // Racks already covered by chosen replicas; an
                // unlabeled broker constrains nothing.
                let mut used: Vec<usize> =
                    racks.get(&leader).copied().into_iter().collect();
                // Anti-affine pass: ring order, skipping covered racks.
                for k in 1..n {
                    if nodes.len() >= slots {
                        break;
                    }
                    let cand = brokers[(leader_idx + k) % n];
                    if let Some(r) = racks.get(&cand) {
                        if used.contains(r) {
                            continue;
                        }
                        used.push(*r);
                    }
                    nodes.push(cand);
                }
                // Fallback pass: racks exhausted before the factor —
                // fill the remaining slots in ring order anyway,
                // counting each forced co-location.
                for k in 1..n {
                    if nodes.len() >= slots {
                        break;
                    }
                    let cand = brokers[(leader_idx + k) % n];
                    if nodes.contains(&cand) {
                        continue;
                    }
                    nodes.push(cand);
                    self.inner
                        .rack_constraint_violations
                        .fetch_add(1, Ordering::Relaxed);
                }
                nodes
            };
            let mut set = p.replicas.lock().unwrap();
            set.mirrors.retain(|node, _| nodes[1..].contains(node));
            set.pending_bytes.retain(|node, _| nodes[1..].contains(node));
            for &f in &nodes[1..] {
                set.mirrors.insert(f, p.log.mirror());
                set.pending_bytes.insert(f, 0);
            }
            set.isr = nodes.clone();
            set.nodes = nodes;
        }
    }

    /// One replication pass for a partition: every follower adopts the
    /// leader's current segments (zero payload copies) and advances its
    /// applied watermark as far as the lag model allows, paying the
    /// modeled inter-broker stream costs — leader egress, follower
    /// ingress, follower disk — for exactly the bytes it applies.  The
    /// ISR is then recomputed from each follower's watermark gap vs the
    /// topic's `replica_lag_max`.
    ///
    /// `new_bytes` is the payload size a just-appended batch added to
    /// each follower's backlog (0 for a heartbeat pass).  Under
    /// [`AckMode::Quorum`] an in-sync follower (injected lag within
    /// `replica_lag_max`) is driven to full catch-up before the produce
    /// acks — that synchronous bill is the latency cost of quorum acks;
    /// under [`AckMode::Leader`] followers trail by their injected lag
    /// and the bill is deferred, keeping the produce path flat.
    pub(super) fn sync_partition_followers(
        &self,
        p: &Partition,
        rep: &ReplicationConfig,
        new_bytes: usize,
    ) {
        let mut set = p.replicas.lock().unwrap();
        if set.nodes.len() <= 1 {
            if set.isr != set.nodes {
                set.isr = set.nodes.clone();
            }
            return;
        }
        let leader = set.nodes[0];
        let followers: Vec<NodeId> = set.nodes[1..].to_vec();
        let mirror = p.log.mirror();
        let leader_end = mirror.end_offset();
        let mut isr = vec![leader];
        for &f in &followers {
            let held = set.held.get(&f).copied().unwrap_or(0);
            let prev = set.mirrors.get(&f).map(|m| m.high_watermark()).unwrap_or(0);
            let backlog_bytes =
                set.pending_bytes.get(&f).copied().unwrap_or(0) + new_bytes as u64;
            // The follower applies up to the leader end minus its
            // injected lag — except under Quorum, where an in-sync
            // follower must fully apply before the ack.
            let target = if rep.ack_mode == AckMode::Quorum && held <= rep.replica_lag_max {
                leader_end
            } else {
                leader_end.saturating_sub(held)
            }
            .max(prev);
            let backlog_records = leader_end.saturating_sub(prev);
            let applied_records = target.saturating_sub(prev);
            // Bill the applied share of the byte backlog (exact for
            // uniform records; proportional otherwise).
            let bill = if backlog_records == 0 {
                0
            } else {
                (backlog_bytes as u128 * applied_records as u128 / backlog_records as u128)
                    as u64
            };
            if bill > 0 {
                self.inner.machine.node(leader).egress.acquire(bill as usize);
                self.inner.machine.node(f).ingress.acquire(bill as usize);
                self.inner.machine.node(f).disk.acquire(bill as usize);
            }
            set.pending_bytes.insert(f, backlog_bytes - bill);
            set.mirrors.insert(f, mirror.clone().with_high_watermark(target));
            // ISR admission needs both a closed gap and a healthy lag
            // model: a known-slow follower (held > replica_lag_max) is
            // ejected even while momentarily caught up, so a quorum can
            // never ack against a follower that cannot keep up with the
            // very batch being acked.
            if leader_end - target <= rep.replica_lag_max && held <= rep.replica_lag_max {
                isr.push(f);
            }
        }
        set.isr = isr;
    }

    /// Advance every follower of `topic` without a new produce — the
    /// modeled equivalent of the background replica fetcher running
    /// between produces.  Followers apply their pending backlog up to
    /// their injected lag (billing the deferred bytes), and followers
    /// whose gap closed re-enter the ISR.
    /// Heartbeats are aggregated per partition pass, not per record:
    /// one call settles every pending follower backlog of the topic.
    /// Sharded deployments drive
    /// [`BrokerCluster::replication_heartbeat_shard`] from each shard's
    /// reactor instead, so the ISR bookkeeping of a partition only ever
    /// runs on its owning core.
    pub fn replication_heartbeat(&self, topic: &str) -> Result<()> {
        let t = self.topic(topic)?;
        for p in &t.partitions {
            self.sync_partition_followers(p, &t.replication, 0);
        }
        Ok(())
    }

    /// Per-shard ISR heartbeat: advance the followers of only the
    /// partitions of `topic` owned by data-plane shard `shard` (see
    /// [`crate::broker::shard::shard_of`]), returning how many
    /// partitions were heartbeaten.  This is the shard-affine form of
    /// [`BrokerCluster::replication_heartbeat`]: each shard settles its
    /// own partitions' quorum acks once per heartbeat — one aggregated
    /// pass per shard flush instead of per-record ack traffic — and
    /// never touches replica state owned by a sibling shard.
    pub fn replication_heartbeat_shard(&self, topic: &str, shard: usize) -> Result<usize> {
        if shard >= self.n_shards() {
            return Err(Error::Broker(format!(
                "shard {shard} out of range (cluster has {} shards)",
                self.n_shards()
            )));
        }
        let t = self.topic(topic)?;
        let mut settled = 0;
        for p in t.partitions.iter().filter(|p| p.shard_id() == shard) {
            self.sync_partition_followers(p, &t.replication, 0);
            settled += 1;
        }
        Ok(settled)
    }

    /// Partitions of `topic` whose alive replica count is below the
    /// topic's configured factor — durability headroom is reduced, but
    /// quorum may still be healthy.  The planner treats this as
    /// repair-worthy only when [`BrokerCluster::below_min_insync`] also
    /// fires.
    pub fn under_replicated(&self, topic: &str) -> Result<usize> {
        let t = self.topic(topic)?;
        Ok(t.partitions
            .iter()
            .filter(|p| p.replicas.lock().unwrap().nodes.len() < t.replication.factor)
            .count())
    }

    /// Partitions of `topic` whose ISR is smaller than the topic's
    /// `min_insync` — the quorum-degraded signal: these partitions
    /// reject [`AckMode::Quorum`] produces right now.  This (not mere
    /// under-replication) drives the planner's broker-repair step.
    pub fn below_min_insync(&self, topic: &str) -> Result<usize> {
        let t = self.topic(topic)?;
        let min = t.replication.min_insync;
        Ok(t.partitions
            .iter()
            .filter(|p| p.replicas.lock().unwrap().isr.len() < min)
            .count())
    }

    /// Inject a modeled replication lag of `records` for broker `node`
    /// on every partition of `topic` it follows — the deterministic
    /// stand-in for a follower with a slow NIC/disk.  The follower's
    /// watermark will trail the leader by up to `records` from the next
    /// produce on; it drops out of the ISR on the next replication pass
    /// (the pre-produce quorum gate included) once either its gap or
    /// the injection itself exceeds the topic's `replica_lag_max`.
    /// `records = 0` clears the injection; the follower re-enters the
    /// ISR when its gap closes.
    pub fn inject_follower_lag(&self, topic: &str, node: NodeId, records: u64) -> Result<()> {
        let t = self.topic(topic)?;
        for p in &t.partitions {
            let mut set = p.replicas.lock().unwrap();
            if records == 0 {
                set.held.remove(&node);
            } else {
                set.held.insert(node, records);
            }
        }
        Ok(())
    }

    /// The current in-sync replica set of one partition (leader first).
    pub fn in_sync_replicas(&self, topic: &str, partition: usize) -> Result<Vec<NodeId>> {
        let t = self.topic(topic)?;
        let p = t
            .partitions
            .get(partition)
            .ok_or_else(|| Error::Broker(format!("{topic}/{partition}: no such partition")))?;
        Ok(p.replicas.lock().unwrap().isr.clone())
    }

    /// Records follower `node` has yet to apply on one partition: the
    /// leader log's end offset minus the follower's high watermark.
    /// 0 for the leader itself and for non-replica nodes.
    pub fn follower_gap(&self, topic: &str, partition: usize, node: NodeId) -> Result<u64> {
        let t = self.topic(topic)?;
        let p = t
            .partitions
            .get(partition)
            .ok_or_else(|| Error::Broker(format!("{topic}/{partition}: no such partition")))?;
        let set = p.replicas.lock().unwrap();
        Ok(set
            .mirrors
            .get(&node)
            .map(|m| p.log.end_offset().saturating_sub(m.high_watermark()))
            .unwrap_or(0))
    }

    /// The broker node coordinating `group`'s offsets — jump-consistent
    /// over the *stable* ring of every broker the cluster has ever
    /// known, walked forward past dead nodes.  Unrelated membership
    /// churn therefore leaves a group's coordinator in place: killing
    /// one broker remaps only the groups that node coordinated (~1/n of
    /// them), and adding brokers appends ring slots instead of
    /// reshuffling the modulus.  The offset store itself is modeled as
    /// replicated coordinator state (it lives with the cluster, not the
    /// node), which is exactly the durability claim
    /// `offsets_survive_coordinator_death` pins: killing the
    /// coordinator changes this answer but not one committed offset.
    pub fn group_coordinator(&self, group: &str) -> NodeId {
        let brokers = self.inner.broker_nodes.load();
        let ring = self.inner.coordinator_ring.lock().unwrap();
        if ring.is_empty() {
            return brokers[0];
        }
        let h = super::repartition::key_hash(group.as_bytes());
        let start = super::repartition::jump_hash(h, ring.len());
        (0..ring.len())
            .map(|i| ring[(start + i) % ring.len()])
            .find(|n| brokers.contains(n))
            .unwrap_or(brokers[0])
    }

    /// Attach a timeline: every subsequent failover records a
    /// [`ScalingAction::Failover`] event (with its recovery time as the
    /// event cost) on it, alongside whatever the autoscaler records.
    pub fn add_scaling_timeline(&self, timeline: Arc<ScalingTimeline>) {
        self.inner.timelines.lock().unwrap().push(timeline);
    }

    /// Drain queued failover notifications (the autoscale control loop
    /// calls this every tick).
    pub fn take_failover_events(&self) -> Vec<FailoverEvent> {
        std::mem::take(&mut *self.inner.failover_events.lock().unwrap())
    }

    /// Kill broker `node`: remove it from the membership and fail over
    /// every partition it led — deterministically, to the first
    /// surviving *in-sync* follower in replica-set order, falling back
    /// to any surviving follower (an unclean election, counted on the
    /// report) when no ISR member survives; factor-1 partitions fall
    /// back to round-robin reassignment and are counted as
    /// `unreplicated`.  Records above the promoted follower's high
    /// watermark are counted as `lost_records`.  Committed
    /// consumer-group offsets survive untouched; blocked fetchers wake
    /// and re-resolve the new leader.  The last alive broker cannot be
    /// killed.
    pub fn kill_broker(&self, node: NodeId) -> Result<FailoverReport> {
        self.check_running()?;
        let _control = self.inner.control.lock().unwrap();
        self.kill_broker_inner(node)
    }

    /// Kill every alive broker labeled with failure domain `rack` in
    /// one atomic control-plane action — the whole-rack outage
    /// (switch/PDU loss) that rack-anti-affine placement exists to
    /// survive.  Each victim fails over exactly as
    /// [`BrokerCluster::kill_broker`] would, under a single control
    /// lock so no produce or scaling action interleaves between the
    /// deaths.  Refused when the rack has no alive broker or holds
    /// every alive broker.
    pub fn kill_rack(&self, rack: usize) -> Result<Vec<FailoverReport>> {
        self.check_running()?;
        let _control = self.inner.control.lock().unwrap();
        let alive = self.inner.broker_nodes.load();
        let victims: Vec<NodeId> = {
            let racks = self.inner.racks.lock().unwrap();
            alive.iter().copied().filter(|b| racks.get(b) == Some(&rack)).collect()
        };
        if victims.is_empty() {
            return Err(Error::Broker(format!("rack {rack} has no alive broker")));
        }
        if victims.len() == alive.len() {
            return Err(Error::Broker(format!(
                "cannot kill rack {rack}: it holds every alive broker"
            )));
        }
        let mut reports = Vec::with_capacity(victims.len());
        for v in victims {
            reports.push(self.kill_broker_inner(v)?);
        }
        Ok(reports)
    }

    /// The kill path proper; the caller holds the control lock.
    fn kill_broker_inner(&self, node: NodeId) -> Result<FailoverReport> {
        let started = Instant::now();
        let old_brokers = self.inner.broker_nodes.load();
        if !old_brokers.contains(&node) {
            return Err(Error::Broker(format!("broker node {node} is not in the cluster")));
        }
        let brokers: Vec<NodeId> =
            old_brokers.iter().copied().filter(|b| *b != node).collect();
        if brokers.is_empty() {
            return Err(Error::Broker("cannot kill the last broker".into()));
        }
        let n_old = old_brokers.len();
        let n = brokers.len();
        self.inner.broker_nodes.store(Arc::new(brokers.clone()));

        let mut promoted = 0usize;
        let mut unreplicated = 0usize;
        let mut partitions = 0usize;
        let mut lost_records = 0u64;
        let mut unclean_elections = 0usize;
        // What the dead node keeps on its (modeled) local disk, for a
        // later rejoin_broker: its mirror per followed partition, and
        // the divergence fence per led partition — everything above the
        // promoted survivor's watermark belongs to the dead leader's
        // epoch alone and must be truncated on re-entry.
        let mut retained: HashMap<(String, usize), (LogMirror, u64)> = HashMap::new();
        let topics = self.inner.topics.load();
        for topic in topics.values() {
            for p in &topic.partitions {
                partitions += 1;
                let old_leader = old_brokers[p.leader_index() % n_old];
                let new_leader = if old_leader != node {
                    // Leadership survives; only its index moved with the
                    // membership edit.  If the dead node followed this
                    // partition, it retains its applied mirror — no
                    // divergence: a follower never wrote past its
                    // watermark, so its fence is its own end.
                    if let Some(m) = p.replicas.lock().unwrap().mirrors.get(&node) {
                        retained.insert(
                            (topic.name.clone(), p.id),
                            (m.clone(), m.end_offset()),
                        );
                    }
                    old_leader
                } else {
                    // Deterministic promotion: first surviving *ISR*
                    // follower in replica-set order, else any surviving
                    // follower (unclean); factor-1 partitions have none
                    // and fall back to round-robin placement.
                    let survivor = {
                        let set = p.replicas.lock().unwrap();
                        let pick = set
                            .nodes
                            .iter()
                            .copied()
                            .find(|r| *r != node && set.isr.contains(r))
                            .or_else(|| set.nodes.iter().copied().find(|r| *r != node));
                        pick.map(|s| {
                            let watermark = set
                                .mirrors
                                .get(&s)
                                .map(|m| m.high_watermark())
                                .unwrap_or(0);
                            (s, watermark, set.isr.contains(&s))
                        })
                    };
                    match survivor {
                        Some((s, watermark, in_isr)) => {
                            promoted += 1;
                            // Unclean-election accounting: acked records
                            // the promoted follower never applied.  The
                            // shared slabs keep the bytes physically
                            // readable in-process; a real deployment
                            // would have lost them, so the timeline
                            // charges them as lost.
                            lost_records +=
                                p.log.end_offset().saturating_sub(watermark);
                            if !in_isr {
                                unclean_elections += 1;
                            }
                            // The dead leader keeps its full log, but
                            // everything past the survivor's watermark
                            // now belongs to an abandoned epoch: fence
                            // at the watermark, truncate on rejoin.
                            retained.insert(
                                (topic.name.clone(), p.id),
                                (p.log.mirror(), watermark),
                            );
                            s
                        }
                        None => {
                            unreplicated += 1;
                            // Unreplicated partition: nothing diverges
                            // (no other epoch exists), the dead node
                            // retains its whole log.
                            retained.insert(
                                (topic.name.clone(), p.id),
                                (p.log.mirror(), p.log.end_offset()),
                            );
                            brokers[p.id % n]
                        }
                    }
                };
                let idx = brokers
                    .iter()
                    .position(|b| *b == new_leader)
                    .expect("new leader is an alive broker");
                p.set_leader_index(idx);
                // The promoted leader owns the full shared log, so
                // everything replicated (and, in this in-process model,
                // everything appended) stays readable: re-publish the
                // visibility watermark at the log end.
                p.high_watermark.fetch_max(p.log.end_offset(), Ordering::AcqRel);
            }
            // Refill follower slots from the survivors (a tier now
            // smaller than the factor leaves partitions degraded).
            self.assign_replica_sets(&topic.partitions, topic.replication.factor, &brokers);
        }
        self.inner.departed.lock().unwrap().insert(node, DepartedBroker { retained });

        // Wake every parked fetcher: the leader it resolved may be the
        // dead node; the fetch loop re-resolves against the new
        // membership on its next pass.  Forced rings (one per shard,
        // not per partition) bypass the data-plane coalescing gate —
        // a control-plane wakeup must reach fetchers racing into the
        // park window.
        self.inner.shards.ring_all();

        let recovery_secs = started.elapsed().as_secs_f64();
        let at_secs = self.elapsed_ns() as f64 / 1e9;
        let event = ScalingEvent {
            at_secs,
            action: ScalingAction::Failover,
            delta_nodes: 1,
            total_nodes: n,
            lag: 0,
            partitions,
            policy: "failover".to_string(),
            reaction_secs: recovery_secs,
            cost_secs: recovery_secs,
            lost_records,
        };
        for timeline in self.inner.timelines.lock().unwrap().iter() {
            timeline.record(event.clone());
        }
        self.inner.failover_events.lock().unwrap().push(FailoverEvent {
            at_secs,
            killed: node,
            promoted,
            unreplicated,
            lost_records,
            recovery_secs,
        });
        Ok(FailoverReport {
            killed: node,
            promoted,
            unreplicated,
            partitions,
            lost_records,
            unclean_elections,
            recovery_secs,
        })
    }

    /// Re-admit a previously killed broker with the log state it
    /// retained at death.  The returning replica first reconciles with
    /// the current leader epoch: every retained mirror is truncated to
    /// its divergence fence (KIP-101-style — the tail past the
    /// promoted survivor's watermark was charged as lost at kill time
    /// and must not resurface), with the dropped total reported as
    /// `truncated_records`.  The node then re-enters each replica set
    /// that still has an open slot as an *out-of-sync* follower: it
    /// joins the ISR only after catching up through the normal
    /// replication path (a heartbeat or the next produce pass) — never
    /// by fiat at rejoin time.  Its catch-up transfer is billed the
    /// same way as the `add_brokers` heal: followers adopt the shared
    /// slabs with no pending byte backlog, so re-replication IO is not
    /// double-charged on top of the original appends.
    ///
    /// Only brokers that left via [`BrokerCluster::kill_broker`] /
    /// [`BrokerCluster::kill_rack`] can rejoin; planned removals and
    /// genuinely new nodes go through [`BrokerCluster::add_brokers`],
    /// which clears any stale departed state for re-admitted ids.
    pub fn rejoin_broker(&self, node: NodeId) -> Result<RejoinReport> {
        self.check_running()?;
        let started = Instant::now();
        let _control = self.inner.control.lock().unwrap();
        let old_brokers = self.inner.broker_nodes.load();
        if old_brokers.contains(&node) {
            return Err(Error::Broker(format!(
                "broker node {node} is already a cluster member"
            )));
        }
        let mut dep =
            self.inner.departed.lock().unwrap().remove(&node).ok_or_else(|| {
                Error::Broker(format!(
                    "broker node {node} never departed this cluster \
                     (add_brokers admits new nodes)"
                ))
            })?;
        let n_old = old_brokers.len();
        let topics = self.inner.topics.load();
        // Leaders are stored as *indices* into the membership list;
        // appending a member changes the modulus and would silently
        // move leaderships onto the returning node.  Pin every index
        // to its current resolution first — existing brokers keep
        // their positions across the append, so leadership is
        // preserved exactly.
        for topic in topics.values() {
            for p in &topic.partitions {
                p.set_leader_index(p.leader_index() % n_old);
            }
        }
        let mut brokers: Vec<NodeId> = old_brokers.iter().copied().collect();
        brokers.push(node);
        self.inner.broker_nodes.store(Arc::new(brokers.clone()));
        {
            // First-ever sighting of this id appends a coordinator
            // ring slot; a returning id reclaims its original slot
            // (same stability contract as add_brokers).
            let mut ring = self.inner.coordinator_ring.lock().unwrap();
            if !ring.contains(&node) {
                ring.push(node);
            }
        }

        let mut truncated_records = 0u64;
        let mut rejoined = 0usize;
        let partitions = dep.retained.len();
        for topic in topics.values() {
            for p in &topic.partitions {
                let Some((mut mirror, fence)) =
                    dep.retained.remove(&(topic.name.clone(), p.id))
                else {
                    continue;
                };
                truncated_records += mirror.truncate_to(fence);
                let mut set = p.replicas.lock().unwrap();
                if set.nodes.len() < topic.replication.factor
                    && !set.nodes.contains(&node)
                {
                    set.nodes.push(node);
                    set.mirrors.insert(node, mirror);
                    set.pending_bytes.insert(node, 0);
                    rejoined += 1;
                    // Deliberately NOT pushed into set.isr: the
                    // truncated watermark trails the leader, and ISR
                    // re-entry must come from the replication pass
                    // observing a closed gap.
                }
            }
        }

        // Wake parked fetchers so follower-fetch routing can see the
        // returned replica on its next pass.
        self.inner.shards.ring_all();

        let recovery_secs = started.elapsed().as_secs_f64();
        let at_secs = self.elapsed_ns() as f64 / 1e9;
        let event = ScalingEvent {
            at_secs,
            action: ScalingAction::Rejoin,
            delta_nodes: 1,
            total_nodes: brokers.len(),
            lag: 0,
            partitions,
            policy: "rejoin".to_string(),
            reaction_secs: recovery_secs,
            cost_secs: recovery_secs,
            lost_records: truncated_records,
        };
        for timeline in self.inner.timelines.lock().unwrap().iter() {
            timeline.record(event.clone());
        }
        Ok(RejoinReport { node, rejoined, partitions, truncated_records, recovery_secs })
    }

    /// Fraction of replicated partitions (factor >= 2) whose replica
    /// set is needlessly rack-crowded: two replicas share a failure
    /// domain even though the alive tier spans enough distinct domains
    /// to spread them.  0.0 when the tier has at most one labeled
    /// domain, or when every co-location is forced (factor exceeds the
    /// domain count).  This is the placement-health signal the
    /// autoscale planner turns into a
    /// [`BrokerCluster::reassign_replicas`] step.
    pub fn rack_skew(&self) -> f64 {
        let racks = self.inner.racks.lock().unwrap().clone();
        let brokers = self.inner.broker_nodes.load();
        let mut distinct: Vec<usize> = Vec::new();
        for b in brokers.iter() {
            if let Some(r) = racks.get(b) {
                if !distinct.contains(r) {
                    distinct.push(*r);
                }
            }
        }
        if distinct.len() <= 1 {
            return 0.0;
        }
        let topics = self.inner.topics.load();
        let mut total = 0usize;
        let mut crowded = 0usize;
        for topic in topics.values() {
            for p in &topic.partitions {
                let set = p.replicas.lock().unwrap();
                if set.nodes.len() < 2 {
                    continue;
                }
                total += 1;
                let mut seen: Vec<usize> = Vec::new();
                let mut collides = false;
                for n in &set.nodes {
                    if let Some(r) = racks.get(n) {
                        if seen.contains(r) {
                            collides = true;
                            break;
                        }
                        seen.push(*r);
                    }
                }
                // A collision only counts as crowding when the tier
                // could have spread this set (forced co-location is a
                // violation counter's business, not skew's).
                if collides && set.nodes.len() <= distinct.len() {
                    crowded += 1;
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            crowded as f64 / total as f64
        }
    }

    /// Move follower replicas off rack-crowded and hot brokers without
    /// touching leaderships or the tier size — the actuation behind
    /// the planner's `ReassignReplicas` step.  Two passes, both
    /// deterministic (topics by name, partitions in order, candidate
    /// brokers by `(follower load, node id)`):
    ///
    /// 1. **Rack repair** — for each partition whose replica set holds
    ///    two replicas in one failure domain, move the first colliding
    ///    *follower* slot to the least-loaded alive broker outside the
    ///    set whose rack the remaining replicas don't occupy.
    /// 2. **Load spread** — while the follower-count spread between
    ///    the hottest and coldest broker exceeds 1, move one follower
    ///    slot from the hottest to the coldest broker, but never into
    ///    a new rack collision.
    ///
    /// A moved follower adopts the leader's current shared slabs fully
    /// caught up (the same heal path `add_brokers` uses) and replaces
    /// the victim in the ISR.  Returns the number of moves.
    pub fn reassign_replicas(&self) -> Result<usize> {
        self.check_running()?;
        let _control = self.inner.control.lock().unwrap();
        let racks = self.inner.racks.lock().unwrap().clone();
        let brokers = self.inner.broker_nodes.load();
        let topics = self.inner.topics.load();
        let mut names: Vec<&String> = topics.keys().collect();
        names.sort();

        // Follower slots currently hosted per alive broker.
        let mut load: HashMap<NodeId, usize> = brokers.iter().map(|b| (*b, 0)).collect();
        for name in &names {
            for p in &topics[*name].partitions {
                let set = p.replicas.lock().unwrap();
                for f in set.nodes.iter().skip(1) {
                    if let Some(l) = load.get_mut(f) {
                        *l += 1;
                    }
                }
            }
        }

        let mut moves = 0usize;
        fn move_follower(
            set: &mut ReplicaSet,
            slot: usize,
            target: NodeId,
            p: &Partition,
            load: &mut HashMap<NodeId, usize>,
        ) {
            let victim = set.nodes[slot];
            set.nodes[slot] = target;
            set.mirrors.remove(&victim);
            set.pending_bytes.remove(&victim);
            set.mirrors.insert(target, p.log.mirror());
            set.pending_bytes.insert(target, 0);
            set.isr.retain(|n| *n != victim);
            if !set.isr.contains(&target) {
                set.isr.push(target);
            }
            if let Some(l) = load.get_mut(&victim) {
                *l = l.saturating_sub(1);
            }
            if let Some(l) = load.get_mut(&target) {
                *l += 1;
            }
        }

        // Pass 1: rack repair.
        for name in &names {
            let topic = &topics[*name];
            for p in &topic.partitions {
                let mut set = p.replicas.lock().unwrap();
                let mut used: Vec<usize> = Vec::new();
                let mut slot = None;
                for (i, n) in set.nodes.iter().enumerate() {
                    if let Some(r) = racks.get(n) {
                        if i > 0 && used.contains(r) {
                            slot = Some(i);
                            break;
                        }
                        used.push(*r);
                    }
                }
                let Some(i) = slot else { continue };
                let mut kept: Vec<usize> = Vec::new();
                for (j, n) in set.nodes.iter().enumerate() {
                    if j == i {
                        continue;
                    }
                    if let Some(r) = racks.get(n) {
                        if !kept.contains(r) {
                            kept.push(*r);
                        }
                    }
                }
                let mut candidates: Vec<NodeId> = brokers
                    .iter()
                    .copied()
                    .filter(|b| !set.nodes.contains(b))
                    .filter(|b| racks.get(b).map_or(true, |r| !kept.contains(r)))
                    .collect();
                candidates.sort_by_key(|b| (load.get(b).copied().unwrap_or(0), *b));
                let Some(&target) = candidates.first() else { continue };
                move_follower(&mut set, i, target, p, &mut load);
                moves += 1;
            }
        }

        // Pass 2: load spread.
        loop {
            let Some((hot, hot_load)) = load
                .iter()
                .max_by_key(|(b, l)| (**l, std::cmp::Reverse(**b)))
                .map(|(b, l)| (*b, *l))
            else {
                break;
            };
            let Some((cold, cold_load)) =
                load.iter().min_by_key(|(b, l)| (**l, **b)).map(|(b, l)| (*b, *l))
            else {
                break;
            };
            if hot_load.saturating_sub(cold_load) <= 1 {
                break;
            }
            let mut moved = false;
            'scan: for name in &names {
                for p in &topics[*name].partitions {
                    let mut set = p.replicas.lock().unwrap();
                    let Some(i) =
                        set.nodes.iter().skip(1).position(|n| *n == hot).map(|k| k + 1)
                    else {
                        continue;
                    };
                    if set.nodes.contains(&cold) {
                        continue;
                    }
                    if let Some(r) = racks.get(&cold) {
                        let collide = set
                            .nodes
                            .iter()
                            .enumerate()
                            .any(|(j, n)| j != i && racks.get(n) == Some(r));
                        if collide {
                            continue;
                        }
                    }
                    move_follower(&mut set, i, cold, p, &mut load);
                    moves += 1;
                    moved = true;
                    break 'scan;
                }
            }
            if !moved {
                break;
            }
        }

        if moves > 0 {
            self.inner.shards.ring_all();
        }
        Ok(moves)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn cluster(brokers: usize) -> BrokerCluster {
        BrokerCluster::new(Machine::unthrottled(brokers + 2), (0..brokers).collect())
    }

    #[test]
    fn ack_mode_parses_and_displays() {
        assert_eq!(AckMode::parse("leader").unwrap(), AckMode::Leader);
        assert_eq!(AckMode::parse("quorum").unwrap(), AckMode::Quorum);
        assert!(AckMode::parse("all").is_err());
        assert_eq!(AckMode::Quorum.to_string(), "quorum");
    }

    #[test]
    fn replication_config_validates_bounds() {
        assert!(ReplicationConfig::new(0).validate(4).is_err(), "factor 0");
        assert!(ReplicationConfig::new(3).validate(2).is_err(), "factor > brokers");
        assert!(ReplicationConfig::new(2).validate(2).is_ok());
        assert!(
            ReplicationConfig::new(2).with_min_insync(3).validate(4).is_err(),
            "min_insync > factor"
        );
        assert!(ReplicationConfig::new(2).with_min_insync(0).validate(4).is_err());
    }

    #[test]
    fn replicated_topic_assigns_follower_sets_round_robin() {
        let c = cluster(3);
        c.create_topic_replicated("t", 3, ReplicationConfig::new(2)).unwrap();
        let t = c.topic("t").unwrap();
        for (i, p) in t.partitions.iter().enumerate() {
            let set = p.replicas.lock().unwrap();
            assert_eq!(set.nodes.len(), 2);
            assert_eq!(set.nodes[0], i % 3, "leader first");
            assert_eq!(set.nodes[1], (i + 1) % 3, "next broker on the ring follows");
            assert_eq!(set.isr, set.nodes, "fresh replicas start in sync");
        }
        assert_eq!(c.under_replicated("t").unwrap(), 0);
        assert_eq!(c.below_min_insync("t").unwrap(), 0);
    }

    #[test]
    fn produce_mirrors_to_followers_and_charges_their_io() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2)).unwrap();
        let io0 = c.broker_io();
        c.produce("t", 0, 2, &[vec![0u8; 100]]).unwrap();
        let io1 = c.broker_io();
        // Leader (node 0): producer ingress + replication egress.
        assert_eq!(io1[0].nic_in_bytes - io0[0].nic_in_bytes, 100);
        assert_eq!(io1[0].nic_out_bytes - io0[0].nic_out_bytes, 100);
        assert_eq!(io1[0].disk_bytes - io0[0].disk_bytes, 100);
        // Follower (node 1): replication ingress + its own disk append.
        assert_eq!(io1[1].nic_in_bytes - io0[1].nic_in_bytes, 100);
        assert_eq!(io1[1].disk_bytes - io0[1].disk_bytes, 100);
        // And the mirror tracks the leader's end offset, zero-copy.
        let t = c.topic("t").unwrap();
        let set = t.partitions[0].replicas.lock().unwrap();
        assert_eq!(set.mirrors[&1].end_offset(), 1);
        assert_eq!(set.mirrors[&1].high_watermark(), 1, "zero-lag follower fully applied");
        assert_eq!(set.isr, vec![0, 1]);
    }

    #[test]
    fn shard_heartbeat_settles_only_owned_partitions() {
        let c = BrokerCluster::with_shards(
            Machine::unthrottled(4),
            vec![0, 1],
            crate::broker::LogConfig::default(),
            2,
        );
        c.create_topic_replicated("t", 4, ReplicationConfig::new(2)).unwrap();
        let t = c.topic("t").unwrap();
        let owned: Vec<usize> = (0..2)
            .map(|s| t.partitions.iter().filter(|p| p.shard_id() == s).count())
            .collect();
        assert_eq!(owned.iter().sum::<usize>(), 4, "every partition has one owner");
        assert_eq!(c.replication_heartbeat_shard("t", 0).unwrap(), owned[0]);
        assert_eq!(c.replication_heartbeat_shard("t", 1).unwrap(), owned[1]);
        assert!(c.replication_heartbeat_shard("t", 9).is_err(), "shard out of range");

        // A lagging follower is ejected from partition 0's ISR by the
        // produce, and re-admitted by a heartbeat on *its owning
        // shard* alone once the injection clears — the shard-affine
        // form of the aggregated quorum-ack settlement.
        c.inject_follower_lag("t", 1, 3).unwrap();
        c.produce("t", 0, 2, &[vec![1], vec![2]]).unwrap();
        assert_eq!(t.partitions[0].replicas.lock().unwrap().isr, vec![0]);
        c.inject_follower_lag("t", 1, 0).unwrap();
        let sid = t.partitions[0].shard_id();
        assert!(c.replication_heartbeat_shard("t", sid).unwrap() >= 1);
        assert_eq!(t.partitions[0].replicas.lock().unwrap().isr, vec![0, 1]);
    }

    #[test]
    fn kill_broker_promotes_first_surviving_follower() {
        let c = cluster(3);
        c.create_topic_replicated("t", 3, ReplicationConfig::new(2)).unwrap();
        c.produce("t", 0, 3, &[b"a".to_vec(), b"b".to_vec()]).unwrap();
        assert_eq!(c.leader_node("t", 0).unwrap(), 0);
        let report = c.kill_broker(0).unwrap();
        assert_eq!(report.killed, 0);
        assert_eq!(report.promoted, 1, "partition 0's leadership moves");
        assert_eq!(report.unreplicated, 0);
        assert_eq!(report.lost_records, 0, "the follower was fully caught up");
        assert_eq!(report.unclean_elections, 0);
        assert!(report.recovery_secs >= 0.0);
        // Partition 0 promoted to its follower (node 1), deterministically.
        assert_eq!(c.leader_node("t", 0).unwrap(), 1);
        assert_eq!(c.broker_nodes(), vec![1, 2]);
        // Every record is still readable through the shared slabs.
        let recs = c.fetch("t", 0, 0, usize::MAX, 3, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].value, b"b");
    }

    #[test]
    fn kill_broker_rejects_unknown_and_last_node() {
        let c = cluster(1);
        assert!(c.kill_broker(7).is_err(), "not a member");
        assert!(c.kill_broker(0).is_err(), "last broker");
        assert_eq!(c.broker_nodes(), vec![0]);
    }

    #[test]
    fn quorum_rejects_produce_when_insync_below_minimum() {
        let c = cluster(2);
        c.create_topic_replicated(
            "t",
            1,
            ReplicationConfig::new(2).with_ack_mode(AckMode::Quorum).with_min_insync(2),
        )
        .unwrap();
        c.produce("t", 0, 2, &[vec![1]]).unwrap();
        c.kill_broker(0).unwrap();
        assert_eq!(c.under_replicated("t").unwrap(), 1);
        assert_eq!(c.below_min_insync("t").unwrap(), 1);
        // Quorum: quorum-degraded partition rejects produces...
        let err = c.produce("t", 0, 2, &[vec![2]]).unwrap_err();
        assert!(err.to_string().contains("in-sync"), "{err}");
        // ...until a replacement broker restores the replica set.
        c.add_brokers(vec![3]);
        assert_eq!(c.under_replicated("t").unwrap(), 0);
        assert_eq!(c.below_min_insync("t").unwrap(), 0);
        c.produce("t", 0, 2, &[vec![2]]).unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 2);
    }

    #[test]
    fn leader_ack_keeps_accepting_while_degraded() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2)).unwrap();
        c.kill_broker(1).unwrap();
        assert_eq!(c.under_replicated("t").unwrap(), 1);
        assert_eq!(
            c.below_min_insync("t").unwrap(),
            0,
            "min_insync 1 is satisfied by the leader alone"
        );
        c.produce("t", 0, 2, &[vec![9]]).unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 1);
    }

    #[test]
    fn offsets_survive_coordinator_death() {
        let c = cluster(3);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        c.produce("t", 0, 3, &[vec![1], vec![2], vec![3]]).unwrap();
        c.group_join("g", "t");
        c.commit("g", "t", 0, 2);
        let coordinator = c.group_coordinator("g");
        c.kill_broker(coordinator).unwrap();
        // The coordinator moved to a survivor; not one offset moved.
        assert_ne!(c.group_coordinator("g"), coordinator);
        assert_eq!(c.committed("g", "t", 0), 2);
        assert_eq!(c.group_lag("g", "t").unwrap(), 1);
    }

    #[test]
    fn failover_lands_on_attached_timelines_and_event_queue() {
        let c = cluster(2);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        let timeline = Arc::new(ScalingTimeline::new());
        c.add_scaling_timeline(timeline.clone());
        c.kill_broker(1).unwrap();
        assert_eq!(timeline.count(ScalingAction::Failover), 1);
        let ev = &timeline.events()[0];
        assert_eq!(ev.total_nodes, 1);
        assert_eq!(ev.partitions, 2);
        assert_eq!(ev.policy, "failover");
        assert!(ev.cost_secs >= 0.0, "recovery time is the event's cost");
        let queued = c.take_failover_events();
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].killed, 1);
        assert_eq!(queued[0].promoted + queued[0].unreplicated, 1, "node 1 led partition 1");
        assert!(c.take_failover_events().is_empty(), "drained");
    }

    #[test]
    fn unreplicated_partitions_fall_back_to_round_robin() {
        let c = cluster(2);
        c.create_topic("t", 4).unwrap(); // factor 1
        let report = c.kill_broker(1).unwrap();
        assert_eq!(report.promoted, 0);
        assert_eq!(report.unreplicated, 2, "node 1 led partitions 1 and 3");
        for p in 0..4 {
            assert_eq!(c.leader_node("t", p).unwrap(), 0);
        }
    }

    #[test]
    fn follower_lag_shrinks_isr_and_catchup_expands_it() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2).with_replica_lag_max(2))
            .unwrap();
        c.inject_follower_lag("t", 1, 5).unwrap();
        let batch: Vec<Vec<u8>> = (0..10).map(|i| vec![i as u8; 10]).collect();
        c.produce("t", 0, 2, &batch).unwrap();
        // The follower's watermark trails by the injected 5 records —
        // past replica_lag_max 2, so it is ejected from the ISR.
        assert_eq!(c.follower_gap("t", 0, 1).unwrap(), 5);
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![0]);
        assert_eq!(c.under_replicated("t").unwrap(), 0, "the replica is alive, just slow");
        // Clearing the lag + a heartbeat pass catches it up (billing
        // the deferred bytes) and re-admits it to the ISR.
        c.inject_follower_lag("t", 1, 0).unwrap();
        let io0 = c.broker_io();
        c.replication_heartbeat("t").unwrap();
        let io1 = c.broker_io();
        assert_eq!(io1[1].nic_in_bytes - io0[1].nic_in_bytes, 50, "5 deferred 10B records");
        assert_eq!(c.follower_gap("t", 0, 1).unwrap(), 0);
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![0, 1]);
    }

    #[test]
    fn quorum_acks_against_isr_not_replica_list() {
        let c = cluster(2);
        c.create_topic_replicated(
            "t",
            1,
            ReplicationConfig::new(2).with_ack_mode(AckMode::Quorum).with_min_insync(2),
        )
        .unwrap();
        // replica_lag_max 0 (strict): a follower the lag model marks
        // slow is ejected on the next replication pass — including the
        // pre-append pass that gates the produce itself, so no record
        // is ever acked against a quorum the slow follower cannot
        // honor.
        c.inject_follower_lag("t", 1, 1).unwrap();
        let err = c.produce("t", 0, 2, &[vec![1]]).unwrap_err();
        assert!(err.to_string().contains("in-sync"), "{err}");
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![0]);
        // Both replicas are alive — the static list is full — but the
        // ISR is below min_insync, so quorum produces are rejected.
        assert_eq!(c.under_replicated("t").unwrap(), 0);
        assert_eq!(c.below_min_insync("t").unwrap(), 1);
        // Once the follower recovers, the produce-path heartbeat
        // re-admits it and the same produce succeeds.
        c.inject_follower_lag("t", 1, 0).unwrap();
        c.produce("t", 0, 2, &[vec![1]]).unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 1, "only the re-sent produce landed");
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![0, 1]);
        assert_eq!(c.below_min_insync("t").unwrap(), 0);
    }

    #[test]
    fn ack_modes_trade_produce_cost_for_durability_under_follower_lag() {
        // The §acceptance trade-off, pinned at the broker level with
        // charged replication bytes as the produce-latency proxy (all
        // throttles are unthrottled, so charged-bytes-on-the-ack-path
        // is the deterministic stand-in for produce latency).
        let total_bytes = 20 * 100u64;

        // Quorum: the lagging-but-in-sync follower is driven to full
        // catch-up on every ack (latency rises with lag) — and failover
        // therefore loses nothing.
        let q = cluster(2);
        q.create_topic_replicated(
            "t",
            1,
            ReplicationConfig::new(2)
                .with_ack_mode(AckMode::Quorum)
                .with_min_insync(2)
                .with_replica_lag_max(10),
        )
        .unwrap();
        q.inject_follower_lag("t", 1, 3).unwrap();
        let io0 = q.broker_io();
        for i in 0..20u8 {
            q.produce("t", 0, 2, &[vec![i; 100]]).unwrap();
        }
        let io1 = q.broker_io();
        assert_eq!(
            io1[1].nic_in_bytes - io0[1].nic_in_bytes,
            total_bytes,
            "quorum bills every replicated byte synchronously on the ack path"
        );
        let report = q.kill_broker(0).unwrap();
        assert_eq!(report.lost_records, 0, "no acked record is lost under quorum");
        assert_eq!(report.unclean_elections, 0);
        let recs = q.fetch("t", 0, 0, usize::MAX, 1, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 20);

        // Leader: the ack path stays flat (the follower's catch-up is
        // deferred, capped by its injected lag) — and killing the
        // leader records the follower's gap as lost on the timeline.
        let l = cluster(2);
        l.create_topic_replicated(
            "t",
            1,
            ReplicationConfig::new(2).with_replica_lag_max(10),
        )
        .unwrap();
        let timeline = Arc::new(ScalingTimeline::new());
        l.add_scaling_timeline(timeline.clone());
        l.inject_follower_lag("t", 1, 3).unwrap();
        let io0 = l.broker_io();
        for i in 0..20u8 {
            l.produce("t", 0, 2, &[vec![i; 100]]).unwrap();
        }
        let io1 = l.broker_io();
        assert_eq!(
            io1[1].nic_in_bytes - io0[1].nic_in_bytes,
            total_bytes - 300,
            "leader acks defer the lagging follower's last 3 records"
        );
        let report = l.kill_broker(0).unwrap();
        assert_eq!(report.lost_records, 3, "the follower's gap is charged as lost");
        let ev = &timeline.events()[0];
        assert_eq!(ev.lost_records, 3, "unclean-election loss lands on the timeline");
        let queued = l.take_failover_events();
        assert_eq!(queued[0].lost_records, 3);
    }

    #[test]
    fn coordinator_placement_stable_across_unrelated_churn() {
        // Regression for the `hash % alive_brokers.len()` coordinator
        // placement: any membership change remapped nearly every group.
        // Jump-hashing over the stable first-seen ring pins unrelated
        // groups in place exactly.
        let c = cluster(16);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        let groups: Vec<String> = (0..100).map(|i| format!("group-{i}")).collect();
        let before: Vec<NodeId> = groups.iter().map(|g| c.group_coordinator(g)).collect();
        // Kill the broker coordinating the fewest groups (<= 100/16 by
        // pigeonhole, so >= 90% of groups must stay put).
        let victim = (0..16)
            .min_by_key(|b| before.iter().filter(|n| *n == b).count())
            .unwrap();
        c.kill_broker(victim).unwrap();
        let mut moved = 0;
        for (g, b) in groups.iter().zip(&before) {
            let after = c.group_coordinator(g);
            if *b == victim {
                assert_ne!(after, victim, "dead coordinator must move");
                moved += 1;
            } else {
                assert_eq!(after, *b, "{g}: unrelated coordinator moved");
            }
        }
        assert!(moved * 10 <= groups.len(), "at most 1/16 < 10% of groups remap");
        // Re-adding the node restores its ring slot: every displaced
        // group returns home, and nothing else moves.
        c.add_brokers(vec![victim]);
        let after: Vec<NodeId> = groups.iter().map(|g| c.group_coordinator(g)).collect();
        assert_eq!(after, before);
        // A brand-new broker appends a ring slot; jump hashing moves
        // only the ~1/17 of groups that land on the new slot.
        c.add_brokers(vec![99]);
        let grown: Vec<NodeId> = groups.iter().map(|g| c.group_coordinator(g)).collect();
        let remapped = grown.iter().zip(&before).filter(|(a, b)| a != b).count();
        assert!(remapped * 5 <= groups.len(), "growth remaps only toward the new slot");
        for (a, b) in grown.iter().zip(&before) {
            if a != b {
                assert_eq!(*a, 99, "growth moves groups only onto the new broker");
            }
        }
    }

    #[test]
    fn rack_aware_placement_prefers_distinct_domains() {
        let c = BrokerCluster::with_racks(Machine::unthrottled(6), vec![0, 1, 2, 3], 2);
        assert_eq!(c.rack_of(0), Some(0));
        assert_eq!(c.rack_of(1), Some(1));
        assert_eq!(c.rack_of(3), Some(1));
        assert_eq!(c.rack_of(9), None, "not a broker");
        c.create_topic_replicated("t", 4, ReplicationConfig::new(2)).unwrap();
        assert_eq!(c.rack_constraint_violations(), 0);
        // Kill node 1: the survivors [0, 2, 3] sit in racks [0, 0, 1],
        // so ring order alone would co-locate — the follower walk must
        // skip the same-rack neighbor instead.
        c.kill_broker(1).unwrap();
        let t = c.topic("t").unwrap();
        for p in &t.partitions {
            let set = p.replicas.lock().unwrap();
            assert_eq!(set.nodes.len(), 2);
            let r0 = c.rack_of(set.nodes[0]).unwrap();
            let r1 = c.rack_of(set.nodes[1]).unwrap();
            assert_ne!(r0, r1, "partition {}: replicas share a rack", p.id);
        }
        assert_eq!(c.rack_constraint_violations(), 0, "anti-affinity needed no fallback");
        assert_eq!(c.rack_skew(), 0.0);
    }

    #[test]
    fn rack_exhaustion_falls_back_with_violation_accounting() {
        let c = BrokerCluster::with_racks(Machine::unthrottled(6), vec![0, 1, 2, 3], 2);
        // Factor 3 across 2 racks: every partition's third replica is
        // forced to co-locate — placed anyway, and counted.
        c.create_topic_replicated("t", 2, ReplicationConfig::new(3)).unwrap();
        assert_eq!(c.rack_constraint_violations(), 2, "one forced slot per partition");
        let t = c.topic("t").unwrap();
        for p in &t.partitions {
            assert_eq!(p.replicas.lock().unwrap().nodes.len(), 3, "fallback still places");
        }
        // Skew stays 0: with 2 distinct domains a factor-3 set cannot
        // spread, so the co-location is forced, not repairable.
        assert_eq!(c.rack_skew(), 0.0);
    }

    #[test]
    fn kill_rack_fails_over_every_broker_in_the_domain() {
        let c = BrokerCluster::with_racks(Machine::unthrottled(6), vec![0, 1, 2, 3], 2);
        c.create_topic_replicated("t", 4, ReplicationConfig::new(2)).unwrap();
        c.produce("t", 0, 4, &[vec![1], vec![2]]).unwrap();
        assert!(c.kill_rack(7).is_err(), "no such rack");
        let reports = c.kill_rack(1).unwrap();
        assert_eq!(reports.len(), 2, "nodes 1 and 3 die together");
        assert_eq!(reports[0].killed, 1);
        assert_eq!(reports[1].killed, 3);
        assert_eq!(c.broker_nodes(), vec![0, 2]);
        // Rack-anti-affine placement kept a replica of every partition
        // in rack 0, so every acked record is still readable.
        let recs = c.fetch("t", 0, 0, usize::MAX, 4, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 2);
        // The surviving tier is all of rack 0: killing it is refused.
        assert!(c.kill_rack(0).is_err(), "cannot kill every alive broker");
        assert_eq!(c.broker_nodes(), vec![0, 2]);
    }

    #[test]
    fn rejoin_truncates_divergent_tail_and_reenters_isr_after_catchup() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2).with_replica_lag_max(10))
            .unwrap();
        c.inject_follower_lag("t", 1, 3).unwrap();
        let batch: Vec<Vec<u8>> = (0..5).map(|i| vec![i as u8; 10]).collect();
        c.produce("t", 0, 2, &batch).unwrap();
        // Follower 1 applied 2 of 5 records; killing leader 0 promotes
        // it and charges the 3-record gap as lost.  That unapplied
        // tail is exactly what node 0's retained log now diverges by.
        let report = c.kill_broker(0).unwrap();
        assert_eq!(report.lost_records, 3);
        c.inject_follower_lag("t", 1, 0).unwrap();
        // The new leader continues on its own epoch.
        c.produce("t", 0, 2, &[vec![9u8; 10]]).unwrap();
        // Node 0 returns: the divergent tail is truncated (KIP-101) —
        // exactly the 3 records charged as lost, no more, no less.
        let rejoin = c.rejoin_broker(0).unwrap();
        assert_eq!(rejoin.node, 0);
        assert_eq!(rejoin.truncated_records, 3, "divergent tail dropped exactly");
        assert_eq!(rejoin.partitions, 1);
        assert_eq!(rejoin.rejoined, 1, "re-enters partition 0's replica set");
        assert!(rejoin.recovery_secs >= 0.0);
        assert_eq!(c.broker_nodes(), vec![1, 0]);
        // Leadership never moved off the survivor during the rejoin...
        assert_eq!(c.leader_node("t", 0).unwrap(), 1);
        // ...and the returning replica is NOT in the ISR: it trails by
        // the truncation plus the new epoch's records.
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![1]);
        assert!(c.follower_gap("t", 0, 0).unwrap() > 0);
        // ISR re-entry comes only from the normal catch-up path.
        c.replication_heartbeat("t").unwrap();
        assert_eq!(c.follower_gap("t", 0, 0).unwrap(), 0);
        assert_eq!(c.in_sync_replicas("t", 0).unwrap(), vec![1, 0]);
    }

    #[test]
    fn rejoin_rejects_members_and_strangers_and_lands_on_timeline() {
        let c = cluster(3);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        assert!(c.rejoin_broker(0).is_err(), "already a member");
        assert!(c.rejoin_broker(42).is_err(), "never departed");
        let timeline = Arc::new(ScalingTimeline::new());
        c.add_scaling_timeline(timeline.clone());
        c.kill_broker(2).unwrap();
        let report = c.rejoin_broker(2).unwrap();
        assert_eq!(report.node, 2);
        assert_eq!(c.broker_nodes(), vec![0, 1, 2]);
        assert_eq!(report.truncated_records, 0, "nothing produced, nothing diverged");
        assert_eq!(report.partitions, 1, "node 2 followed partition 1");
        assert_eq!(report.rejoined, 0, "the survivors already refilled the set");
        assert_eq!(timeline.count(ScalingAction::Rejoin), 1);
        let ev = timeline
            .events()
            .iter()
            .find(|e| e.action == ScalingAction::Rejoin)
            .cloned()
            .unwrap();
        assert_eq!(ev.policy, "rejoin");
        assert_eq!(ev.total_nodes, 3);
        assert_eq!(ev.delta_nodes, 1);
        assert_eq!(ev.lost_records, 0);
        // A planned removal leaves nothing retained to rejoin from.
        c.remove_brokers(&[2]).unwrap();
        let err = c.rejoin_broker(2).unwrap_err();
        assert!(err.to_string().contains("never departed"), "{err}");
    }

    #[test]
    fn reassign_moves_followers_off_crowded_racks() {
        let c = BrokerCluster::with_racks(Machine::unthrottled(6), vec![0, 1, 2, 3], 2);
        c.create_topic_replicated("t", 4, ReplicationConfig::new(2)).unwrap();
        c.kill_rack(1).unwrap();
        c.rejoin_broker(1).unwrap();
        c.rejoin_broker(3).unwrap();
        // The survivors (all rack 0) refilled every replica set during
        // the failover, so the rejoined rack-1 nodes found no open
        // slot: every set is co-located and the returning nodes idle.
        assert_eq!(c.rack_skew(), 1.0);
        let t = c.topic("t").unwrap();
        for p in &t.partitions {
            let set = p.replicas.lock().unwrap();
            assert!(!set.nodes.contains(&1) && !set.nodes.contains(&3));
        }
        // The reassignment pass spreads each partition back across
        // domains — moving follower slots only, never leaderships, and
        // never changing the tier size.
        let leaders: Vec<NodeId> =
            (0..4).map(|p| c.leader_node("t", p).unwrap()).collect();
        let moves = c.reassign_replicas().unwrap();
        assert_eq!(moves, 4, "every partition sheds its co-located follower");
        assert_eq!(c.rack_skew(), 0.0);
        assert_eq!(
            (0..4).map(|p| c.leader_node("t", p).unwrap()).collect::<Vec<_>>(),
            leaders,
            "reassignment moves followers, not leaders"
        );
        assert_eq!(c.broker_nodes(), vec![0, 2, 1, 3]);
        for p in &t.partitions {
            let set = p.replicas.lock().unwrap();
            let r: Vec<usize> =
                set.nodes.iter().map(|n| c.rack_of(*n).unwrap()).collect();
            assert_ne!(r[0], r[1], "partition {} spread across domains", p.id);
            assert_eq!(set.isr.len(), 2, "moved follower adopts a caught-up mirror");
        }
        // Converged: a second pass finds nothing to move.
        assert_eq!(c.reassign_replicas().unwrap(), 0);
    }
}
