//! Per-partition replication and crash-tolerant failover.
//!
//! Every partition carries a replica set — a leader plus `factor - 1`
//! followers — layered over the shared-slab segments of [`super::log`]:
//! followers replicate by adopting the leader's segment `Arc`s
//! ([`super::log::LogMirror`]), so in-process replication moves zero
//! payload bytes while still paying the modeled leader-egress /
//! follower-ingress / follower-disk costs a real inter-broker
//! replication stream would.  Produces are *acked* under a configurable
//! [`AckMode`]:
//!
//! * [`AckMode::Leader`] — acked once the leader appended (and, when
//!   followers exist, synchronously mirrored).  Stays available while
//!   the replica set is degraded, like Kafka `acks=1`.
//! * [`AckMode::Quorum`] — additionally *rejects* produces while fewer
//!   than `min_insync` replicas are alive (Kafka `acks=all` +
//!   `min.insync.replicas`): availability is sacrificed so that no
//!   acked record can ever be lost to a node death.
//!
//! [`BrokerCluster::kill_broker`] models a broker node crash: the node
//! leaves the membership, every partition it led fails over —
//! deterministically, to the first surviving follower in replica-set
//! order — consumer-group offsets survive untouched (the group
//! coordinator state is modeled as replicated), blocked fetchers wake
//! against the new leader, and the recovery is recorded as a
//! [`ScalingAction::Failover`] event on every attached
//! [`ScalingTimeline`] plus a [`FailoverEvent`] the autoscale
//! controller drains, so recovery time lands on the same timeline as
//! every other scaling action (Luckow & Jha: startup/recovery time is a
//! first-class performance axis).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::NodeId;
use crate::error::{Error, Result};
use crate::metrics::{ScalingAction, ScalingEvent, ScalingTimeline};

use super::cluster::{BrokerCluster, Partition};
use super::log::LogMirror;

/// When a produce is acknowledged (and what happens while degraded).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AckMode {
    /// Ack after the leader append (+ synchronous mirror adoption when
    /// followers are alive).  Keeps accepting writes while degraded.
    #[default]
    Leader,
    /// Ack only while at least `min_insync` replicas are alive; reject
    /// produces otherwise.  No acked record can be lost to failover.
    Quorum,
}

impl AckMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "leader" => Ok(AckMode::Leader),
            "quorum" => Ok(AckMode::Quorum),
            other => Err(Error::Config(format!(
                "unknown ack_mode '{other}' (expected: leader, quorum)"
            ))),
        }
    }
}

impl std::fmt::Display for AckMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AckMode::Leader => write!(f, "leader"),
            AckMode::Quorum => write!(f, "quorum"),
        }
    }
}

/// Per-topic replication configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicationConfig {
    /// Replicas per partition (leader included).  1 = unreplicated.
    pub factor: usize,
    pub ack_mode: AckMode,
    /// Minimum alive replicas a [`AckMode::Quorum`] produce requires.
    pub min_insync: usize,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig { factor: 1, ack_mode: AckMode::Leader, min_insync: 1 }
    }
}

impl ReplicationConfig {
    pub fn new(factor: usize) -> Self {
        ReplicationConfig { factor, ..Default::default() }
    }

    pub fn with_ack_mode(mut self, mode: AckMode) -> Self {
        self.ack_mode = mode;
        self
    }

    pub fn with_min_insync(mut self, min_insync: usize) -> Self {
        self.min_insync = min_insync;
        self
    }

    /// Validate against a broker-tier size (spec builders and topic
    /// creation share this, so both reject the same configs).
    pub fn validate(&self, broker_nodes: usize) -> Result<()> {
        if self.factor == 0 {
            return Err(Error::Config("replication factor must be >= 1".into()));
        }
        if self.factor > broker_nodes {
            return Err(Error::Config(format!(
                "replication factor {} exceeds the broker tier's {broker_nodes} node{}",
                self.factor,
                if broker_nodes == 1 { "" } else { "s" }
            )));
        }
        if self.min_insync == 0 || self.min_insync > self.factor {
            return Err(Error::Config(format!(
                "min_insync {} must be in 1..=factor ({})",
                self.min_insync, self.factor
            )));
        }
        Ok(())
    }
}

/// One partition's replica set: node ids in priority order (leader
/// first; failover promotes the first surviving entry) plus each
/// follower's adopted [`LogMirror`].
#[derive(Debug, Default)]
pub(super) struct ReplicaSet {
    pub(super) nodes: Vec<NodeId>,
    pub(super) mirrors: HashMap<NodeId, LogMirror>,
}

/// What one [`BrokerCluster::kill_broker`] did, for assertions and logs.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    pub killed: NodeId,
    /// Partitions whose leadership moved to a surviving follower from
    /// the replica set (planned, replicated failover).
    pub promoted: usize,
    /// Partitions the dead node led with no replica to promote
    /// (factor 1): reassigned round-robin; their unconsumed tail above
    /// the last committed offset had no other home and is the data-loss
    /// exposure an unreplicated topic accepts.
    pub unreplicated: usize,
    /// Partitions (across all topics) inspected during the failover.
    pub partitions: usize,
    /// Wall-clock seconds the failover took (membership edit, leader
    /// promotion, replica reassignment, fetcher wakeup).
    pub recovery_secs: f64,
}

/// A queued failover notification the autoscale controller drains
/// ([`BrokerCluster::take_failover_events`]) so node death enters the
/// control loop as a first-class signal.
#[derive(Debug, Clone)]
pub struct FailoverEvent {
    /// Seconds since the cluster's epoch.
    pub at_secs: f64,
    pub killed: NodeId,
    pub promoted: usize,
    pub unreplicated: usize,
    pub recovery_secs: f64,
}

impl BrokerCluster {
    /// Recompute every partition's replica set against `brokers`:
    /// leader = the partition's current leader index, followers = the
    /// next `factor - 1` brokers on the ring (capped at the tier size —
    /// a tier smaller than the factor leaves partitions *degraded*,
    /// visible through [`BrokerCluster::degraded_partitions`]).
    /// Followers adopt the leader log's current segments.
    pub(super) fn assign_replica_sets(
        partitions: &[Arc<Partition>],
        factor: usize,
        brokers: &[NodeId],
    ) {
        let n = brokers.len().max(1);
        for p in partitions {
            let leader_idx = p.leader_index() % n;
            let nodes: Vec<NodeId> =
                (0..factor.min(n)).map(|k| brokers[(leader_idx + k) % n]).collect();
            let mut set = p.replicas.lock().unwrap();
            set.mirrors.retain(|node, _| nodes[1..].contains(node));
            for &f in &nodes[1..] {
                set.mirrors.insert(f, p.log.mirror());
            }
            set.nodes = nodes;
        }
    }

    /// Partitions of `topic` whose alive replica count is below the
    /// topic's configured factor — the degraded-replication signal the
    /// autoscale probe samples and the planner answers with a broker
    /// replacement step.
    pub fn degraded_partitions(&self, topic: &str) -> Result<usize> {
        let t = self.topic(topic)?;
        Ok(t.partitions
            .iter()
            .filter(|p| p.replicas.lock().unwrap().nodes.len() < t.replication.factor)
            .count())
    }

    /// The broker node coordinating `group`'s offsets — deterministic
    /// over the alive membership, so it *moves* when its node dies.
    /// The offset store itself is modeled as replicated coordinator
    /// state (it lives with the cluster, not the node), which is
    /// exactly the durability claim
    /// `offsets_survive_coordinator_death` pins: killing the
    /// coordinator changes this answer but not one committed offset.
    pub fn group_coordinator(&self, group: &str) -> NodeId {
        let brokers = self.inner.broker_nodes.load();
        let h = super::repartition::key_hash(group.as_bytes());
        brokers[(h % brokers.len() as u64) as usize]
    }

    /// Attach a timeline: every subsequent failover records a
    /// [`ScalingAction::Failover`] event (with its recovery time as the
    /// event cost) on it, alongside whatever the autoscaler records.
    pub fn add_scaling_timeline(&self, timeline: Arc<ScalingTimeline>) {
        self.inner.timelines.lock().unwrap().push(timeline);
    }

    /// Drain queued failover notifications (the autoscale control loop
    /// calls this every tick).
    pub fn take_failover_events(&self) -> Vec<FailoverEvent> {
        std::mem::take(&mut *self.inner.failover_events.lock().unwrap())
    }

    /// Kill broker `node`: remove it from the membership and fail over
    /// every partition it led — deterministically, to the first
    /// surviving follower in replica-set order (factor-1 partitions
    /// fall back to round-robin reassignment and are counted as
    /// `unreplicated`).  Committed consumer-group offsets survive
    /// untouched; blocked fetchers wake and re-resolve the new leader.
    /// The last alive broker cannot be killed.
    pub fn kill_broker(&self, node: NodeId) -> Result<FailoverReport> {
        self.check_running()?;
        let started = Instant::now();
        let _control = self.inner.control.lock().unwrap();
        let old_brokers = self.inner.broker_nodes.load();
        if !old_brokers.contains(&node) {
            return Err(Error::Broker(format!("broker node {node} is not in the cluster")));
        }
        let brokers: Vec<NodeId> =
            old_brokers.iter().copied().filter(|b| *b != node).collect();
        if brokers.is_empty() {
            return Err(Error::Broker("cannot kill the last broker".into()));
        }
        let n_old = old_brokers.len();
        let n = brokers.len();
        self.inner.broker_nodes.store(Arc::new(brokers.clone()));

        let mut promoted = 0usize;
        let mut unreplicated = 0usize;
        let mut partitions = 0usize;
        let topics = self.inner.topics.load();
        for topic in topics.values() {
            for p in &topic.partitions {
                partitions += 1;
                let old_leader = old_brokers[p.leader_index() % n_old];
                let new_leader = if old_leader != node {
                    // Leadership survives; only its index moved with the
                    // membership edit.
                    old_leader
                } else {
                    // Deterministic promotion: first surviving follower
                    // in replica-set order; factor-1 partitions have
                    // none and fall back to round-robin placement.
                    let survivor = {
                        let set = p.replicas.lock().unwrap();
                        set.nodes.iter().copied().find(|r| *r != node)
                    };
                    match survivor {
                        Some(s) => {
                            promoted += 1;
                            s
                        }
                        None => {
                            unreplicated += 1;
                            brokers[p.id % n]
                        }
                    }
                };
                let idx = brokers
                    .iter()
                    .position(|b| *b == new_leader)
                    .expect("new leader is an alive broker");
                p.set_leader_index(idx);
                // The promoted leader owns the full shared log, so
                // everything replicated (and, in this in-process model,
                // everything appended) stays readable: re-publish the
                // visibility watermark at the log end.
                p.high_watermark.fetch_max(p.log.end_offset(), Ordering::AcqRel);
            }
            // Refill follower slots from the survivors (a tier now
            // smaller than the factor leaves partitions degraded).
            Self::assign_replica_sets(&topic.partitions, topic.replication.factor, &brokers);
        }

        // Wake every parked fetcher: the leader it resolved may be the
        // dead node; the fetch loop re-resolves against the new
        // membership on its next pass.
        for topic in topics.values() {
            for p in &topic.partitions {
                p.notify_data();
            }
        }

        let recovery_secs = started.elapsed().as_secs_f64();
        let at_secs = self.elapsed_ns() as f64 / 1e9;
        let event = ScalingEvent {
            at_secs,
            action: ScalingAction::Failover,
            delta_nodes: 1,
            total_nodes: n,
            lag: 0,
            partitions,
            policy: "failover".to_string(),
            reaction_secs: recovery_secs,
            cost_secs: recovery_secs,
        };
        for timeline in self.inner.timelines.lock().unwrap().iter() {
            timeline.record(event.clone());
        }
        self.inner.failover_events.lock().unwrap().push(FailoverEvent {
            at_secs,
            killed: node,
            promoted,
            unreplicated,
            recovery_secs,
        });
        Ok(FailoverReport { killed: node, promoted, unreplicated, partitions, recovery_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn cluster(brokers: usize) -> BrokerCluster {
        BrokerCluster::new(Machine::unthrottled(brokers + 2), (0..brokers).collect())
    }

    #[test]
    fn ack_mode_parses_and_displays() {
        assert_eq!(AckMode::parse("leader").unwrap(), AckMode::Leader);
        assert_eq!(AckMode::parse("quorum").unwrap(), AckMode::Quorum);
        assert!(AckMode::parse("all").is_err());
        assert_eq!(AckMode::Quorum.to_string(), "quorum");
    }

    #[test]
    fn replication_config_validates_bounds() {
        assert!(ReplicationConfig::new(0).validate(4).is_err(), "factor 0");
        assert!(ReplicationConfig::new(3).validate(2).is_err(), "factor > brokers");
        assert!(ReplicationConfig::new(2).validate(2).is_ok());
        assert!(
            ReplicationConfig::new(2).with_min_insync(3).validate(4).is_err(),
            "min_insync > factor"
        );
        assert!(ReplicationConfig::new(2).with_min_insync(0).validate(4).is_err());
    }

    #[test]
    fn replicated_topic_assigns_follower_sets_round_robin() {
        let c = cluster(3);
        c.create_topic_replicated("t", 3, ReplicationConfig::new(2)).unwrap();
        let t = c.topic("t").unwrap();
        for (i, p) in t.partitions.iter().enumerate() {
            let set = p.replicas.lock().unwrap();
            assert_eq!(set.nodes.len(), 2);
            assert_eq!(set.nodes[0], i % 3, "leader first");
            assert_eq!(set.nodes[1], (i + 1) % 3, "next broker on the ring follows");
        }
        assert_eq!(c.degraded_partitions("t").unwrap(), 0);
    }

    #[test]
    fn produce_mirrors_to_followers_and_charges_their_io() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2)).unwrap();
        let io0 = c.broker_io();
        c.produce("t", 0, 2, &[vec![0u8; 100]]).unwrap();
        let io1 = c.broker_io();
        // Leader (node 0): producer ingress + replication egress.
        assert_eq!(io1[0].nic_in_bytes - io0[0].nic_in_bytes, 100);
        assert_eq!(io1[0].nic_out_bytes - io0[0].nic_out_bytes, 100);
        assert_eq!(io1[0].disk_bytes - io0[0].disk_bytes, 100);
        // Follower (node 1): replication ingress + its own disk append.
        assert_eq!(io1[1].nic_in_bytes - io0[1].nic_in_bytes, 100);
        assert_eq!(io1[1].disk_bytes - io0[1].disk_bytes, 100);
        // And the mirror tracks the leader's end offset, zero-copy.
        let t = c.topic("t").unwrap();
        let set = t.partitions[0].replicas.lock().unwrap();
        assert_eq!(set.mirrors[&1].end_offset(), 1);
    }

    #[test]
    fn kill_broker_promotes_first_surviving_follower() {
        let c = cluster(3);
        c.create_topic_replicated("t", 3, ReplicationConfig::new(2)).unwrap();
        c.produce("t", 0, 3, &[b"a".to_vec(), b"b".to_vec()]).unwrap();
        assert_eq!(c.leader_node("t", 0).unwrap(), 0);
        let report = c.kill_broker(0).unwrap();
        assert_eq!(report.killed, 0);
        assert_eq!(report.promoted, 1, "partition 0's leadership moves");
        assert_eq!(report.unreplicated, 0);
        assert!(report.recovery_secs >= 0.0);
        // Partition 0 promoted to its follower (node 1), deterministically.
        assert_eq!(c.leader_node("t", 0).unwrap(), 1);
        assert_eq!(c.broker_nodes(), vec![1, 2]);
        // Every record is still readable through the shared slabs.
        let recs = c.fetch("t", 0, 0, usize::MAX, 3, Duration::from_millis(10)).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].value, b"b");
    }

    #[test]
    fn kill_broker_rejects_unknown_and_last_node() {
        let c = cluster(1);
        assert!(c.kill_broker(7).is_err(), "not a member");
        assert!(c.kill_broker(0).is_err(), "last broker");
        assert_eq!(c.broker_nodes(), vec![0]);
    }

    #[test]
    fn quorum_rejects_produce_when_insync_below_minimum() {
        let c = cluster(2);
        c.create_topic_replicated(
            "t",
            1,
            ReplicationConfig::new(2).with_ack_mode(AckMode::Quorum).with_min_insync(2),
        )
        .unwrap();
        c.produce("t", 0, 2, &[vec![1]]).unwrap();
        c.kill_broker(0).unwrap();
        assert_eq!(c.degraded_partitions("t").unwrap(), 1);
        // Quorum: degraded partition rejects produces...
        let err = c.produce("t", 0, 2, &[vec![2]]).unwrap_err();
        assert!(err.to_string().contains("in-sync"), "{err}");
        // ...until a replacement broker restores the replica set.
        c.add_brokers(vec![3]);
        assert_eq!(c.degraded_partitions("t").unwrap(), 0);
        c.produce("t", 0, 2, &[vec![2]]).unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 2);
    }

    #[test]
    fn leader_ack_keeps_accepting_while_degraded() {
        let c = cluster(2);
        c.create_topic_replicated("t", 1, ReplicationConfig::new(2)).unwrap();
        c.kill_broker(1).unwrap();
        assert_eq!(c.degraded_partitions("t").unwrap(), 1);
        c.produce("t", 0, 2, &[vec![9]]).unwrap();
        assert_eq!(c.end_offset("t", 0).unwrap(), 1);
    }

    #[test]
    fn offsets_survive_coordinator_death() {
        let c = cluster(3);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        c.produce("t", 0, 3, &[vec![1], vec![2], vec![3]]).unwrap();
        c.group_join("g", "t");
        c.commit("g", "t", 0, 2);
        let coordinator = c.group_coordinator("g");
        c.kill_broker(coordinator).unwrap();
        // The coordinator moved to a survivor; not one offset moved.
        assert_ne!(c.group_coordinator("g"), coordinator);
        assert_eq!(c.committed("g", "t", 0), 2);
        assert_eq!(c.group_lag("g", "t").unwrap(), 1);
    }

    #[test]
    fn failover_lands_on_attached_timelines_and_event_queue() {
        let c = cluster(2);
        c.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        let timeline = Arc::new(ScalingTimeline::new());
        c.add_scaling_timeline(timeline.clone());
        c.kill_broker(1).unwrap();
        assert_eq!(timeline.count(ScalingAction::Failover), 1);
        let ev = &timeline.events()[0];
        assert_eq!(ev.total_nodes, 1);
        assert_eq!(ev.partitions, 2);
        assert_eq!(ev.policy, "failover");
        assert!(ev.cost_secs >= 0.0, "recovery time is the event's cost");
        let queued = c.take_failover_events();
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].killed, 1);
        assert_eq!(queued[0].promoted + queued[0].unreplicated, 1, "node 1 led partition 1");
        assert!(c.take_failover_events().is_empty(), "drained");
    }

    #[test]
    fn unreplicated_partitions_fall_back_to_round_robin() {
        let c = cluster(2);
        c.create_topic("t", 4).unwrap(); // factor 1
        let report = c.kill_broker(1).unwrap();
        assert_eq!(report.promoted, 0);
        assert_eq!(report.unreplicated, 2, "node 1 led partitions 1 and 3");
        for p in 0..4 {
            assert_eq!(c.leader_node("t", p).unwrap(), 0);
        }
    }
}
