//! Online topic repartitioning: the partition count moves with the fleet.
//!
//! The paper's §6.4 evaluation shows processing throughput flat-lining
//! once processing nodes exceed the topic's partition count — Spark
//! assigns one task per Kafka partition, so partitions are the
//! parallelism ceiling.  This module removes that knee: a topic's
//! partition set can grow (and shrink) *while producers and consumer
//! groups are attached*, with three guarantees the invariant suite
//! (`tests/proptest_repartition.rs`) checks across random interleavings:
//!
//! * **exactly-once** — no produced record is lost or duplicated across
//!   a resize;
//! * **per-key order** — records of one key are consumed in produce
//!   order even when the key's partition changes;
//! * **monotone progress** — committed offsets never exceed end offsets
//!   (group lag never goes negative).
//!
//! The mechanism is epoch-based:
//!
//! 1. Every resize bumps the topic's **epoch** and installs a new
//!    epoch-stamped partition set (ids are stable; a grow appends or
//!    re-activates partitions, a shrink retires a suffix that stays
//!    readable until drained).
//! 2. At the transition, every live partition log records an **epoch
//!    watermark** ([`crate::broker::PartitionLog::seal_epoch`]) — the
//!    fence below which records belong to the old epoch.  Appends that
//!    raced the seal are rejected ([`crate::error::Error::StaleEpoch`])
//!    and re-routed by the producer, so the fence is exact.
//! 3. Consumer groups **drain before serving**: while a group's epoch
//!    trails the topic's, members fetch only below the fences; when all
//!    fences are committed the group's epoch advances and a rebalance
//!    spreads members over the new partition set.  All records of epoch
//!    `e` are therefore consumed before any record of epoch `e+1` —
//!    which, combined with per-partition order inside an epoch, gives
//!    global per-key order.
//! 4. Producers map keys to partitions with **jump consistent hashing**
//!    ([`key_partition`]), so a resize from `n` to `m` partitions moves
//!    only a `1 - n/m` fraction of the key space (1/m per added
//!    partition) instead of reshuffling almost every key the way
//!    `hash % n` does.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::error::{Error, Result};

use super::cluster::{BrokerCluster, Partition, Topic};

/// One epoch transition of a topic, recorded at resize time.
#[derive(Debug, Clone)]
pub struct EpochTransition {
    /// The epoch this transition leads *to*.
    pub epoch: u64,
    /// Active partition count from this epoch on.
    pub active: usize,
    /// Per-partition end offsets at seal time, indexed by partition id
    /// over every partition that existed before the transition.  A
    /// consumer group serving the previous epoch must commit up to all
    /// of these before it may serve epoch `epoch` data.
    pub fences: Vec<u64>,
}

/// What one group member should serve right now: generation, serving
/// epoch, assigned partitions, and (while draining) fetch ceilings
/// aligned with `partitions` (`None` = unbounded).
#[derive(Debug, Clone)]
pub struct ServePlan {
    pub generation: u64,
    /// The epoch the group is serving.
    pub epoch: u64,
    /// The topic's epoch when this plan was computed.  A consumer whose
    /// blocking fetch outlives the plan re-checks this before trusting
    /// an uncapped fetch — a repartition mid-fetch could otherwise hand
    /// it records from beyond a fence it never saw.
    pub topic_epoch: u64,
    pub partitions: Vec<usize>,
    pub ceilings: Vec<Option<u64>>,
}

/// Jump consistent hash (Lamping & Veach 2014): maps `key` to a bucket
/// in `[0, buckets)` such that growing the bucket count from `n` to `m`
/// relocates only a `1 - n/m` fraction of keys — and always toward the
/// *new* buckets, matching how repartition grows the partition suffix.
pub fn jump_hash(mut key: u64, buckets: usize) -> usize {
    debug_assert!(buckets > 0);
    let mut b: i64 = -1;
    let mut j: i64 = 0;
    while j < buckets as i64 {
        b = j;
        key = key.wrapping_mul(2862933555777941757).wrapping_add(1);
        let scale = (1u64 << 31) as f64 / ((key >> 33).wrapping_add(1) as f64);
        j = (b.wrapping_add(1) as f64 * scale) as i64;
    }
    b as usize
}

/// FNV-1a over the key bytes — the stable 64-bit route a keyed producer
/// resolves *once at append time* and carries in its batches instead of
/// an owned copy of the key (§Perf: no per-record key `Vec`).  Feeding
/// the same hash into [`jump_hash`] under any partition count yields the
/// key's partition there, so pending records re-route across resizes
/// without ever re-reading key bytes.
pub fn key_hash(key: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// [`key_hash`] then jump-hash into the partition count — the
/// keyed-routing function producers use, shared here so tests and
/// applications can predict placements.
pub fn key_partition(key: &[u8], partitions: usize) -> usize {
    jump_hash(key_hash(key), partitions)
}

impl BrokerCluster {
    /// Resize `topic` to `new_active` partitions while attached
    /// producers and consumer groups keep running.  Returns the new
    /// epoch (or the current one when the size is unchanged).
    ///
    /// Growing appends fresh partitions (or re-activates previously
    /// retired ids); shrinking retires the trailing suffix, which stays
    /// readable until every group drains it.  Every attached group is
    /// rebalanced (generation bump) so its members observe the
    /// transition on their next poll.
    pub fn repartition_topic(&self, topic: &str, new_active: usize) -> Result<u64> {
        self.check_running()?;
        if new_active == 0 {
            return Err(Error::Broker("topic needs >= 1 partition".into()));
        }
        let control = self.inner.control.lock().unwrap();
        let n_brokers = self.inner.broker_nodes.load().len().max(1);
        let topics = self.inner.topics.load();
        let t = topics
            .get(topic)
            .cloned()
            .ok_or_else(|| Error::Broker(format!("unknown topic {topic}")))?;
        if new_active == t.active {
            return Ok(t.epoch);
        }
        let new_epoch = t.epoch + 1;

        // Quiesce only the shards that own this topic's partitions
        // (other shards — other topics' partitions — keep serving
        // full-length blocking fetches): their parked fetchers wake,
        // re-check their watermarks, and downgrade to bounded wait
        // slices for the duration of the seal, so a fetcher can never
        // sleep unboundedly through the epoch transition.
        let mut owning: Vec<usize> = t.partitions.iter().map(|p| p.shard_id()).collect();
        owning.sort_unstable();
        owning.dedup();
        for sid in &owning {
            if let Some(s) = self.inner.shards.get(*sid) {
                s.quiesce();
            }
        }

        // Seal every existing log: record the fence and bump the
        // partition's epoch while the log's writer lock is held, so
        // concurrent produces either land below the fence or fail
        // StaleEpoch and re-route.
        let mut fences = Vec::with_capacity(t.partitions.len());
        for p in &t.partitions {
            fences.push(p.log.seal_epoch_then(new_epoch, || {
                p.epoch.store(new_epoch, Ordering::Release);
            }));
        }

        for sid in &owning {
            if let Some(s) = self.inner.shards.get(*sid) {
                s.resume();
            }
        }

        let mut partitions = t.partitions.clone();
        let first_new = partitions.len();
        while partitions.len() < new_active {
            let id = partitions.len();
            partitions.push(Arc::new(Partition::new(
                id,
                id % n_brokers,
                new_epoch,
                self.inner.log_config,
                self.inner.shards.shard_for(id),
            )));
        }
        // Fresh partitions inherit the topic's replication: followers on
        // the next brokers of the ring, adopting the (empty) leader log.
        if first_new < partitions.len() {
            self.assign_replica_sets(
                &partitions[first_new..],
                t.replication.factor,
                &self.inner.broker_nodes.load(),
            );
        }
        let mut transitions = t.transitions.clone();
        transitions.push(EpochTransition {
            epoch: new_epoch,
            active: new_active,
            fences,
        });
        // Publish the new epoch's topic snapshot copy-on-write: in-
        // flight produce/fetch keep their old `Arc<Topic>` (partition
        // objects are shared, so reads stay valid), and the epoch
        // fences above already routed stale producers to re-resolve.
        let mut next = topics.as_ref().clone();
        next.insert(
            topic.to_string(),
            Arc::new(Topic {
                name: t.name.clone(),
                partitions,
                active: new_active,
                epoch: new_epoch,
                transitions,
                replication: t.replication,
            }),
        );
        self.inner.topics.store(Arc::new(next));
        drop(control);

        // Rebalance every attached group so consumers pick up the
        // transition (fences / new partition set) on their next poll.
        let mut groups = self.inner.groups.lock().unwrap();
        for ((_, gt), st) in groups.iter_mut() {
            if gt == topic {
                st.generation += 1;
            }
        }
        Ok(new_epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;
    use std::time::Duration;

    fn cluster() -> BrokerCluster {
        BrokerCluster::new(Machine::unthrottled(3), vec![0])
    }

    #[test]
    fn grow_adds_partitions_and_bumps_epoch() {
        let c = cluster();
        c.create_topic("t", 2).unwrap();
        assert_eq!(c.topic_epoch("t").unwrap(), 0);
        let e = c.repartition_topic("t", 6).unwrap();
        assert_eq!(e, 1);
        assert_eq!(c.partition_count("t").unwrap(), 6);
        assert_eq!(c.total_partitions("t").unwrap(), 6);
        // New partitions accept writes immediately.
        c.produce("t", 5, 0, &[vec![1]]).unwrap();
        assert_eq!(c.end_offset("t", 5).unwrap(), 1);
        // Resizing to the current size is a no-op.
        assert_eq!(c.repartition_topic("t", 6).unwrap(), 1);
    }

    #[test]
    fn shrink_retires_suffix_but_keeps_it_readable() {
        let c = cluster();
        c.create_topic("t", 4).unwrap();
        c.produce("t", 3, 0, &[vec![9]]).unwrap();
        c.repartition_topic("t", 2).unwrap();
        assert_eq!(c.partition_count("t").unwrap(), 2);
        assert_eq!(c.total_partitions("t").unwrap(), 4);
        // Retired partition rejects writes (stale epoch) but still reads.
        assert!(matches!(
            c.produce("t", 3, 0, &[vec![1]]),
            Err(Error::StaleEpoch(_))
        ));
        let recs = c
            .fetch("t", 3, 0, usize::MAX, 0, Duration::from_millis(10))
            .unwrap();
        assert_eq!(recs.len(), 1);
        // Regrowing re-activates the retired ids and their logs.
        c.repartition_topic("t", 4).unwrap();
        c.produce("t", 3, 0, &[vec![2]]).unwrap();
        assert_eq!(c.end_offset("t", 3).unwrap(), 2);
    }

    #[test]
    fn group_drains_old_epoch_before_advancing() {
        let c = cluster();
        c.create_topic("t", 2).unwrap();
        c.produce("t", 0, 0, &[vec![1], vec![2]]).unwrap();
        let (m, _) = c.group_join("g", "t");
        c.repartition_topic("t", 4).unwrap();
        // Draining: the plan covers the old 2 partitions, capped at the
        // fences, and the group's epoch trails the topic's.
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 0);
        assert_eq!(plan.partitions, vec![0, 1]);
        assert_eq!(plan.ceilings, vec![Some(2), Some(0)]);
        assert_eq!(c.group_epoch("g", "t"), 0);
        // Committing up to every fence advances the epoch and widens
        // the plan to the new partition set, uncapped.
        c.commit("g", "t", 0, 2);
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 1);
        assert_eq!(plan.partitions, vec![0, 1, 2, 3]);
        assert!(plan.ceilings.iter().all(|c| c.is_none()));
        assert_eq!(c.group_epoch("g", "t"), 1);
    }

    #[test]
    fn empty_topic_repartition_advances_without_commits() {
        let c = cluster();
        c.create_topic("t", 2).unwrap();
        let (m, _) = c.group_join("g", "t");
        c.repartition_topic("t", 8).unwrap();
        // All fences are 0: the very next serve plan is already at the
        // new epoch.
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 1);
        assert_eq!(plan.partitions.len(), 8);
    }

    #[test]
    fn queued_transitions_drain_in_order() {
        let c = cluster();
        c.create_topic("t", 1).unwrap();
        c.produce("t", 0, 0, &[vec![1]]).unwrap();
        let (m, _) = c.group_join("g", "t");
        c.repartition_topic("t", 3).unwrap(); // epoch 1, fence [1]
        c.produce("t", 2, 0, &[vec![2]]).unwrap();
        c.repartition_topic("t", 2).unwrap(); // epoch 2, fences [1,0,1]
        // Still gated on epoch 0's fence.
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 0);
        assert_eq!(plan.partitions, vec![0]);
        assert_eq!(plan.ceilings, vec![Some(1)]);
        // Draining epoch 0 exposes epoch 1's domain (3 partitions,
        // fenced); draining that reaches epoch 2's active set of 2.
        c.commit("g", "t", 0, 1);
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 1);
        assert_eq!(plan.partitions, vec![0, 1, 2]);
        assert_eq!(plan.ceilings, vec![Some(1), Some(0), Some(1)]);
        c.commit("g", "t", 2, 1);
        let plan = c.group_serve_plan("g", "t", m).unwrap();
        assert_eq!(plan.epoch, 2);
        assert_eq!(plan.partitions, vec![0, 1]);
    }

    #[test]
    fn repartition_rejects_zero_and_unknown_topic() {
        let c = cluster();
        c.create_topic("t", 2).unwrap();
        assert!(c.repartition_topic("t", 0).is_err());
        assert!(c.repartition_topic("nope", 4).is_err());
    }

    #[test]
    fn jump_hash_moves_minimal_keys_on_grow() {
        let n_keys = 10_000u64;
        let mut moved = 0;
        for k in 0..n_keys {
            let before = jump_hash(k, 8);
            let after = jump_hash(k, 12);
            if before != after {
                moved += 1;
                // Moves always land on the new buckets.
                assert!(after >= 8, "key {k} moved {before} -> {after}");
            }
        }
        // Expect ~ (1 - 8/12) = a third of keys to move; allow slack.
        let frac = moved as f64 / n_keys as f64;
        assert!((0.25..0.42).contains(&frac), "moved fraction {frac}");
    }

    #[test]
    fn key_partition_is_stable_and_in_range() {
        for parts in [1usize, 3, 7, 48] {
            for key in [b"a".as_slice(), b"stream-42", b""] {
                let p = key_partition(key, parts);
                assert!(p < parts);
                assert_eq!(p, key_partition(key, parts), "deterministic");
            }
        }
    }
}
