//! Thread-per-core sharded data plane: partition → shard mapping and
//! per-shard batched wakeups.
//!
//! PR 4 removed the payload copies from the broker data plane; this
//! module removes the cross-core traffic that was left.  Every
//! partition is owned by exactly one **shard** — a logical reactor
//! modeled after thread-per-core designs (MPI stream endpoints bound to
//! dedicated compute resources, seastar/scylla reactors): the
//! partition's writer mutex, its published segment snapshots, and every
//! fetcher parked on it live on that shard, so the produce/fetch hot
//! path never bounces its synchronization cache lines across all
//! cores, only across the (few) cores mapped to the shard.
//!
//! The mapping reuses the repo's jump consistent hash
//! ([`super::repartition::jump_hash`]): [`shard_of`] is stable under a
//! growing shard count the same way key routing is stable under a
//! growing partition count, so a future online re-shard moves the
//! minimal set of partitions.
//!
//! **Batched wakeups** replace the old per-partition
//! `wait_lock`/`Condvar` pair: each shard owns one *doorbell*
//! (`Mutex` + `Condvar`) that every fetcher of every partition on the
//! shard parks on.  Producers ring the doorbell **once per append
//! batch** — not per record — and the ring is *coalesced*: when no
//! fetcher is parked (`parked == 0`, the common case under load, where
//! fetchers are busy draining) the ring skips the lock and the notify
//! entirely, so an uncontended produce costs two relaxed atomic bumps
//! and one fence.
//!
//! Lost-wakeup freedom is the classic store-buffer (Dekker) protocol,
//! checked by `tests/proptest_shard.rs` across random interleavings:
//!
//! * producer: publish the high watermark, `SeqCst` fence (inside
//!   [`Shard::ring`]), then read `parked`;
//! * fetcher: increment `parked` ([`Shard::park`]), `SeqCst` fence,
//!   then re-check the watermark **under the doorbell lock** before
//!   sleeping.
//!
//! At least one side observes the other: either the producer sees the
//! parked fetcher and notifies (through the lock, so the notify cannot
//! land in the fetcher's check-to-wait window), or the fetcher sees the
//! new watermark and never sleeps.
//!
//! **Quiesce** ([`Shard::quiesce`]) marks a shard while a repartition
//! seals the epoch fences of *its* partitions (other shards keep
//! serving).  Parked fetchers on a quiesced shard downgrade to bounded
//! wait slices and give up with a clean [`crate::error::Error`] after
//! [`QUIESCE_WAIT_MAX`] — the fix for the sleep-forever bug a
//! mid-repartition quiesce used to cause.

use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::metrics::DepthGauge;

use super::repartition::jump_hash;

/// How long a fetcher parked on a quiesced shard sleeps per slice
/// before re-checking the watermark and the quiesce flag.
pub const QUIESCE_SLICE: Duration = Duration::from_millis(5);

/// Total bounded wait a blocking fetch tolerates on a quiesced shard
/// before surfacing [`crate::error::Error::ShardQuiesced`].  An epoch
/// seal holds the quiesce for microseconds; a shard stuck quiesced this
/// long means the repartition died mid-flight, and erroring out beats
/// sleeping forever.
pub const QUIESCE_WAIT_MAX: Duration = Duration::from_millis(250);

/// Map a partition id onto one of `n_shards` shards — jump consistent,
/// so growing the shard count relocates the minimal partition set (and
/// always toward the new shards).
pub fn shard_of(partition: usize, n_shards: usize) -> usize {
    jump_hash(partition as u64, n_shards)
}

/// Default shard count: one per available core, clamped to `1..=32`
/// (beyond 32 ways the doorbells outnumber any workload in the bench
/// matrix).  This is the "thread-per-core" sizing; tests pin explicit
/// counts via [`crate::broker::BrokerCluster::with_shards`].
pub fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 32)
}

/// One data-plane shard: the doorbell every fetcher of the shard's
/// partitions parks on, plus the counters the autoscale probe exports.
pub struct Shard {
    id: usize,
    /// Companion mutex for `bell` — held only around the parked
    /// fetcher's check-to-wait window and the (rare) contended notify,
    /// never across log I/O.
    doorbell: Mutex<()>,
    bell: Condvar,
    /// Fetchers currently parked (or about to park) on this shard —
    /// the coalescing gate for [`Shard::ring`] and the per-shard
    /// queue-depth planner signal.  Relaxed internally; the `SeqCst`
    /// fences in `ring`/`park` order it against the watermark.
    parked: DepthGauge,
    /// Doorbell rings requested (one per append batch).
    rings: AtomicU64,
    /// Rings that actually took the lock and notified — `rings -
    /// notifies` is the wakeup traffic the coalescing saved.
    notifies: AtomicU64,
    /// Set while a repartition seals this shard's partitions.
    quiesced: AtomicBool,
}

/// Point-in-time counters of one shard (see
/// [`crate::broker::BrokerCluster::shard_stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    pub shard: usize,
    /// Fetchers parked on the doorbell right now — the queue-depth
    /// gauge the autoscale planner reads: persistent depth on one
    /// shard with idle siblings means partitions hash unevenly.
    pub parked_fetchers: u64,
    /// High-water mark of `parked_fetchers` since cluster start.
    pub peak_parked: u64,
    pub rings: u64,
    pub notifies: u64,
    pub quiesced: bool,
}

impl Shard {
    pub(super) fn new(id: usize) -> Self {
        Shard {
            id,
            doorbell: Mutex::new(()),
            bell: Condvar::new(),
            parked: DepthGauge::new(),
            rings: AtomicU64::new(0),
            notifies: AtomicU64::new(0),
            quiesced: AtomicBool::new(false),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// Ring the doorbell after publishing data — once per append
    /// *batch*.  Coalesced: skips the lock and the notify when nobody
    /// is parked.  The caller must have published its watermark (any
    /// store the parked fetchers re-check) *before* calling; the
    /// `SeqCst` fence here pairs with the one in [`Shard::park`].
    pub(super) fn ring(&self) {
        self.rings.fetch_add(1, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        if self.parked.current() == 0 {
            return;
        }
        self.notify();
    }

    /// Ring unconditionally — control-plane wakeups (stop, failover,
    /// quiesce/resume) that must reach fetchers racing into the park
    /// window regardless of the coalescing gate.
    pub(super) fn ring_force(&self) {
        self.rings.fetch_add(1, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        self.notify();
    }

    /// The empty critical section orders the notify after any parked
    /// fetcher's check-to-wait window: a fetcher that re-checked under
    /// the doorbell lock and saw nothing is inside `wait_timeout`
    /// (lock released) before this acquisition can succeed.
    fn notify(&self) {
        drop(self.doorbell.lock().unwrap());
        self.notifies.fetch_add(1, Ordering::Relaxed);
        self.bell.notify_all();
    }

    /// Register as a parked fetcher.  Must be called *before* the final
    /// watermark re-check (the fence pairs with [`Shard::ring`]'s); the
    /// returned guard deregisters on every exit path.
    pub(super) fn park(&self) -> ParkGuard<'_> {
        self.parked.inc();
        fence(Ordering::SeqCst);
        ParkGuard { shard: self }
    }

    /// Acquire the doorbell for the check-then-wait window.
    pub(super) fn lock(&self) -> MutexGuard<'_, ()> {
        self.doorbell.lock().unwrap()
    }

    /// Park on the doorbell for at most `timeout`.
    pub(super) fn wait<'a>(
        &self,
        guard: MutexGuard<'a, ()>,
        timeout: Duration,
    ) -> Result<MutexGuard<'a, ()>> {
        self.bell
            .wait_timeout(guard, timeout)
            .map(|(g, _)| g)
            .map_err(|_| Error::Broker("shard doorbell poisoned".into()))
    }

    /// Mark the shard quiesced (repartition sealing its partitions) and
    /// wake every parked fetcher so it downgrades to bounded slices.
    pub(super) fn quiesce(&self) {
        self.quiesced.store(true, Ordering::Release);
        self.ring_force();
    }

    /// Clear the quiesce and wake parked fetchers to full-length waits.
    pub(super) fn resume(&self) {
        self.quiesced.store(false, Ordering::Release);
        self.ring_force();
    }

    pub fn is_quiesced(&self) -> bool {
        self.quiesced.load(Ordering::Acquire)
    }

    pub fn stats(&self) -> ShardStats {
        ShardStats {
            shard: self.id,
            parked_fetchers: self.parked.current(),
            peak_parked: self.parked.peak(),
            rings: self.rings.load(Ordering::Relaxed),
            notifies: self.notifies.load(Ordering::Relaxed),
            quiesced: self.is_quiesced(),
        }
    }
}

impl std::fmt::Debug for Shard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Shard")
            .field("id", &self.id)
            .field("parked", &self.parked.current())
            .field("quiesced", &self.is_quiesced())
            .finish()
    }
}

/// RAII registration of a parked fetcher — decrements the shard's
/// queue-depth gauge on *every* exit path (timeout, wake, error).
pub(super) struct ParkGuard<'a> {
    shard: &'a Shard,
}

impl Drop for ParkGuard<'_> {
    fn drop(&mut self) {
        self.shard.parked.dec();
    }
}

/// The cluster's fixed set of shards, built once at cluster creation.
pub(super) struct ShardSet {
    shards: Vec<Arc<Shard>>,
}

impl ShardSet {
    pub(super) fn new(n: usize) -> Self {
        assert!(n > 0, "broker cluster needs >= 1 shard");
        ShardSet {
            shards: (0..n).map(|id| Arc::new(Shard::new(id))).collect(),
        }
    }

    pub(super) fn len(&self) -> usize {
        self.shards.len()
    }

    /// The owning shard of a partition id.
    pub(super) fn shard_for(&self, partition: usize) -> Arc<Shard> {
        self.shards[shard_of(partition, self.shards.len())].clone()
    }

    pub(super) fn get(&self, id: usize) -> Option<&Arc<Shard>> {
        self.shards.get(id)
    }

    /// Force-ring every doorbell — cluster stop / broker death.
    pub(super) fn ring_all(&self) {
        for s in &self.shards {
            s.ring_force();
        }
    }

    pub(super) fn stats(&self) -> Vec<ShardStats> {
        self.shards.iter().map(|s| s.stats()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Instant;

    #[test]
    fn shard_of_is_stable_in_range_and_spreads() {
        for n in [1usize, 2, 4, 16, 32] {
            let mut hit = vec![false; n];
            for p in 0..256 {
                let s = shard_of(p, n);
                assert!(s < n);
                assert_eq!(s, shard_of(p, n), "deterministic");
                hit[s] = true;
            }
            assert!(hit.iter().all(|h| *h), "256 partitions cover all {n} shards");
        }
    }

    #[test]
    fn shard_of_moves_minimally_on_grow() {
        // Jump-consistent: partitions that move on 8 -> 16 shards land
        // only on the new shards, so an online re-shard would migrate
        // the minimal set.
        for p in 0..512 {
            let before = shard_of(p, 8);
            let after = shard_of(p, 16);
            if before != after {
                assert!(after >= 8, "partition {p} moved {before} -> {after}");
            }
        }
    }

    #[test]
    fn default_shards_is_clamped() {
        let n = default_shards();
        assert!((1..=32).contains(&n));
    }

    #[test]
    fn ring_skips_notify_with_no_parked_fetchers() {
        let s = Shard::new(3);
        for _ in 0..100 {
            s.ring();
        }
        let st = s.stats();
        assert_eq!(st.shard, 3);
        assert_eq!(st.rings, 100, "every batch ring is counted");
        assert_eq!(st.notifies, 0, "coalesced: no parked fetchers, no notify");
        s.ring_force();
        assert_eq!(s.stats().notifies, 1, "forced ring always notifies");
    }

    #[test]
    fn park_guard_tracks_queue_depth() {
        let s = Shard::new(0);
        assert_eq!(s.stats().parked_fetchers, 0);
        {
            let _a = s.park();
            let _b = s.park();
            assert_eq!(s.stats().parked_fetchers, 2);
            assert_eq!(s.stats().peak_parked, 2);
        }
        assert_eq!(s.stats().parked_fetchers, 0, "guards deregister on drop");
        assert_eq!(s.stats().peak_parked, 2, "peak survives");
    }

    #[test]
    fn ring_wakes_parked_fetcher_without_lost_wakeup() {
        // The full produce/fetch protocol against one shard: the
        // fetcher parks, re-checks the published flag under the
        // doorbell, then sleeps long; the producer publishes and rings
        // exactly once.  The Dekker pairing guarantees the fetcher
        // either never sleeps or is woken — a lost wakeup would make
        // this take the full 5 s.
        let s = Arc::new(Shard::new(0));
        let flag = Arc::new(AtomicU64::new(0));
        let (s2, f2) = (s.clone(), flag.clone());
        let h = std::thread::spawn(move || {
            let start = Instant::now();
            loop {
                if f2.load(Ordering::Acquire) > 0 {
                    return start.elapsed();
                }
                let _parked = s2.park();
                let guard = s2.lock();
                if f2.load(Ordering::Acquire) > 0 {
                    return start.elapsed();
                }
                let _g = s2.wait(guard, Duration::from_secs(5)).unwrap();
            }
        });
        // Let the fetcher reach the park window (not required for
        // correctness — the protocol covers every interleaving — just
        // makes the test exercise the sleeping path most runs).
        while s.stats().parked_fetchers == 0 && s.stats().rings == 0 {
            std::thread::yield_now();
        }
        flag.store(1, Ordering::Release);
        s.ring();
        let waited = h.join().unwrap();
        assert!(
            waited < Duration::from_secs(4),
            "fetcher slept through the ring ({waited:?})"
        );
        assert_eq!(s.stats().parked_fetchers, 0);
    }

    #[test]
    fn quiesce_resume_flag_and_force_ring() {
        let s = Shard::new(1);
        assert!(!s.is_quiesced());
        s.quiesce();
        assert!(s.is_quiesced());
        assert!(s.stats().quiesced);
        assert_eq!(s.stats().notifies, 1, "quiesce force-rings");
        s.resume();
        assert!(!s.is_quiesced());
        assert_eq!(s.stats().notifies, 2, "resume force-rings");
    }

    #[test]
    fn shard_set_maps_consistently_and_rings_all() {
        let set = ShardSet::new(4);
        assert_eq!(set.len(), 4);
        for p in 0..64 {
            assert_eq!(set.shard_for(p).id(), shard_of(p, 4));
        }
        assert!(set.get(3).is_some());
        assert!(set.get(4).is_none());
        set.ring_all();
        let stats = set.stats();
        assert_eq!(stats.len(), 4);
        assert!(stats.iter().all(|s| s.rings == 1 && s.notifies == 1));
    }
}
