//! Kafka framework plugin: pilot-managed broker cluster.

use std::collections::BTreeMap;

use crate::broker::BrokerCluster;
use crate::cluster::NodeId;
use crate::config::BootstrapModel;
use crate::error::{Error, Result};
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::{FrameworkContext, ManagerPlugin, PluginEnv};

/// Deploys the in-process Kafka substrate ([`BrokerCluster`]) on the
/// pilot's nodes.  Bootstrap = ZooKeeper head + per-node brokers.
pub struct KafkaPlugin {
    model: BootstrapModel,
    time_scale: f64,
    cluster: Option<BrokerCluster>,
    pending_nodes: usize,
    broker_nodes: Vec<NodeId>,
}

impl KafkaPlugin {
    pub fn new(_pcd: &PilotComputeDescription, time_scale: f64) -> Self {
        KafkaPlugin {
            model: super::bootstrap_model_for(FrameworkKind::Kafka),
            time_scale,
            cluster: None,
            pending_nodes: 0,
            broker_nodes: Vec::new(),
        }
    }
}

impl ManagerPlugin for KafkaPlugin {
    fn submit_job(&mut self, env: &PluginEnv) -> Result<()> {
        self.broker_nodes = env.nodes.clone();
        self.pending_nodes = env.nodes.len();
        self.cluster = Some(BrokerCluster::new(env.machine.clone(), env.nodes.clone()));
        Ok(())
    }

    fn wait(&mut self) -> Result<f64> {
        if self.cluster.is_none() {
            return Err(Error::Pilot("kafka: wait() before submit_job()".into()));
        }
        Ok(super::do_wait(&self.model, self.pending_nodes, self.time_scale))
    }

    fn extend(&mut self, _env: &PluginEnv, new_nodes: &[NodeId]) -> Result<()> {
        let cluster = self
            .cluster
            .as_ref()
            .ok_or_else(|| Error::Pilot("kafka: extend() before submit_job()".into()))?;
        cluster.add_brokers(new_nodes.to_vec());
        self.broker_nodes.extend_from_slice(new_nodes);
        // Per-broker launch cost for the added nodes.
        super::do_wait(
            &BootstrapModel {
                head_secs: 0.0,
                settle_secs: 2.0,
                ..self.model
            },
            new_nodes.len(),
            self.time_scale,
        );
        Ok(())
    }

    fn get_context(&self) -> Result<FrameworkContext> {
        self.cluster
            .clone()
            .map(FrameworkContext::Kafka)
            .ok_or_else(|| Error::Pilot("kafka: not running".into()))
    }

    fn get_config_data(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        let servers: Vec<String> = self
            .broker_nodes
            .iter()
            .map(|n| format!("node{n}:9092"))
            .collect();
        m.insert("bootstrap.servers".into(), servers.join(","));
        if let Some(first) = self.broker_nodes.first() {
            m.insert("zookeeper.connect".into(), format!("node{first}:2181"));
        }
        m
    }

    fn bootstrap_model(&self) -> BootstrapModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    fn env(nodes: usize) -> PluginEnv {
        let machine = Machine::unthrottled(nodes + 2);
        PluginEnv {
            nodes: machine.allocate("p", nodes).unwrap(),
            description: PilotComputeDescription::new(
                "local://test",
                FrameworkKind::Kafka,
                nodes,
            ),
            machine,
        }
    }

    #[test]
    fn lifecycle_and_context() {
        let env = env(2);
        let mut p = KafkaPlugin::new(&env.description, 0.0);
        assert!(p.wait().is_err(), "wait before submit must fail");
        p.submit_job(&env).unwrap();
        let secs = p.wait().unwrap();
        assert!(secs > 0.0);
        let ctx = p.get_context().unwrap();
        let cluster = ctx.as_kafka().unwrap();
        cluster.create_topic("t", 2).unwrap();
        assert_eq!(cluster.broker_nodes().len(), 2);
        let cfg = p.get_config_data();
        assert!(cfg["bootstrap.servers"].contains(":9092"));
        assert!(cfg.contains_key("zookeeper.connect"));
    }

    #[test]
    fn extend_adds_brokers() {
        let env2 = env(1);
        let mut p = KafkaPlugin::new(&env2.description, 0.0);
        p.submit_job(&env2).unwrap();
        p.wait().unwrap();
        let extra = env2.machine.allocate("p2", 1).unwrap();
        p.extend(&env2, &extra).unwrap();
        let ctx = p.get_context().unwrap();
        assert_eq!(ctx.as_kafka().unwrap().broker_nodes().len(), 2);
        assert!(p.get_config_data()["bootstrap.servers"].matches(":9092").count() == 2);
    }
}
