//! Dask framework plugin: pilot-managed task-parallel engine.

use std::collections::BTreeMap;

use crate::cluster::NodeId;
use crate::config::BootstrapModel;
use crate::engine::TaskEngine;
use crate::error::{Error, Result};
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::{FrameworkContext, ManagerPlugin, PluginEnv};

/// Deploys the Dask-like [`TaskEngine`].  The paper runs the MASS data
/// producers as "8 producer processes in Dask" per node (§6.3), so the
/// default worker count per node is 8.
pub struct DaskPlugin {
    model: BootstrapModel,
    time_scale: f64,
    workers_per_node: usize,
    engine: Option<TaskEngine>,
    pending_nodes: usize,
    scheduler_node: Option<NodeId>,
}

impl DaskPlugin {
    pub fn new(pcd: &PilotComputeDescription, time_scale: f64) -> Self {
        let workers_per_node = pcd.parallelism_per_node(8);
        DaskPlugin {
            model: super::bootstrap_model_for(FrameworkKind::Dask),
            time_scale,
            workers_per_node,
            engine: None,
            pending_nodes: 0,
            scheduler_node: None,
        }
    }
}

impl ManagerPlugin for DaskPlugin {
    fn submit_job(&mut self, env: &PluginEnv) -> Result<()> {
        self.scheduler_node = env.nodes.first().copied();
        self.pending_nodes = env.nodes.len();
        self.engine = Some(TaskEngine::new(
            env.machine.clone(),
            env.nodes.clone(),
            self.workers_per_node,
        ));
        Ok(())
    }

    fn wait(&mut self) -> Result<f64> {
        if self.engine.is_none() {
            return Err(Error::Pilot("dask: wait() before submit_job()".into()));
        }
        Ok(super::do_wait(&self.model, self.pending_nodes, self.time_scale))
    }

    fn extend(&mut self, _env: &PluginEnv, new_nodes: &[NodeId]) -> Result<()> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| Error::Pilot("dask: extend() before submit_job()".into()))?;
        engine.add_workers(new_nodes.to_vec());
        super::do_wait(
            &BootstrapModel {
                head_secs: 0.0,
                settle_secs: 1.0,
                ..self.model
            },
            new_nodes.len(),
            self.time_scale,
        );
        Ok(())
    }

    fn get_context(&self) -> Result<FrameworkContext> {
        self.engine
            .clone()
            .map(FrameworkContext::TaskPar)
            .ok_or_else(|| Error::Pilot("dask: not running".into()))
    }

    fn get_config_data(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        if let Some(s) = self.scheduler_node {
            m.insert("dask.scheduler".into(), format!("tcp://node{s}:8786"));
        }
        m.insert(
            "dask.workers".into(),
            self.engine
                .as_ref()
                .map(|e| e.worker_count().to_string())
                .unwrap_or_default(),
        );
        m
    }

    fn bootstrap_model(&self) -> BootstrapModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    #[test]
    fn lifecycle_submit_compute() {
        let machine = Machine::unthrottled(2);
        let env = PluginEnv {
            nodes: machine.allocate("p", 1).unwrap(),
            description: PilotComputeDescription::new("local://test", FrameworkKind::Dask, 1)
                .with_config("workers_per_node", "2"),
            machine,
        };
        let mut p = DaskPlugin::new(&env.description, 0.0);
        p.submit_job(&env).unwrap();
        let secs = p.wait().unwrap();
        // Dask bootstrap is the cheapest (Fig 6).
        assert!(secs < super::super::bootstrap_model_for(FrameworkKind::Spark).init_secs(1));
        let ctx = p.get_context().unwrap();
        let engine = ctx.as_taskpar().unwrap();
        // Paper Listing 5: interoperable compute unit `compute(x) = x*x`.
        let fut = engine.submit(|_| 2 * 2).unwrap();
        assert_eq!(fut.wait().unwrap(), 4);
        assert!(p.get_config_data()["dask.scheduler"].contains("8786"));
        engine.stop();
    }
}
