//! Flink framework plugin.
//!
//! The paper's framework matrix includes Flink (§4.3) but its
//! evaluation runs no Flink workloads; we model the JobManager +
//! TaskManager bootstrap for the startup experiment and expose a
//! task-parallel context so Compute-Units remain interoperable.

use std::collections::BTreeMap;

use crate::cluster::NodeId;
use crate::config::BootstrapModel;
use crate::engine::TaskEngine;
use crate::error::{Error, Result};
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::{FrameworkContext, ManagerPlugin, PluginEnv};

pub struct FlinkPlugin {
    model: BootstrapModel,
    time_scale: f64,
    slots_per_node: usize,
    engine: Option<TaskEngine>,
    pending_nodes: usize,
    jobmanager_node: Option<NodeId>,
}

impl FlinkPlugin {
    pub fn new(pcd: &PilotComputeDescription, time_scale: f64) -> Self {
        let slots_per_node = pcd.parallelism_per_node(2);
        FlinkPlugin {
            model: super::bootstrap_model_for(FrameworkKind::Flink),
            time_scale,
            slots_per_node,
            engine: None,
            pending_nodes: 0,
            jobmanager_node: None,
        }
    }
}

impl ManagerPlugin for FlinkPlugin {
    fn submit_job(&mut self, env: &PluginEnv) -> Result<()> {
        self.jobmanager_node = env.nodes.first().copied();
        self.pending_nodes = env.nodes.len();
        self.engine = Some(TaskEngine::new(
            env.machine.clone(),
            env.nodes.clone(),
            self.slots_per_node,
        ));
        Ok(())
    }

    fn wait(&mut self) -> Result<f64> {
        if self.engine.is_none() {
            return Err(Error::Pilot("flink: wait() before submit_job()".into()));
        }
        Ok(super::do_wait(&self.model, self.pending_nodes, self.time_scale))
    }

    fn extend(&mut self, _env: &PluginEnv, new_nodes: &[NodeId]) -> Result<()> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| Error::Pilot("flink: extend() before submit_job()".into()))?;
        engine.add_workers(new_nodes.to_vec());
        super::do_wait(
            &BootstrapModel {
                head_secs: 0.0,
                settle_secs: 2.0,
                ..self.model
            },
            new_nodes.len(),
            self.time_scale,
        );
        Ok(())
    }

    fn get_context(&self) -> Result<FrameworkContext> {
        self.engine
            .clone()
            .map(FrameworkContext::TaskPar)
            .ok_or_else(|| Error::Pilot("flink: not running".into()))
    }

    fn get_config_data(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        if let Some(j) = self.jobmanager_node {
            m.insert("jobmanager.rpc.address".into(), format!("node{j}"));
        }
        m.insert(
            "taskmanager.numberOfTaskSlots".into(),
            self.slots_per_node.to_string(),
        );
        m
    }

    fn bootstrap_model(&self) -> BootstrapModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    #[test]
    fn lifecycle() {
        let machine = Machine::unthrottled(2);
        let env = PluginEnv {
            nodes: machine.allocate("p", 2).unwrap(),
            description: PilotComputeDescription::new("local://t", FrameworkKind::Flink, 2),
            machine,
        };
        let mut p = FlinkPlugin::new(&env.description, 0.0);
        p.submit_job(&env).unwrap();
        assert!(p.wait().unwrap() > 0.0);
        let ctx = p.get_context().unwrap();
        let e = ctx.as_taskpar().unwrap();
        assert_eq!(e.worker_count(), 4);
        assert_eq!(p.get_config_data()["taskmanager.numberOfTaskSlots"], "2");
        e.stop();
    }
}
