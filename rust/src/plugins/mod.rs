//! Framework plugins: Kafka, Spark, Dask, Flink (paper §4.1).
//!
//! Each plugin implements the [`ManagerPlugin`] SPI: it "bootstraps" its
//! framework on the pilot's nodes (cost modeled per [`BootstrapModel`],
//! calibrated to the Figure 6 magnitudes), exposes the native context
//! object, and supports runtime extension.
//!
//! Bootstrap structure per framework (drives the Fig 6 ordering):
//!
//! * **Kafka** — ZooKeeper ensemble first, then one broker per node,
//!   then topic-metadata settle.  Heaviest head + per-node cost.
//! * **Spark** — master, then one worker per node, block-manager settle.
//! * **Dask** — scheduler, then lightweight per-node workers; the paper
//!   observes "Dask has the shortest startup times".
//! * **Flink** — jobmanager, then taskmanagers.  The paper deploys
//!   Flink but runs no workloads on it; we model startup and provide a
//!   task-parallel context.

mod dask;
mod flink;
mod kafka;
mod spark;

pub use dask::DaskPlugin;
pub use flink::FlinkPlugin;
pub use kafka::KafkaPlugin;
pub use spark::SparkPlugin;

use crate::config::BootstrapModel;
use crate::error::Result;
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::ManagerPlugin;

/// Construct the plugin for a description (the plugin registry).
///
/// `time_scale` maps modeled bootstrap seconds to real sleeping
/// (0.0 = record only; examples use small non-zero values for pacing).
pub fn create_plugin(
    pcd: &PilotComputeDescription,
    time_scale: f64,
) -> Result<Box<dyn ManagerPlugin>> {
    Ok(match pcd.framework {
        FrameworkKind::Kafka => Box::new(KafkaPlugin::new(pcd, time_scale)),
        FrameworkKind::Spark => Box::new(SparkPlugin::new(pcd, time_scale)),
        FrameworkKind::Dask => Box::new(DaskPlugin::new(pcd, time_scale)),
        FrameworkKind::Flink => Box::new(FlinkPlugin::new(pcd, time_scale)),
    })
}

/// Bootstrap cost model for a framework kind (single source of truth;
/// the Fig 6 sim-plane harness reads these too).
pub fn bootstrap_model_for(kind: FrameworkKind) -> BootstrapModel {
    match kind {
        // ZooKeeper + per-node brokers + metadata settle: slowest.
        FrameworkKind::Kafka => BootstrapModel {
            head_secs: 20.0,
            per_node_secs: 8.0,
            launch_parallelism: 2,
            settle_secs: 15.0,
        },
        // Master + workers + block-manager registration.
        FrameworkKind::Spark => BootstrapModel {
            head_secs: 15.0,
            per_node_secs: 6.0,
            launch_parallelism: 2,
            settle_secs: 10.0,
        },
        // Scheduler + lightweight workers: fastest (paper Fig 6).
        FrameworkKind::Dask => BootstrapModel {
            head_secs: 5.0,
            per_node_secs: 3.0,
            launch_parallelism: 2,
            settle_secs: 3.0,
        },
        // JobManager + TaskManagers.
        FrameworkKind::Flink => BootstrapModel {
            head_secs: 12.0,
            per_node_secs: 5.0,
            launch_parallelism: 2,
            settle_secs: 8.0,
        },
    }
}

/// Shared helper: perform the modeled bootstrap wait.
pub(crate) fn do_wait(model: &BootstrapModel, nodes: usize, time_scale: f64) -> f64 {
    let secs = model.init_secs(nodes);
    if time_scale > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(secs * time_scale));
    }
    secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_ordering_dask_fastest_kafka_slowest() {
        for nodes in [1, 2, 4, 8, 16, 32] {
            let kafka = bootstrap_model_for(FrameworkKind::Kafka).init_secs(nodes);
            let spark = bootstrap_model_for(FrameworkKind::Spark).init_secs(nodes);
            let dask = bootstrap_model_for(FrameworkKind::Dask).init_secs(nodes);
            assert!(kafka > spark, "nodes={nodes}");
            assert!(spark > dask, "nodes={nodes}");
        }
    }

    #[test]
    fn startup_grows_with_nodes() {
        for kind in [
            FrameworkKind::Kafka,
            FrameworkKind::Spark,
            FrameworkKind::Dask,
            FrameworkKind::Flink,
        ] {
            let m = bootstrap_model_for(kind);
            assert!(m.init_secs(32) > m.init_secs(1), "{kind:?}");
        }
    }
}
