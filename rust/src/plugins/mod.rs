//! Framework plugins: Kafka, Spark, Dask, Flink (paper §4.1).
//!
//! Each plugin implements the [`ManagerPlugin`] SPI: it "bootstraps" its
//! framework on the pilot's nodes (cost modeled per [`BootstrapModel`],
//! calibrated to the Figure 6 magnitudes), exposes the native context
//! object, and supports runtime extension.
//!
//! Bootstrap structure per framework (drives the Fig 6 ordering):
//!
//! * **Kafka** — ZooKeeper ensemble first, then one broker per node,
//!   then topic-metadata settle.  Heaviest head + per-node cost.
//! * **Spark** — master, then one worker per node, block-manager settle.
//! * **Dask** — scheduler, then lightweight per-node workers; the paper
//!   observes "Dask has the shortest startup times".
//! * **Flink** — jobmanager, then taskmanagers.  The paper deploys
//!   Flink but runs no workloads on it; we model startup and provide a
//!   task-parallel context.

mod dask;
mod flink;
mod kafka;
mod spark;

pub use dask::DaskPlugin;
pub use flink::FlinkPlugin;
pub use kafka::KafkaPlugin;
pub use spark::SparkPlugin;

use crate::config::BootstrapModel;
use crate::error::Result;
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::ManagerPlugin;

/// Construct the plugin for a description (the plugin registry).
///
/// `time_scale` maps modeled bootstrap seconds to real sleeping
/// (0.0 = record only; examples use small non-zero values for pacing).
pub fn create_plugin(
    pcd: &PilotComputeDescription,
    time_scale: f64,
) -> Result<Box<dyn ManagerPlugin>> {
    Ok(match pcd.framework {
        FrameworkKind::Kafka => Box::new(KafkaPlugin::new(pcd, time_scale)),
        FrameworkKind::Spark => Box::new(SparkPlugin::new(pcd, time_scale)),
        FrameworkKind::Dask => Box::new(DaskPlugin::new(pcd, time_scale)),
        FrameworkKind::Flink => Box::new(FlinkPlugin::new(pcd, time_scale)),
    })
}

/// Bootstrap cost model for a framework kind (single source of truth;
/// the Fig 6 sim-plane harness reads these too).
pub fn bootstrap_model_for(kind: FrameworkKind) -> BootstrapModel {
    match kind {
        // ZooKeeper + per-node brokers + metadata settle: slowest.
        FrameworkKind::Kafka => BootstrapModel {
            head_secs: 20.0,
            per_node_secs: 8.0,
            launch_parallelism: 2,
            settle_secs: 15.0,
        },
        // Master + workers + block-manager registration.
        FrameworkKind::Spark => BootstrapModel {
            head_secs: 15.0,
            per_node_secs: 6.0,
            launch_parallelism: 2,
            settle_secs: 10.0,
        },
        // Scheduler + lightweight workers: fastest (paper Fig 6).
        FrameworkKind::Dask => BootstrapModel {
            head_secs: 5.0,
            per_node_secs: 3.0,
            launch_parallelism: 2,
            settle_secs: 3.0,
        },
        // JobManager + TaskManagers.
        FrameworkKind::Flink => BootstrapModel {
            head_secs: 12.0,
            per_node_secs: 5.0,
            launch_parallelism: 2,
            settle_secs: 8.0,
        },
    }
}

/// Modeled cost of extending a *running* framework by `nodes` nodes —
/// the per-framework scaling cost the autoscale planner weighs against
/// expected drain benefit (Kafka broker join + partition rebalance vs
/// Spark executor attach vs Dask worker join).
///
/// Unlike a fresh bootstrap there is no head-component cost: the
/// extension pays the per-node launches (in `launch_parallelism`-wide
/// waves) plus the settle phase (Kafka's rebalance, Spark's
/// block-manager registration, Dask's scheduler handshake).  The same
/// number floors the recorded extension bootstrap time in
/// [`crate::pilot::PilotComputeService`], so planner estimates and the
/// timeline's reaction latencies agree.
pub fn extension_cost_secs(kind: FrameworkKind, nodes: usize) -> f64 {
    if nodes == 0 {
        return 0.0;
    }
    let m = bootstrap_model_for(kind);
    let waves = nodes.div_ceil(m.launch_parallelism.max(1));
    waves as f64 * m.per_node_secs + m.settle_secs
}

/// Shared helper: perform the modeled bootstrap wait.
pub(crate) fn do_wait(model: &BootstrapModel, nodes: usize, time_scale: f64) -> f64 {
    let secs = model.init_secs(nodes);
    if time_scale > 0.0 {
        std::thread::sleep(std::time::Duration::from_secs_f64(secs * time_scale));
    }
    secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_ordering_dask_fastest_kafka_slowest() {
        for nodes in [1, 2, 4, 8, 16, 32] {
            let kafka = bootstrap_model_for(FrameworkKind::Kafka).init_secs(nodes);
            let spark = bootstrap_model_for(FrameworkKind::Spark).init_secs(nodes);
            let dask = bootstrap_model_for(FrameworkKind::Dask).init_secs(nodes);
            assert!(kafka > spark, "nodes={nodes}");
            assert!(spark > dask, "nodes={nodes}");
        }
    }

    #[test]
    fn startup_grows_with_nodes() {
        for kind in [
            FrameworkKind::Kafka,
            FrameworkKind::Spark,
            FrameworkKind::Dask,
            FrameworkKind::Flink,
        ] {
            let m = bootstrap_model_for(kind);
            assert!(m.init_secs(32) > m.init_secs(1), "{kind:?}");
        }
    }

    /// Pin the per-framework cost tables exactly: the autoscale planner
    /// and the Fig 6 harness both read these constants, so calibration
    /// changes must be deliberate (this test is the change review).
    #[test]
    fn bootstrap_cost_tables_are_pinned() {
        let expect = [
            (FrameworkKind::Kafka, (20.0, 8.0, 2, 15.0)),
            (FrameworkKind::Spark, (15.0, 6.0, 2, 10.0)),
            (FrameworkKind::Dask, (5.0, 3.0, 2, 3.0)),
            (FrameworkKind::Flink, (12.0, 5.0, 2, 8.0)),
        ];
        for (kind, (head, per_node, par, settle)) in expect {
            let m = bootstrap_model_for(kind);
            assert_eq!(m.head_secs, head, "{kind:?} head");
            assert_eq!(m.per_node_secs, per_node, "{kind:?} per-node");
            assert_eq!(m.launch_parallelism, par, "{kind:?} parallelism");
            assert_eq!(m.settle_secs, settle, "{kind:?} settle");
        }
    }

    /// Extension costs: no head cost, per-node waves + settle, with the
    /// rebalance-dominated ordering the planner relies on (Kafka most
    /// expensive to extend, Dask cheapest).
    #[test]
    fn extension_costs_pinned_and_ordered() {
        assert_eq!(extension_cost_secs(FrameworkKind::Kafka, 0), 0.0);
        // One wave of <= launch_parallelism nodes costs the same.
        assert_eq!(extension_cost_secs(FrameworkKind::Kafka, 1), 8.0 + 15.0);
        assert_eq!(extension_cost_secs(FrameworkKind::Kafka, 2), 8.0 + 15.0);
        assert_eq!(extension_cost_secs(FrameworkKind::Kafka, 3), 16.0 + 15.0);
        assert_eq!(extension_cost_secs(FrameworkKind::Spark, 1), 6.0 + 10.0);
        assert_eq!(extension_cost_secs(FrameworkKind::Dask, 1), 3.0 + 3.0);
        assert_eq!(extension_cost_secs(FrameworkKind::Flink, 1), 5.0 + 8.0);
        for n in [1usize, 2, 4, 8] {
            let kafka = extension_cost_secs(FrameworkKind::Kafka, n);
            let spark = extension_cost_secs(FrameworkKind::Spark, n);
            let flink = extension_cost_secs(FrameworkKind::Flink, n);
            let dask = extension_cost_secs(FrameworkKind::Dask, n);
            assert!(kafka > spark && spark > flink && flink > dask, "n={n}");
        }
        // Extension never exceeds a fresh bootstrap of the same size.
        for kind in [
            FrameworkKind::Kafka,
            FrameworkKind::Spark,
            FrameworkKind::Dask,
            FrameworkKind::Flink,
        ] {
            for n in [1usize, 2, 4, 8, 16] {
                assert!(
                    extension_cost_secs(kind, n) < bootstrap_model_for(kind).init_secs(n),
                    "{kind:?} n={n}"
                );
            }
        }
    }

    /// `do_wait` returns the model's modeled seconds regardless of the
    /// time scale, and only the sleep scales (time_scale 0 = no sleep).
    #[test]
    fn do_wait_scaling_is_pinned() {
        let m = bootstrap_model_for(FrameworkKind::Dask);
        let t0 = std::time::Instant::now();
        let modeled = do_wait(&m, 4, 0.0);
        assert!(t0.elapsed().as_secs_f64() < 0.05, "time_scale 0 must not sleep");
        assert_eq!(modeled, m.init_secs(4));
        // A tiny non-zero scale sleeps for secs * scale.
        let scale = 1e-3;
        let t0 = std::time::Instant::now();
        let modeled = do_wait(&m, 4, scale);
        let slept = t0.elapsed().as_secs_f64();
        assert_eq!(modeled, m.init_secs(4));
        assert!(slept >= modeled * scale, "slept {slept}s < {}s", modeled * scale);
        assert!(slept < modeled * scale + 0.25, "slept {slept}s way past the model");
    }
}
