//! Spark framework plugin: pilot-managed micro-batch engine.

use std::collections::BTreeMap;

use crate::cluster::NodeId;
use crate::config::BootstrapModel;
use crate::engine::MicroBatchEngine;
use crate::error::{Error, Result};
use crate::pilot::description::{FrameworkKind, PilotComputeDescription};
use crate::pilot::plugin::{FrameworkContext, ManagerPlugin, PluginEnv};

/// Deploys the Spark-Streaming-like [`MicroBatchEngine`] on the pilot's
/// nodes.  Bootstrap = master + per-node workers.
pub struct SparkPlugin {
    model: BootstrapModel,
    time_scale: f64,
    executors_per_node: usize,
    engine: Option<MicroBatchEngine>,
    pending_nodes: usize,
    master_node: Option<NodeId>,
}

impl SparkPlugin {
    pub fn new(pcd: &PilotComputeDescription, time_scale: f64) -> Self {
        let executors_per_node = pcd.parallelism_per_node(2);
        SparkPlugin {
            model: super::bootstrap_model_for(FrameworkKind::Spark),
            time_scale,
            executors_per_node,
            engine: None,
            pending_nodes: 0,
            master_node: None,
        }
    }
}

impl ManagerPlugin for SparkPlugin {
    fn submit_job(&mut self, env: &PluginEnv) -> Result<()> {
        self.master_node = env.nodes.first().copied();
        self.pending_nodes = env.nodes.len();
        self.engine = Some(MicroBatchEngine::new(
            env.machine.clone(),
            env.nodes.clone(),
            self.executors_per_node,
        ));
        Ok(())
    }

    fn wait(&mut self) -> Result<f64> {
        if self.engine.is_none() {
            return Err(Error::Pilot("spark: wait() before submit_job()".into()));
        }
        Ok(super::do_wait(&self.model, self.pending_nodes, self.time_scale))
    }

    fn extend(&mut self, _env: &PluginEnv, new_nodes: &[NodeId]) -> Result<()> {
        let engine = self
            .engine
            .as_ref()
            .ok_or_else(|| Error::Pilot("spark: extend() before submit_job()".into()))?;
        engine.add_executors(new_nodes.to_vec());
        super::do_wait(
            &BootstrapModel {
                head_secs: 0.0,
                settle_secs: 2.0,
                ..self.model
            },
            new_nodes.len(),
            self.time_scale,
        );
        Ok(())
    }

    fn get_context(&self) -> Result<FrameworkContext> {
        self.engine
            .clone()
            .map(FrameworkContext::MicroBatch)
            .ok_or_else(|| Error::Pilot("spark: not running".into()))
    }

    fn get_config_data(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        if let Some(master) = self.master_node {
            m.insert("spark.master".into(), format!("spark://node{master}:7077"));
        }
        m.insert(
            "spark.executor.instances".into(),
            self.engine
                .as_ref()
                .map(|e| e.executor_count().to_string())
                .unwrap_or_default(),
        );
        m
    }

    fn bootstrap_model(&self) -> BootstrapModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    #[test]
    fn lifecycle_and_extend() {
        let machine = Machine::unthrottled(4);
        let env = PluginEnv {
            nodes: machine.allocate("p", 2).unwrap(),
            description: PilotComputeDescription::new(
                "local://test",
                FrameworkKind::Spark,
                2,
            )
            .with_config("executors_per_node", "3"),
            machine: machine.clone(),
        };
        let mut p = SparkPlugin::new(&env.description, 0.0);
        p.submit_job(&env).unwrap();
        p.wait().unwrap();
        let ctx = p.get_context().unwrap();
        let engine = ctx.as_microbatch().unwrap();
        assert_eq!(engine.executor_count(), 6, "2 nodes x 3 executors");
        let extra = machine.allocate("p2", 1).unwrap();
        p.extend(&env, &extra).unwrap();
        assert_eq!(engine.executor_count(), 9);
        assert!(p.get_config_data()["spark.master"].contains("7077"));
        engine.stop();
    }
}
