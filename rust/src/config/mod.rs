//! Typed configuration for machines, frameworks and experiments.
//!
//! Everything the CLI and the experiment harness can tune lives here as
//! JSON-round-trippable structs, so experiment configs load from files
//! (``--config exp.json``) and the recorded results embed the exact
//! configuration that produced them.

use crate::error::{Error, Result};
use crate::util::Json;

/// Hardware description of one HPC machine (the paper's testbed is
/// XSEDE Wrangler: 24-core / 128 GB nodes with local SSD).
#[derive(Debug, Clone, PartialEq)]
pub struct MachineConfig {
    /// Human-readable machine name (shows up in experiment records).
    pub name: String,
    /// Total nodes available to the resource manager.
    pub nodes: usize,
    /// Cores per node.
    pub cores_per_node: usize,
    /// Memory per node in GB.
    pub mem_gb_per_node: usize,
    /// NIC bandwidth per node, MB/s (full duplex; modeled per direction).
    pub nic_mbps: f64,
    /// Local SSD sequential bandwidth per node, MB/s.
    pub ssd_mbps: f64,
}

impl MachineConfig {
    /// The paper's testbed: Wrangler nodes (24 cores, 128 GB, 10 GbE,
    /// local SSD).  `nodes` is the allocation size, up to 32 in the
    /// paper's largest experiment (§6.5).
    pub fn wrangler(nodes: usize) -> Self {
        MachineConfig {
            name: "wrangler".into(),
            nodes,
            cores_per_node: 24,
            mem_gb_per_node: 128,
            nic_mbps: 1250.0, // 10 GbE
            ssd_mbps: 500.0,
        }
    }

    /// A small machine sized for this host (integration tests/examples).
    pub fn localhost(nodes: usize) -> Self {
        MachineConfig {
            name: "localhost".into(),
            nodes,
            cores_per_node: 2,
            mem_gb_per_node: 4,
            nic_mbps: 4000.0,
            ssd_mbps: 1000.0,
        }
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.cores_per_node == 0 {
            return Err(Error::Config(format!(
                "machine {}: nodes and cores_per_node must be > 0",
                self.name
            )));
        }
        if self.nic_mbps <= 0.0 || self.ssd_mbps <= 0.0 {
            return Err(Error::Config(format!(
                "machine {}: bandwidths must be positive",
                self.name
            )));
        }
        Ok(())
    }
}

/// Framework bootstrap cost model (per framework plugin).
///
/// The paper's Figure 6 decomposes startup into (i) the batch job
/// placement and (ii) framework initialization, which grows with node
/// count (sequential component launches + per-node agent starts).
/// Constants are calibrated to the magnitudes reported for Wrangler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BootstrapModel {
    /// Fixed head-component cost, seconds (e.g. ZooKeeper, Spark master).
    pub head_secs: f64,
    /// Per-node worker/broker launch cost, seconds.
    pub per_node_secs: f64,
    /// How many nodes' worth of launches can proceed in parallel
    /// (launch fan-out of the bootstrap script).
    pub launch_parallelism: usize,
    /// Post-launch settle/health-check cost, seconds.
    pub settle_secs: f64,
}

impl BootstrapModel {
    /// Total framework-init seconds for `nodes` nodes.
    pub fn init_secs(&self, nodes: usize) -> f64 {
        let waves = nodes.div_ceil(self.launch_parallelism.max(1));
        self.head_secs + waves as f64 * self.per_node_secs + self.settle_secs
    }
}

/// Batch-queue model for the SimSlurm adaptor (queue wait + placement).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueueModel {
    /// Base scheduling latency, seconds.
    pub base_secs: f64,
    /// Additional placement cost per node, seconds.
    pub per_node_secs: f64,
}

impl QueueModel {
    pub fn wait_secs(&self, nodes: usize) -> f64 {
        self.base_secs + self.per_node_secs * nodes as f64
    }
}

/// Producer-side cost preset for the simulation plane (DESIGN.md §4b).
///
/// `Calibrated` uses costs measured from this repo's real Rust plane;
/// `PaperEra` scales generation costs to the paper's Python/PyKafka
/// producers (NumPy RNG + string serialization), restoring the
/// RNG-bound regime behind Fig 8's KMeans-static vs -random gap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostPreset {
    #[default]
    Calibrated,
    PaperEra,
}

/// Top-level experiment configuration (shared across figure harnesses).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub machine: MachineConfig,
    /// Kafka partitions per broker node (paper §6.3: fixed at 12/node).
    pub partitions_per_node: usize,
    /// Producer processes per producer node (paper §6.3: 8/node).
    pub producers_per_node: usize,
    /// Micro-batch window seconds for processing experiments (§6.4: 60 s).
    pub window_secs: f64,
    /// Cost preset for the simulation plane.
    pub preset: CostPreset,
    /// Random seed for reproducibility.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            machine: MachineConfig::wrangler(32),
            partitions_per_node: 12,
            producers_per_node: 8,
            window_secs: 60.0,
            preset: CostPreset::Calibrated,
            seed: 42,
        }
    }
}

impl MachineConfig {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("nodes", self.nodes)
            .set("cores_per_node", self.cores_per_node)
            .set("mem_gb_per_node", self.mem_gb_per_node)
            .set("nic_mbps", self.nic_mbps)
            .set("ssd_mbps", self.ssd_mbps)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            j.req(k)?
                .as_f64()
                .ok_or_else(|| Error::Config(format!("machine.{k}: expected number")))
        };
        Ok(MachineConfig {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Config("machine.name: expected string".into()))?
                .to_string(),
            nodes: num("nodes")? as usize,
            cores_per_node: num("cores_per_node")? as usize,
            mem_gb_per_node: num("mem_gb_per_node")? as usize,
            nic_mbps: num("nic_mbps")?,
            ssd_mbps: num("ssd_mbps")?,
        })
    }
}

impl ExperimentConfig {
    /// Load from a JSON config file.
    pub fn from_json_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = ExperimentConfig::default();
        let usize_or = |k: &str, dv: usize| j.get(k).and_then(Json::as_usize).unwrap_or(dv);
        Ok(ExperimentConfig {
            machine: match j.get("machine") {
                Some(m) => MachineConfig::from_json(m)?,
                None => d.machine,
            },
            partitions_per_node: usize_or("partitions_per_node", d.partitions_per_node),
            producers_per_node: usize_or("producers_per_node", d.producers_per_node),
            window_secs: j
                .get("window_secs")
                .and_then(Json::as_f64)
                .unwrap_or(d.window_secs),
            preset: match j.get("preset").and_then(Json::as_str) {
                Some("paper-era") => CostPreset::PaperEra,
                Some("calibrated") | None => CostPreset::Calibrated,
                Some(other) => {
                    return Err(Error::Config(format!("unknown preset '{other}'")))
                }
            },
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
        })
    }

    /// Serialize (embedded into experiment records).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("machine", self.machine.to_json())
            .set("partitions_per_node", self.partitions_per_node)
            .set("producers_per_node", self.producers_per_node)
            .set("window_secs", self.window_secs)
            .set(
                "preset",
                match self.preset {
                    CostPreset::Calibrated => "calibrated",
                    CostPreset::PaperEra => "paper-era",
                },
            )
            .set("seed", self.seed)
    }
}

/// Message-size constants from the paper's Mini-App workloads (§6.3).
pub mod messages {
    /// KMeans message: 5,000 3-D points, ~0.32 MB serialized.
    pub const KMEANS_MSG_BYTES: usize = 320_000;
    /// Light-source message: one APS-format frame, ~2 MB serialized.
    pub const LIGHTSOURCE_MSG_BYTES: usize = 2_000_000;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrangler_defaults_match_paper() {
        let m = MachineConfig::wrangler(32);
        assert_eq!(m.cores_per_node, 24);
        assert_eq!(m.mem_gb_per_node, 128);
        assert_eq!(m.nodes, 32);
        m.validate().unwrap();
        // §6.5: 32 nodes = 1536 vcores (24 cores x 2 hyperthreads x 32).
        assert_eq!(32 * m.cores_per_node * 2, 1536);
    }

    #[test]
    fn validate_rejects_zero_nodes() {
        let mut m = MachineConfig::wrangler(0);
        assert!(m.validate().is_err());
        m.nodes = 1;
        m.cores_per_node = 0;
        assert!(m.validate().is_err());
    }

    #[test]
    fn bootstrap_model_grows_with_nodes() {
        let b = BootstrapModel {
            head_secs: 10.0,
            per_node_secs: 2.0,
            launch_parallelism: 4,
            settle_secs: 5.0,
        };
        assert!(b.init_secs(16) > b.init_secs(4));
        assert_eq!(b.init_secs(4), 10.0 + 2.0 + 5.0);
        assert_eq!(b.init_secs(8), 10.0 + 4.0 + 5.0);
    }

    #[test]
    fn experiment_config_json_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        cfg.preset = CostPreset::PaperEra;
        cfg.window_secs = 30.0;
        let text = cfg.to_json().to_string();
        let back = ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.machine, cfg.machine);
        assert_eq!(back.partitions_per_node, 12);
        assert_eq!(back.producers_per_node, 8);
        assert_eq!(back.preset, CostPreset::PaperEra);
        assert_eq!(back.window_secs, 30.0);
    }

    #[test]
    fn experiment_config_defaults_for_missing_keys() {
        let back = ExperimentConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(back.partitions_per_node, 12);
        assert_eq!(back.preset, CostPreset::Calibrated);
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"preset": "bogus"}"#).unwrap()
        )
        .is_err());
    }
}
