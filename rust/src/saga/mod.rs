//! SAGA-like resource-adaptor layer (paper §4.1).
//!
//! Pilot-Streaming provisions resources through the SAGA Job API, "a
//! lightweight, standards-based abstraction to resource management
//! systems, such as SLURM, SGE and PBS/Torque".  This module is the
//! equivalent: a [`ResourceAdaptor`] trait with
//!
//! * [`LocalAdaptor`] — immediate placement (tests, examples), and
//! * [`SimSlurmAdaptor`] — a modeled batch queue whose wait times follow
//!   a [`QueueModel`], optionally *scaled into real time* so examples
//!   can show realistic pacing without sleeping for minutes.  Virtual
//!   durations are always recorded on the job for the Figure 6 startup
//!   analysis.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::QueueModel;
use crate::error::{Error, Result};

/// SAGA-style job description (attributes map 1:1 onto the paper's
/// Pilot-Compute-Description, §4.1).
#[derive(Debug, Clone)]
pub struct JobDescription {
    /// Bootstrap executable (framework plugin id, e.g. "kafka").
    pub executable: String,
    pub number_of_nodes: usize,
    pub cores_per_node: usize,
    pub walltime_secs: u64,
    pub queue: String,
    pub project: String,
}

impl Default for JobDescription {
    fn default() -> Self {
        JobDescription {
            executable: String::new(),
            number_of_nodes: 1,
            cores_per_node: 1,
            walltime_secs: 3600,
            queue: "normal".into(),
            project: "pilot-streaming".into(),
        }
    }
}

/// SAGA job states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    New,
    Pending,
    Running,
    Done,
    Canceled,
    Failed,
}

/// Handle to a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JobHandle(pub u64);

/// Metadata recorded for a job (virtual durations for Fig 6).
#[derive(Debug, Clone)]
pub struct JobInfo {
    pub description: JobDescription,
    pub state: JobState,
    /// Modeled queue wait (virtual seconds).
    pub queue_wait_secs: f64,
}

/// Adaptor SPI: what Pilot-Streaming needs from a resource manager.
pub trait ResourceAdaptor: Send + Sync {
    /// Submit a placeholder job; returns once accepted (Pending).
    fn submit(&self, description: JobDescription) -> Result<JobHandle>;

    /// Block until the job is Running (queue wait elapses) or fails.
    fn wait_running(&self, handle: JobHandle) -> Result<()>;

    fn state(&self, handle: JobHandle) -> Result<JobState>;

    fn info(&self, handle: JobHandle) -> Result<JobInfo>;

    fn cancel(&self, handle: JobHandle) -> Result<()>;

    /// Adaptor scheme name (diagnostics, e.g. "slurm", "fork").
    fn scheme(&self) -> &'static str;
}

fn update_state(
    jobs: &Mutex<HashMap<JobHandle, JobInfo>>,
    handle: JobHandle,
    f: impl FnOnce(&mut JobInfo),
) -> Result<()> {
    let mut jobs = jobs.lock().unwrap();
    let info = jobs
        .get_mut(&handle)
        .ok_or_else(|| Error::Pilot(format!("unknown job {handle:?}")))?;
    f(info);
    Ok(())
}

/// Immediate-placement adaptor (interactive/local resources).
pub struct LocalAdaptor {
    jobs: Mutex<HashMap<JobHandle, JobInfo>>,
    next_id: AtomicU64,
}

impl Default for LocalAdaptor {
    fn default() -> Self {
        Self::new()
    }
}

impl LocalAdaptor {
    pub fn new() -> Self {
        LocalAdaptor {
            jobs: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
        }
    }
}

impl ResourceAdaptor for LocalAdaptor {
    fn submit(&self, description: JobDescription) -> Result<JobHandle> {
        let handle = JobHandle(self.next_id.fetch_add(1, Ordering::Relaxed));
        self.jobs.lock().unwrap().insert(
            handle,
            JobInfo {
                description,
                state: JobState::Running,
                queue_wait_secs: 0.0,
            },
        );
        Ok(handle)
    }

    fn wait_running(&self, handle: JobHandle) -> Result<()> {
        match self.state(handle)? {
            JobState::Running => Ok(()),
            s => Err(Error::Pilot(format!("job {handle:?} in state {s:?}"))),
        }
    }

    fn state(&self, handle: JobHandle) -> Result<JobState> {
        Ok(self.info(handle)?.state)
    }

    fn info(&self, handle: JobHandle) -> Result<JobInfo> {
        self.jobs
            .lock()
            .unwrap()
            .get(&handle)
            .cloned()
            .ok_or_else(|| Error::Pilot(format!("unknown job {handle:?}")))
    }

    fn cancel(&self, handle: JobHandle) -> Result<()> {
        update_state(&self.jobs, handle, |i| i.state = JobState::Canceled)
    }

    fn scheme(&self) -> &'static str {
        "fork"
    }
}

/// Modeled SLURM batch queue.
///
/// `time_scale` maps virtual queue seconds to real sleeping: 0.0 (tests,
/// benches — no sleeping, purely recorded) up to 1.0 (full fidelity).
pub struct SimSlurmAdaptor {
    model: QueueModel,
    time_scale: f64,
    jobs: Mutex<HashMap<JobHandle, JobInfo>>,
    next_id: AtomicU64,
}

impl SimSlurmAdaptor {
    pub fn new(model: QueueModel, time_scale: f64) -> Arc<Self> {
        Arc::new(SimSlurmAdaptor {
            model,
            time_scale: time_scale.clamp(0.0, 1.0),
            jobs: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
        })
    }

    /// Wrangler-ish queue: ~20 s base scheduling latency plus a small
    /// per-node placement cost.
    pub fn wrangler(time_scale: f64) -> Arc<Self> {
        Self::new(
            QueueModel {
                base_secs: 20.0,
                per_node_secs: 0.5,
            },
            time_scale,
        )
    }
}

impl ResourceAdaptor for SimSlurmAdaptor {
    fn submit(&self, description: JobDescription) -> Result<JobHandle> {
        let handle = JobHandle(self.next_id.fetch_add(1, Ordering::Relaxed));
        let wait = self.model.wait_secs(description.number_of_nodes);
        self.jobs.lock().unwrap().insert(
            handle,
            JobInfo {
                description,
                state: JobState::Pending,
                queue_wait_secs: wait,
            },
        );
        Ok(handle)
    }

    fn wait_running(&self, handle: JobHandle) -> Result<()> {
        let info = self.info(handle)?;
        match info.state {
            JobState::Pending => {
                if self.time_scale > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(
                        info.queue_wait_secs * self.time_scale,
                    ));
                }
                update_state(&self.jobs, handle, |i| i.state = JobState::Running)
            }
            JobState::Running => Ok(()),
            s => Err(Error::Pilot(format!("job {handle:?} in state {s:?}"))),
        }
    }

    fn state(&self, handle: JobHandle) -> Result<JobState> {
        Ok(self.info(handle)?.state)
    }

    fn info(&self, handle: JobHandle) -> Result<JobInfo> {
        self.jobs
            .lock()
            .unwrap()
            .get(&handle)
            .cloned()
            .ok_or_else(|| Error::Pilot(format!("unknown job {handle:?}")))
    }

    fn cancel(&self, handle: JobHandle) -> Result<()> {
        update_state(&self.jobs, handle, |i| i.state = JobState::Canceled)
    }

    fn scheme(&self) -> &'static str {
        "slurm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jd(nodes: usize) -> JobDescription {
        JobDescription {
            executable: "kafka".into(),
            number_of_nodes: nodes,
            ..Default::default()
        }
    }

    #[test]
    fn local_adaptor_runs_immediately() {
        let a = LocalAdaptor::new();
        let h = a.submit(jd(2)).unwrap();
        assert_eq!(a.state(h).unwrap(), JobState::Running);
        a.wait_running(h).unwrap();
        assert_eq!(a.info(h).unwrap().queue_wait_secs, 0.0);
        a.cancel(h).unwrap();
        assert_eq!(a.state(h).unwrap(), JobState::Canceled);
    }

    #[test]
    fn sim_slurm_records_queue_wait() {
        let a = SimSlurmAdaptor::new(
            QueueModel {
                base_secs: 10.0,
                per_node_secs: 1.0,
            },
            0.0, // no real sleeping in tests
        );
        let h = a.submit(jd(4)).unwrap();
        assert_eq!(a.state(h).unwrap(), JobState::Pending);
        assert_eq!(a.info(h).unwrap().queue_wait_secs, 14.0);
        a.wait_running(h).unwrap();
        assert_eq!(a.state(h).unwrap(), JobState::Running);
        // Larger jobs wait longer (virtual).
        let h8 = a.submit(jd(8)).unwrap();
        assert!(a.info(h8).unwrap().queue_wait_secs > 14.0);
    }

    #[test]
    fn cancel_pending_job_cannot_run() {
        let a = SimSlurmAdaptor::wrangler(0.0);
        let h = a.submit(jd(1)).unwrap();
        a.cancel(h).unwrap();
        assert!(a.wait_running(h).is_err());
    }

    #[test]
    fn unknown_handle_errors() {
        let a = LocalAdaptor::new();
        assert!(a.state(JobHandle(99)).is_err());
        assert!(a.cancel(JobHandle(99)).is_err());
    }
}
