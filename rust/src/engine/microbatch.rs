//! Spark-Streaming-like micro-batch engine.
//!
//! The paper's MASA Mini-App "relies on Spark Streaming and a mini-batch
//! window of 60 sec" (§6.4) with "1 task per Kafka partition".  This
//! engine reproduces that model on the real plane:
//!
//! * a **driver** thread per streaming job ticks every window interval,
//!   snapshots each partition's high watermark, and emits **one task per
//!   partition** covering the new offset range (Spark's Kafka
//!   direct-stream approach);
//! * tasks run on an executor pool spanning the pilot's nodes (the pool
//!   is a [`TaskEngine`], so `add_executors` extends it at runtime —
//!   the paper's dynamic-scaling story);
//! * the driver barriers on the batch (like Spark) and records batch
//!   duration; batches that outrun the window are counted as *behind*
//!   — the backpressure signal the paper's resource management reacts
//!   to.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::broker::{key_hash, BrokerCluster, Partitioner, Producer, ProducerConfig, Record};
use crate::cluster::{Machine, NodeId};
use crate::error::{Error, Result};
use crate::metrics::{Histogram, RateMeter};

use super::taskpar::TaskEngine;

/// Per-task context handed to processors.
#[derive(Debug, Clone, Copy)]
pub struct TaskContext {
    pub partition: usize,
    /// Executor node the task landed on.
    pub node: NodeId,
    /// Batch sequence number.
    pub batch: u64,
}

/// Output collector handed to [`BatchProcessor::process_emit`]: records
/// emitted here are produced to the job's downstream topics (stage
/// chaining — [`StreamingJobConfig::output_topics`]).
///
/// Keys are re-keyed through the broker's own route function
/// ([`crate::broker::key_hash`]) at emit time, and the task's keyed
/// producers jump-hash that route onto the *live* partition set — so a
/// repartition racing the batch re-routes pending emissions instead of
/// landing them on a sealed partition, and per-key order holds across
/// every hop of a chained pipeline.
#[derive(Debug, Default)]
pub struct Emitter {
    /// `(branch, route, value)` — branch indexes the job's
    /// `output_topics`; route is the key hash (None ⇒ round-robin).
    out: Vec<(usize, Option<u64>, Vec<u8>)>,
}

impl Emitter {
    fn new() -> Self {
        Emitter { out: Vec::new() }
    }

    /// Emit to the first (usually only) output topic.
    pub fn emit(&mut self, key: Option<&[u8]>, value: Vec<u8>) {
        self.emit_to(0, key, value);
    }

    /// Emit to output topic `branch` (split nodes route across
    /// branches; everything else uses [`Emitter::emit`]).
    pub fn emit_to(&mut self, branch: usize, key: Option<&[u8]>, value: Vec<u8>) {
        self.out.push((branch, key.map(key_hash), value));
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }
}

/// User-defined batch processing function (the paper's Compute-Unit in
/// its streaming form — Listing 5's `compute` over a window of records).
pub trait BatchProcessor: Send + Sync {
    fn process(&self, ctx: &TaskContext, records: &[Record]) -> Result<()>;

    /// Like [`BatchProcessor::process`], but with an [`Emitter`] for
    /// producing results downstream.  Only called when the job has
    /// `output_topics`; the default ignores the emitter so sink-only
    /// processors need not change.
    fn process_emit(&self, ctx: &TaskContext, records: &[Record], out: &mut Emitter) -> Result<()> {
        let _ = out;
        self.process(ctx, records)
    }
}

impl<F> BatchProcessor for F
where
    F: Fn(&TaskContext, &[Record]) -> Result<()> + Send + Sync,
{
    fn process(&self, ctx: &TaskContext, records: &[Record]) -> Result<()> {
        self(ctx, records)
    }
}

/// Streaming job configuration.
#[derive(Debug, Clone)]
pub struct StreamingJobConfig {
    pub topic: String,
    /// Consumer group used for offset commits.
    pub group: String,
    /// Micro-batch window (paper §6.4 uses 60 s; examples use shorter).
    pub window: Duration,
    /// Per-fetch byte cap while draining a partition range.
    pub max_fetch_bytes: usize,
    /// Downstream topics this job's processor emits to (stage
    /// chaining).  Empty for sink stages.  Emissions are flushed before
    /// a task's offsets commit, so a drained input (lag 0 on a current
    /// epoch) guarantees every derived record already landed downstream
    /// — the invariant topological drain rests on.
    pub output_topics: Vec<String>,
}

impl StreamingJobConfig {
    pub fn new(topic: &str, window: Duration) -> Self {
        StreamingJobConfig {
            topic: topic.to_string(),
            group: format!("{topic}-job"),
            window,
            max_fetch_bytes: 8 << 20,
            output_topics: Vec::new(),
        }
    }

    pub fn with_output_topics(mut self, topics: Vec<String>) -> Self {
        self.output_topics = topics;
        self
    }
}

/// Live statistics of a streaming job.
#[derive(Debug, Default)]
pub struct JobStats {
    /// Messages/bytes processed.
    pub processed: RateMeter,
    /// Messages/bytes emitted downstream (zero for sink stages).
    pub emitted: RateMeter,
    /// Wall-clock duration of each micro-batch (task barrier time).
    pub batch_secs: Histogram,
    /// Broker-timestamp to processing-completion latency per batch.
    pub record_latency: Histogram,
    /// Completed batches.
    pub batches: AtomicU64,
    /// Batches whose processing outran the window (backpressure signal).
    pub behind: AtomicU64,
    /// Duration of the most recent micro-batch, nanoseconds (cheap
    /// atomic gauge the autoscaler samples for window-overrun detection).
    pub last_batch_ns: AtomicU64,
    /// Processor errors.
    pub errors: AtomicU64,
}

impl JobStats {
    fn new() -> Arc<Self> {
        Arc::new(JobStats {
            processed: RateMeter::new(),
            emitted: RateMeter::new(),
            batch_secs: Histogram::new(),
            record_latency: Histogram::new(),
            batches: AtomicU64::new(0),
            behind: AtomicU64::new(0),
            last_batch_ns: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        })
    }

    /// Most recent micro-batch duration in seconds (0.0 before the
    /// first batch completes).
    pub fn last_batch_secs(&self) -> f64 {
        self.last_batch_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// Handle to a running streaming job.
pub struct StreamingJobHandle {
    stats: Arc<JobStats>,
    stop: Arc<AtomicBool>,
    driver: Option<std::thread::JoinHandle<()>>,
}

impl StreamingJobHandle {
    pub fn stats(&self) -> &Arc<JobStats> {
        &self.stats
    }

    /// Signal the driver to stop and wait for it.
    pub fn stop(mut self) -> Arc<JobStats> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.driver.take() {
            let _ = h.join();
        }
        self.stats.clone()
    }
}

impl Drop for StreamingJobHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.driver.take() {
            let _ = h.join();
        }
    }
}

/// The micro-batch engine: executor pool + job drivers.
#[derive(Clone)]
pub struct MicroBatchEngine {
    pool: TaskEngine,
}

impl std::fmt::Debug for MicroBatchEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MicroBatchEngine")
            .field("executors", &self.pool.worker_count())
            .finish()
    }
}

impl MicroBatchEngine {
    /// `executors_per_node` mirrors Spark's executor cores.
    pub fn new(machine: Machine, nodes: Vec<NodeId>, executors_per_node: usize) -> Self {
        MicroBatchEngine {
            pool: TaskEngine::new(machine, nodes, executors_per_node),
        }
    }

    /// Serve micro-batch jobs on an existing executor pool.  This is
    /// the cross-framework path of the application layer: a Dask- or
    /// Flink-managed [`TaskEngine`] (whose pilot handles extension and
    /// shrinking) runs the same windowed jobs Spark's engine does —
    /// both handles share the pool, so workers added through the pilot
    /// are visible here immediately.
    pub fn with_pool(pool: TaskEngine) -> Self {
        MicroBatchEngine { pool }
    }

    pub fn executor_count(&self) -> usize {
        self.pool.worker_count()
    }

    pub fn nodes(&self) -> Vec<NodeId> {
        self.pool.nodes()
    }

    /// Extend the executor pool at runtime (pilot extend).
    pub fn add_executors(&self, nodes: Vec<NodeId>) {
        self.pool.add_workers(nodes);
    }

    /// Drain executors on `nodes` (pilot shrink).
    pub fn remove_executors(&self, nodes: &[NodeId]) {
        self.pool.remove_workers(nodes);
    }

    /// Stop the executor pool (jobs must be stopped first).
    pub fn stop(&self) {
        self.pool.stop();
    }

    /// The underlying executor pool (Compute-Units run here too).
    pub fn executor_pool(&self) -> TaskEngine {
        self.pool.clone()
    }

    /// Start a streaming job; the driver polls `cluster` every window.
    pub fn start_job(
        &self,
        cluster: BrokerCluster,
        config: StreamingJobConfig,
        processor: Arc<dyn BatchProcessor>,
    ) -> Result<StreamingJobHandle> {
        // Validate the topics exist up front; the driver re-derives the
        // partition count (and therefore its task parallelism) every
        // window, so a runtime repartition moves the per-batch task
        // fan-out with it.
        cluster.partition_count(&config.topic)?;
        for out in &config.output_topics {
            cluster.partition_count(out)?;
        }
        let stats = JobStats::new();
        let stop = Arc::new(AtomicBool::new(false));
        let pool = self.pool.clone();

        let driver_stats = stats.clone();
        let driver_stop = stop.clone();
        let driver = std::thread::Builder::new()
            .name(format!("driver-{}", config.topic))
            .spawn(move || {
                driver_loop(pool, cluster, config, processor, driver_stats, driver_stop)
            })
            .map_err(|e| Error::Engine(format!("spawn driver: {e}")))?;

        Ok(StreamingJobHandle {
            stats,
            stop,
            driver: Some(driver),
        })
    }
}

fn driver_loop(
    pool: TaskEngine,
    cluster: BrokerCluster,
    config: StreamingJobConfig,
    processor: Arc<dyn BatchProcessor>,
    stats: Arc<JobStats>,
    stop: Arc<AtomicBool>,
) {
    // Offsets are tracked per partition id and lazily extended as the
    // topic grows (resume semantics: a partition's first appearance
    // starts at its committed offset).
    let mut positions: HashMap<usize, u64> = HashMap::new();
    let mut batch_no: u64 = 0;

    while !stop.load(Ordering::Relaxed) {
        let tick = Instant::now();

        // Re-derive parallelism from the live partition set: one task
        // per partition with new data (paper: "Spark Streaming assigns
        // 1 task per Kafka partition"), including partitions retired by
        // a shrink that still hold a backlog.
        let n_partitions = match cluster.total_partitions(&config.topic) {
            Ok(n) => n,
            Err(_) => break, // cluster stopped
        };

        // Snapshot watermarks; one task per partition with new data.
        let mut tasks = Vec::new();
        for p in 0..n_partitions {
            let pos = *positions
                .entry(p)
                .or_insert_with(|| cluster.committed(&config.group, &config.topic, p));
            let end = match cluster.end_offset(&config.topic, p) {
                Ok(e) => e,
                Err(_) => break, // cluster stopped
            };
            if end > pos {
                tasks.push((p, pos, end));
            }
        }

        let batch_start = Instant::now();
        let mut futures = Vec::new();
        for (p, pos, end) in &tasks {
            let (p, pos, end) = (*p, *pos, *end);
            let cluster = cluster.clone();
            let config = config.clone();
            let processor = processor.clone();
            let stats = stats.clone();
            let fut = pool.submit(move |node| {
                process_range(
                    &cluster, &config, &*processor, node, p, pos, end, batch_no, &stats,
                )
            });
            match fut {
                Ok(f) => futures.push((p, f)),
                Err(_) => return, // pool stopped
            }
        }

        let mut new_positions = Vec::new();
        for (p, f) in futures {
            match f.wait() {
                Ok(Ok(consumed_to)) => new_positions.push((p, consumed_to)),
                Ok(Err(_)) | Err(_) => {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        for (p, pos) in new_positions {
            positions.insert(p, pos);
            cluster.commit(&config.group, &config.topic, p, pos);
        }

        if !tasks.is_empty() {
            let batch_secs = batch_start.elapsed().as_secs_f64();
            stats.batch_secs.record_secs(batch_secs);
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats
                .last_batch_ns
                .store((batch_secs * 1e9) as u64, Ordering::Relaxed);
            batch_no += 1;
            if batch_secs > config.window.as_secs_f64() {
                stats.behind.fetch_add(1, Ordering::Relaxed);
            }
        }

        // Sleep out the remainder of the window (in small slices so
        // stop() stays responsive).
        while tick.elapsed() < config.window && !stop.load(Ordering::Relaxed) {
            let left = config.window.saturating_sub(tick.elapsed());
            std::thread::sleep(left.min(Duration::from_millis(20)));
        }
    }
}

/// Drain one partition's offset range through the processor.
/// Returns the next offset to consume.
#[allow(clippy::too_many_arguments)]
fn process_range(
    cluster: &BrokerCluster,
    config: &StreamingJobConfig,
    processor: &dyn BatchProcessor,
    node: NodeId,
    partition: usize,
    mut pos: u64,
    end: u64,
    batch: u64,
    stats: &JobStats,
) -> Result<u64> {
    let ctx = TaskContext {
        partition,
        node,
        batch,
    };
    // Resolve the topic handle once per task: the fetch loop below runs
    // against it without re-touching the cluster's topics snapshot
    // (partition ids are stable across epochs, so a mid-range
    // repartition cannot invalidate reads).
    let topic = cluster.topic(&config.topic)?;
    // One keyed producer per output topic (stage chaining).  Keyed:
    // emitted routes are the key hashes computed at emit time, so equal
    // keys land on one downstream partition and per-key order survives
    // the hop; unkeyed emissions round-robin.  A repartition racing the
    // batch is absorbed inside the producer (pending records re-route
    // on the epoch bump).
    let mut outputs: Vec<Producer> = Vec::with_capacity(config.output_topics.len());
    for out in &config.output_topics {
        outputs.push(Producer::new(
            cluster.clone(),
            out,
            node,
            ProducerConfig {
                partitioner: Partitioner::Keyed,
                ..ProducerConfig::default()
            },
        )?);
    }
    while pos < end {
        let records = cluster.fetch_from(
            &topic,
            partition,
            pos,
            config.max_fetch_bytes,
            node,
            Duration::from_millis(100),
        )?;
        if records.is_empty() {
            break;
        }
        // Only process up to the snapshot end; later records belong to
        // the next batch.
        let cut = records.partition_point(|r| r.offset < end);
        let slice = &records[..cut];
        if slice.is_empty() {
            break;
        }
        if outputs.is_empty() {
            processor.process(&ctx, slice)?;
        } else {
            let mut emitter = Emitter::new();
            processor.process_emit(&ctx, slice, &mut emitter)?;
            let mut emitted = 0u64;
            let mut emitted_bytes = 0u64;
            for (branch, route, value) in emitter.out.drain(..) {
                let producer = outputs.get_mut(branch).ok_or_else(|| {
                    Error::Engine(format!(
                        "emit_to branch {branch} out of range ({} output topics)",
                        config.output_topics.len()
                    ))
                })?;
                emitted += 1;
                emitted_bytes += value.len() as u64;
                producer.send_routed(route, value)?;
            }
            stats.emitted.record_many(emitted, emitted_bytes);
        }
        let bytes: usize = slice.iter().map(|r| r.value.len()).sum();
        stats
            .processed
            .record_many(slice.len() as u64, bytes as u64);
        let now_ns = cluster.elapsed_ns();
        for r in slice {
            stats
                .record_latency
                .record_ns(now_ns.saturating_sub(r.timestamp_ns));
        }
        pos = slice.last().unwrap().offset + 1;
    }
    // Flush every output before reporting the range consumed: the
    // driver commits offsets only after the task returns, so a
    // committed (drained) input range implies its derived records are
    // already appended downstream.  On the error path above, buffered
    // emissions flush on Drop and the uncommitted range reprocesses —
    // at-least-once across the edge, matching the input-side contract.
    for producer in &mut outputs {
        producer.flush()?;
    }
    Ok(pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Mutex;

    fn setup(partitions: usize) -> (Machine, BrokerCluster) {
        let m = Machine::unthrottled(4);
        let c = BrokerCluster::new(m.clone(), vec![0]);
        c.create_topic("t", partitions).unwrap();
        (m, c)
    }

    fn wait_for<F: Fn() -> bool>(cond: F, secs: f64) -> bool {
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < secs {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        false
    }

    #[test]
    fn processes_all_produced_records() {
        let (m, c) = setup(3);
        let engine = MicroBatchEngine::new(m, vec![1, 2], 1);
        let count = Arc::new(AtomicUsize::new(0));
        let count2 = count.clone();
        let processor = move |_ctx: &TaskContext, recs: &[Record]| {
            count2.fetch_add(recs.len(), Ordering::Relaxed);
            Ok(())
        };
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(50)),
                Arc::new(processor),
            )
            .unwrap();
        for i in 0..30u8 {
            c.produce("t", (i % 3) as usize, 3, &[vec![i]]).unwrap();
        }
        assert!(
            wait_for(|| count.load(Ordering::Relaxed) == 30, 5.0),
            "processed {} of 30",
            count.load(Ordering::Relaxed)
        );
        let stats = job.stop();
        assert_eq!(stats.processed.messages(), 30);
        assert!(stats.batches.load(Ordering::Relaxed) >= 1);
        engine.stop();
    }

    #[test]
    fn partition_isolation_one_task_per_partition() {
        let (m, c) = setup(2);
        let engine = MicroBatchEngine::new(m, vec![1], 2);
        let seen: Arc<Mutex<Vec<(usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let processor = move |ctx: &TaskContext, recs: &[Record]| {
            for r in recs {
                seen2.lock().unwrap().push((ctx.partition, r.offset));
            }
            Ok(())
        };
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30)),
                Arc::new(processor),
            )
            .unwrap();
        c.produce("t", 0, 3, &[vec![0], vec![1]]).unwrap();
        c.produce("t", 1, 3, &[vec![2]]).unwrap();
        assert!(wait_for(|| seen.lock().unwrap().len() == 3, 5.0));
        job.stop();
        engine.stop();
        let mut got = seen.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, vec![(0, 0), (0, 1), (1, 0)]);
    }

    #[test]
    fn resumes_from_committed_offsets() {
        let (m, c) = setup(1);
        let engine = MicroBatchEngine::new(m, vec![1], 1);
        c.produce("t", 0, 3, &[vec![1], vec![2]]).unwrap();
        let count = Arc::new(AtomicUsize::new(0));
        {
            let count2 = count.clone();
            let job = engine
                .start_job(
                    c.clone(),
                    StreamingJobConfig::new("t", Duration::from_millis(30)),
                    Arc::new(move |_: &TaskContext, recs: &[Record]| {
                        count2.fetch_add(recs.len(), Ordering::Relaxed);
                        Ok(())
                    }),
                )
                .unwrap();
            assert!(wait_for(|| count.load(Ordering::Relaxed) == 2, 5.0));
            job.stop();
        }
        // Second job with the same group: nothing to reprocess.
        c.produce("t", 0, 3, &[vec![3]]).unwrap();
        let second = Arc::new(AtomicUsize::new(0));
        let second2 = second.clone();
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30)),
                Arc::new(move |_: &TaskContext, recs: &[Record]| {
                    second2.fetch_add(recs.len(), Ordering::Relaxed);
                    Ok(())
                }),
            )
            .unwrap();
        assert!(wait_for(|| second.load(Ordering::Relaxed) >= 1, 5.0));
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(second.load(Ordering::Relaxed), 1, "only the new record");
        job.stop();
        engine.stop();
    }

    #[test]
    fn processor_errors_are_counted_not_fatal() {
        let (m, c) = setup(1);
        let engine = MicroBatchEngine::new(m, vec![1], 1);
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30)),
                Arc::new(|_: &TaskContext, _: &[Record]| {
                    Err(Error::Engine("synthetic failure".into()))
                }),
            )
            .unwrap();
        c.produce("t", 0, 3, &[vec![1]]).unwrap();
        assert!(wait_for(
            || job.stats().errors.load(Ordering::Relaxed) >= 1,
            5.0
        ));
        job.stop();
        engine.stop();
    }

    #[test]
    fn job_tasks_follow_live_partition_count() {
        // A running job must fan out over partitions created *after*
        // start_job: repartition mid-stream and confirm records landing
        // on the new partitions are processed.
        let (m, c) = setup(1);
        let engine = MicroBatchEngine::new(m, vec![1, 2], 2);
        let seen: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let seen2 = seen.clone();
        let processor = move |ctx: &TaskContext, recs: &[Record]| {
            for _ in recs {
                seen2.lock().unwrap().push(ctx.partition);
            }
            Ok(())
        };
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30)),
                Arc::new(processor),
            )
            .unwrap();
        c.produce("t", 0, 3, &[vec![1]]).unwrap();
        assert!(wait_for(|| seen.lock().unwrap().len() == 1, 5.0));
        c.repartition_topic("t", 3).unwrap();
        c.produce("t", 1, 3, &[vec![2]]).unwrap();
        c.produce("t", 2, 3, &[vec![3]]).unwrap();
        assert!(
            wait_for(|| seen.lock().unwrap().len() == 3, 5.0),
            "saw {:?}",
            seen.lock().unwrap()
        );
        job.stop();
        engine.stop();
        let mut got = seen.lock().unwrap().clone();
        got.sort();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn with_pool_shares_workers_with_the_task_engine() {
        // A Dask/Flink-style pool serves micro-batch jobs; growing the
        // pool through its own handle is visible to the wrapper.
        let (m, c) = setup(2);
        let pool = TaskEngine::new(m, vec![1], 1);
        let engine = MicroBatchEngine::with_pool(pool.clone());
        assert_eq!(engine.executor_count(), 1);
        pool.add_workers(vec![2]);
        assert_eq!(engine.executor_count(), 2);

        let count = Arc::new(AtomicUsize::new(0));
        let count2 = count.clone();
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30)),
                Arc::new(move |_: &TaskContext, recs: &[Record]| {
                    count2.fetch_add(recs.len(), Ordering::Relaxed);
                    Ok(())
                }),
            )
            .unwrap();
        c.produce("t", 0, 3, &[vec![1], vec![2]]).unwrap();
        c.produce("t", 1, 3, &[vec![3]]).unwrap();
        assert!(wait_for(|| count.load(Ordering::Relaxed) == 3, 5.0));
        job.stop();
        engine.stop();
    }

    #[test]
    fn add_executors_at_runtime() {
        let (m, _c) = setup(1);
        let engine = MicroBatchEngine::new(m, vec![1], 2);
        assert_eq!(engine.executor_count(), 2);
        engine.add_executors(vec![2, 3]);
        assert_eq!(engine.executor_count(), 6);
        engine.stop();
    }

    /// Re-emits each record keyed by its first value byte.
    struct RekeyEmit;
    impl BatchProcessor for RekeyEmit {
        fn process(&self, _ctx: &TaskContext, _records: &[Record]) -> Result<()> {
            Ok(())
        }
        fn process_emit(
            &self,
            _ctx: &TaskContext,
            records: &[Record],
            out: &mut Emitter,
        ) -> Result<()> {
            for r in records {
                out.emit(Some(&r.value[..1]), r.value.to_vec());
            }
            Ok(())
        }
    }

    #[test]
    fn emitting_job_chains_records_to_the_downstream_topic() {
        let (m, c) = setup(2);
        c.create_topic("d", 4).unwrap();
        let engine = MicroBatchEngine::new(m, vec![1], 2);
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30))
                    .with_output_topics(vec!["d".into()]),
                Arc::new(RekeyEmit),
            )
            .unwrap();
        for i in 0..20u8 {
            c.produce("t", (i % 2) as usize, 3, &[vec![i % 4, i]]).unwrap();
        }
        let downstream =
            || (0..4).map(|p| c.end_offset("d", p).unwrap_or(0)).sum::<u64>();
        assert!(
            wait_for(|| downstream() == 20, 5.0),
            "downstream has {} of 20",
            downstream()
        );
        // Keyed routing: every record of a key shares one partition.
        let topic = c.topic("d").unwrap();
        let mut key_partitions: HashMap<u8, Vec<usize>> = HashMap::new();
        for p in 0..4 {
            let recs = c
                .fetch_from(&topic, p, 0, 8 << 20, 3, Duration::from_millis(1))
                .unwrap_or_default();
            for r in recs {
                let owners = key_partitions.entry(r.value[0]).or_default();
                if !owners.contains(&p) {
                    owners.push(p);
                }
            }
        }
        for (key, owners) in &key_partitions {
            assert_eq!(owners.len(), 1, "key {key} split across {owners:?}");
        }
        let stats = job.stop();
        assert_eq!(stats.processed.messages(), 20);
        assert_eq!(stats.emitted.messages(), 20);
        engine.stop();
    }

    /// Emits every record unkeyed (round-robin downstream).
    struct UnkeyedEmit;
    impl BatchProcessor for UnkeyedEmit {
        fn process(&self, _ctx: &TaskContext, _records: &[Record]) -> Result<()> {
            Ok(())
        }
        fn process_emit(
            &self,
            _ctx: &TaskContext,
            records: &[Record],
            out: &mut Emitter,
        ) -> Result<()> {
            for r in records {
                out.emit(None, r.value.to_vec());
            }
            Ok(())
        }
    }

    #[test]
    fn unkeyed_emissions_round_robin_across_downstream_partitions() {
        let (m, c) = setup(1);
        c.create_topic("d", 3).unwrap();
        let engine = MicroBatchEngine::new(m, vec![1], 1);
        // All records land before the first batch, so one task (one
        // producer) emits all nine and the spread is exact.
        let batch: Vec<Vec<u8>> = (0..9u8).map(|i| vec![i]).collect();
        c.produce("t", 0, 3, &batch).unwrap();
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30))
                    .with_output_topics(vec!["d".into()]),
                Arc::new(UnkeyedEmit),
            )
            .unwrap();
        let per_part = || -> Vec<u64> { (0..3).map(|p| c.end_offset("d", p).unwrap_or(0)).collect() };
        assert!(
            wait_for(|| per_part().iter().sum::<u64>() == 9, 5.0),
            "downstream has {:?}",
            per_part()
        );
        assert_eq!(per_part(), vec![3, 3, 3], "unkeyed emissions must round-robin");
        job.stop();
        engine.stop();
    }

    /// Routes to a branch index past the output list.
    struct BadBranch;
    impl BatchProcessor for BadBranch {
        fn process(&self, _ctx: &TaskContext, _records: &[Record]) -> Result<()> {
            Ok(())
        }
        fn process_emit(
            &self,
            _ctx: &TaskContext,
            _records: &[Record],
            out: &mut Emitter,
        ) -> Result<()> {
            out.emit_to(5, None, vec![1]);
            Ok(())
        }
    }

    #[test]
    fn out_of_range_branch_is_a_counted_task_error() {
        let (m, c) = setup(1);
        c.create_topic("d", 1).unwrap();
        let engine = MicroBatchEngine::new(m, vec![1], 1);
        let job = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30))
                    .with_output_topics(vec!["d".into()]),
                Arc::new(BadBranch),
            )
            .unwrap();
        c.produce("t", 0, 3, &[vec![1]]).unwrap();
        assert!(wait_for(
            || job.stats().errors.load(Ordering::Relaxed) >= 1,
            5.0
        ));
        job.stop();
        engine.stop();
    }

    #[test]
    fn start_job_validates_output_topics_up_front() {
        let (m, c) = setup(1);
        let engine = MicroBatchEngine::new(m, vec![1], 1);
        let err = engine
            .start_job(
                c.clone(),
                StreamingJobConfig::new("t", Duration::from_millis(30))
                    .with_output_topics(vec!["missing".into()]),
                Arc::new(UnkeyedEmit),
            )
            .err();
        assert!(err.is_some(), "missing output topic must fail start_job");
        engine.stop();
    }
}
