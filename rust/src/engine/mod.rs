//! Stream-processing engines (the paper's "data processing" plugins).
//!
//! Two execution backends mirror the paper's framework matrix (§4):
//!
//! * [`microbatch`] — a Spark-Streaming-like micro-batch engine (window
//!   assembly, one task per Kafka partition, executor pool, batch
//!   barrier) used by the MASA Mini-App;
//! * [`taskpar`] — a Dask-like futures engine used by the MASS data
//!   producers and as a generic Compute-Unit backend.
//!
//! Both support runtime extension (`add_executors` / `add_workers`),
//! which is what pilot `extend()` calls through the framework plugins.

pub mod microbatch;
pub mod taskpar;

pub use microbatch::{
    BatchProcessor, Emitter, JobStats, MicroBatchEngine, StreamingJobConfig,
    StreamingJobHandle, TaskContext,
};
pub use taskpar::{TaskEngine, TaskFuture};
