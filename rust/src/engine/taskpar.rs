//! Dask-like task-parallel engine.
//!
//! The paper uses Dask as a lightweight task launcher (the MASS data
//! producers run "8 producer processes in Dask" per node, §6.3) and as
//! one of the Compute-Unit execution backends (§4.2).  This engine is
//! the equivalent: a futures-based worker pool spanning the pilot's
//! nodes, with runtime `add_workers` for pilot extension.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::cluster::{Machine, NodeId};
use crate::error::{Error, Result};

type Task = Box<dyn FnOnce(NodeId) + Send + 'static>;

struct Queue {
    tasks: Mutex<VecDeque<Task>>,
    available: Condvar,
    stopped: AtomicBool,
    /// Nodes being drained (pilot shrink): their workers exit before
    /// picking up new tasks.
    draining: Mutex<std::collections::HashSet<NodeId>>,
}

/// Future for a submitted task.
pub struct TaskFuture<R> {
    rx: mpsc::Receiver<std::thread::Result<R>>,
}

impl<R> TaskFuture<R> {
    /// Block until the task finishes.
    pub fn wait(self) -> Result<R> {
        match self.rx.recv() {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(_)) => Err(Error::Engine("task panicked".into())),
            Err(_) => Err(Error::Engine("task dropped (engine stopped?)".into())),
        }
    }

    /// Non-blocking check.
    pub fn try_wait(&self) -> Option<Result<R>> {
        match self.rx.try_recv() {
            Ok(Ok(r)) => Some(Ok(r)),
            Ok(Err(_)) => Some(Err(Error::Engine("task panicked".into()))),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(Error::Engine("task dropped".into())))
            }
        }
    }
}

struct EngineInner {
    queue: Arc<Queue>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    worker_count: AtomicUsize,
    workers_per_node: usize,
    nodes: Mutex<Vec<NodeId>>,
}

/// Dask-like engine: `workers_per_node` worker threads per pilot node.
#[derive(Clone)]
pub struct TaskEngine {
    #[allow(dead_code)]
    machine: Machine,
    inner: Arc<EngineInner>,
}

impl std::fmt::Debug for TaskEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TaskEngine")
            .field("workers", &self.worker_count())
            .field("nodes", &self.nodes().len())
            .finish()
    }
}

impl TaskEngine {
    pub fn new(machine: Machine, nodes: Vec<NodeId>, workers_per_node: usize) -> Self {
        let engine = TaskEngine {
            machine,
            inner: Arc::new(EngineInner {
                queue: Arc::new(Queue {
                    tasks: Mutex::new(VecDeque::new()),
                    available: Condvar::new(),
                    stopped: AtomicBool::new(false),
                    draining: Mutex::new(std::collections::HashSet::new()),
                }),
                workers: Mutex::new(Vec::new()),
                worker_count: AtomicUsize::new(0),
                workers_per_node: workers_per_node.max(1),
                nodes: Mutex::new(Vec::new()),
            }),
        };
        engine.add_workers(nodes);
        engine
    }

    pub fn nodes(&self) -> Vec<NodeId> {
        self.inner.nodes.lock().unwrap().clone()
    }

    pub fn worker_count(&self) -> usize {
        self.inner.worker_count.load(Ordering::Relaxed)
    }

    /// Extend the engine onto additional nodes at runtime.
    pub fn add_workers(&self, nodes: Vec<NodeId>) {
        let mut handles = self.inner.workers.lock().unwrap();
        for node in nodes {
            self.inner.queue.draining.lock().unwrap().remove(&node);
            self.inner.nodes.lock().unwrap().push(node);
            for _ in 0..self.inner.workers_per_node {
                let queue = self.inner.queue.clone();
                let count_ref = self.inner.clone();
                // Count the worker immediately (synchronously) so that
                // worker_count reflects add_workers on return; decrement
                // when the worker drains out.
                count_ref.worker_count.fetch_add(1, Ordering::Relaxed);
                handles.push(std::thread::spawn(move || {
                    worker_loop(queue, node);
                    count_ref.worker_count.fetch_sub(1, Ordering::Relaxed);
                }));
            }
        }
    }

    /// Drain workers on `nodes` (pilot shrink): they finish their
    /// current task and exit; in-flight tasks are unaffected.
    pub fn remove_workers(&self, nodes: &[NodeId]) {
        {
            let mut draining = self.inner.queue.draining.lock().unwrap();
            draining.extend(nodes.iter().copied());
        }
        self.inner
            .nodes
            .lock()
            .unwrap()
            .retain(|n| !nodes.contains(n));
        self.inner.queue.available.notify_all();
    }

    /// Submit a closure; it runs on some worker, receiving the worker's
    /// node id (for data-plane cost accounting).
    pub fn submit<R, F>(&self, f: F) -> Result<TaskFuture<R>>
    where
        R: Send + 'static,
        F: FnOnce(NodeId) -> R + Send + 'static,
    {
        if self.inner.queue.stopped.load(Ordering::Relaxed) {
            return Err(Error::Engine("engine stopped".into()));
        }
        let (tx, rx) = mpsc::channel();
        let task: Task = Box::new(move |node| {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(node)));
            let _ = tx.send(result);
        });
        self.inner.queue.tasks.lock().unwrap().push_back(task);
        self.inner.queue.available.notify_one();
        Ok(TaskFuture { rx })
    }

    /// Pending (not yet started) task count.
    pub fn backlog(&self) -> usize {
        self.inner.queue.tasks.lock().unwrap().len()
    }

    /// Stop all workers (pending tasks are dropped).
    pub fn stop(&self) {
        self.inner.queue.stopped.store(true, Ordering::Relaxed);
        self.inner.queue.available.notify_all();
        let mut workers = self.inner.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(queue: Arc<Queue>, node: NodeId) {
    loop {
        let task = {
            let mut tasks = queue.tasks.lock().unwrap();
            loop {
                if queue.stopped.load(Ordering::Relaxed)
                    || queue.draining.lock().unwrap().contains(&node)
                {
                    return;
                }
                if let Some(t) = tasks.pop_front() {
                    break t;
                }
                tasks = queue.available.wait(tasks).unwrap();
            }
        };
        task(node);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(nodes: usize, wpn: usize) -> TaskEngine {
        let m = Machine::unthrottled(nodes);
        TaskEngine::new(m, (0..nodes).collect(), wpn)
    }

    #[test]
    fn submit_and_wait() {
        let e = engine(1, 2);
        let f = e.submit(|_| 21 * 2).unwrap();
        assert_eq!(f.wait().unwrap(), 42);
        e.stop();
    }

    #[test]
    fn many_tasks_all_complete() {
        let e = engine(2, 2);
        let futures: Vec<_> = (0..50)
            .map(|i| e.submit(move |_| i * i).unwrap())
            .collect();
        let mut results: Vec<i32> = futures.into_iter().map(|f| f.wait().unwrap()).collect();
        results.sort();
        assert_eq!(results, (0..50).map(|i| i * i).collect::<Vec<_>>());
        e.stop();
    }

    #[test]
    fn tasks_receive_node_ids_from_pool() {
        let e = engine(3, 1);
        let mut nodes: Vec<NodeId> = (0..30)
            .map(|_| {
                e.submit(|n| {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    n
                })
                .unwrap()
            })
            .map(|f| f.wait().unwrap())
            .collect();
        nodes.sort();
        nodes.dedup();
        assert!(!nodes.is_empty());
        for n in nodes {
            assert!(n < 3);
        }
        e.stop();
    }

    #[test]
    fn panicking_task_reports_error_and_pool_survives() {
        let e = engine(1, 1);
        let f = e.submit::<(), _>(|_| panic!("boom")).unwrap();
        assert!(f.wait().is_err());
        let f2 = e.submit(|_| 7).unwrap();
        assert_eq!(f2.wait().unwrap(), 7);
        e.stop();
    }

    #[test]
    fn add_workers_extends_pool() {
        let e = engine(1, 1);
        assert_eq!(e.worker_count(), 1);
        e.add_workers(vec![0]);
        assert_eq!(e.worker_count(), 2);
        e.stop();
    }

    #[test]
    fn submit_after_stop_errors() {
        let e = engine(1, 1);
        e.stop();
        assert!(e.submit(|_| ()).is_err());
    }
}
