//! Micro-benchmark harness (criterion is not in the offline dependency
//! set, so `cargo bench` targets use this instead).
//!
//! Measures wall-clock per iteration with warmup, reports mean / p50 /
//! p95 and throughput, and supports `--quick` (fewer iterations) and
//! name filters passed by `cargo bench <filter>`.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_secs(self.mean_secs),
            fmt_secs(self.p50_secs),
            fmt_secs(self.p95_secs),
        );
    }
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Bench runner configured from `cargo bench` CLI args.
pub struct Bench {
    filter: Option<String>,
    quick: bool,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::from_args()
    }
}

impl Bench {
    /// Parse `cargo bench`-style args: optional name filter, `--quick`,
    /// and ignore harness flags like `--bench`.  `--test` (what
    /// `cargo bench -- --test` passes for libtest's smoke mode) maps to
    /// quick mode, so CI can compile + one-shot every bench cheaply.
    pub fn from_args() -> Self {
        let mut filter = None;
        let mut quick = std::env::var_os("PILOT_BENCH_QUICK").is_some();
        for a in std::env::args().skip(1) {
            match a.as_str() {
                "--quick" | "--test" => quick = true,
                "--bench" | "--exact" => {}
                s if s.starts_with("--") => {}
                s => filter = Some(s.to_string()),
            }
        }
        Bench {
            filter,
            quick,
            results: Vec::new(),
        }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }

    /// Time `f` for `iters` iterations (after `warmup` iterations).
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> Option<Measurement> {
        if !self.enabled(name) {
            return None;
        }
        let iters = if self.quick { iters.div_ceil(5) } else { iters }.max(3);
        let warmup = (iters / 5).max(1);
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_secs: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_secs: samples[samples.len() / 2],
            p95_secs: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        };
        m.print();
        self.results.push(m.clone());
        Some(m)
    }

    /// Run a whole-workload measurement once and report custom metrics
    /// (used by the figure harnesses where "one iteration" is a full
    /// simulated experiment).
    pub fn run_once<F: FnOnce() -> Vec<(String, f64)>>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let t0 = Instant::now();
        let metrics = f();
        let secs = t0.elapsed().as_secs_f64();
        print!("{:<44} {:>10}  ", name, fmt_secs(secs));
        for (k, v) in &metrics {
            print!("{k}={v:.3}  ");
        }
        println!();
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }
}
