//! Micro-benchmark harness (criterion is not in the offline dependency
//! set, so `cargo bench` targets use this instead).
//!
//! Measures wall-clock per iteration with warmup, reports mean / p50 /
//! p95 and throughput, and supports `--quick` (fewer iterations), name
//! filters passed by `cargo bench <filter>`, and machine-readable
//! output: `--json` prints a JSON document of every measurement on
//! [`Bench::emit`] (suppressing the human-readable lines), and
//! `--baseline=FILE` embeds a previously-committed JSON document under
//! a `"baseline"` key — that is how `BENCH_pr*.json` files carry the
//! perf trajectory forward (each PR's run embeds its predecessor).

use std::time::Instant;

use super::json::Json;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} {:>6} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_secs(self.mean_secs),
            fmt_secs(self.p50_secs),
            fmt_secs(self.p95_secs),
        );
    }

    fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_secs", self.mean_secs)
            .set("p50_secs", self.p50_secs)
            .set("p95_secs", self.p95_secs)
    }
}

/// One whole-workload measurement with custom metrics (see
/// [`Bench::run_once`]).
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub secs: f64,
    pub metrics: Vec<(String, f64)>,
}

pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Bench runner configured from `cargo bench` CLI args.
pub struct Bench {
    filter: Option<String>,
    quick: bool,
    json: bool,
    baseline: Option<String>,
    results: Vec<Measurement>,
    workloads: Vec<Workload>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::from_args()
    }
}

impl Bench {
    /// Parse `cargo bench`-style args: optional name filter, `--quick`,
    /// `--json`, `--baseline=FILE`, and ignore harness flags like
    /// `--bench`.  `--test` (what `cargo bench -- --test` passes for
    /// libtest's smoke mode) maps to quick mode, so CI can compile +
    /// one-shot every bench cheaply.
    pub fn from_args() -> Self {
        let mut filter = None;
        let mut quick = std::env::var_os("PILOT_BENCH_QUICK").is_some();
        let mut json = false;
        let mut baseline = None;
        for a in std::env::args().skip(1) {
            match a.as_str() {
                "--quick" | "--test" => quick = true,
                "--json" => json = true,
                "--bench" | "--exact" => {}
                s if s.starts_with("--baseline=") => {
                    baseline = Some(s["--baseline=".len()..].to_string());
                }
                s if s.starts_with("--") => {}
                s => filter = Some(s.to_string()),
            }
        }
        Bench {
            filter,
            quick,
            json,
            baseline,
            results: Vec::new(),
            workloads: Vec::new(),
        }
    }

    pub fn quick(&self) -> bool {
        self.quick
    }

    /// Whether `--json` was requested (human-readable lines are
    /// suppressed; callers should invoke [`Bench::emit`] at the end).
    pub fn json(&self) -> bool {
        self.json
    }

    fn enabled(&self, name: &str) -> bool {
        self.filter.as_deref().map_or(true, |f| name.contains(f))
    }

    /// Time `f` for `iters` iterations (after `warmup` iterations).
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> Option<Measurement> {
        if !self.enabled(name) {
            return None;
        }
        let iters = if self.quick { iters.div_ceil(5) } else { iters }.max(3);
        let warmup = (iters / 5).max(1);
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean_secs: samples.iter().sum::<f64>() / samples.len() as f64,
            p50_secs: samples[samples.len() / 2],
            p95_secs: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
        };
        if !self.json {
            m.print();
        }
        self.results.push(m.clone());
        Some(m)
    }

    /// Run a whole-workload measurement once and report custom metrics
    /// (used by the figure harnesses — and the contention benches —
    /// where "one iteration" is a full experiment).
    pub fn run_once<F: FnOnce() -> Vec<(String, f64)>>(&mut self, name: &str, f: F) {
        if !self.enabled(name) {
            return;
        }
        let t0 = Instant::now();
        let metrics = f();
        let secs = t0.elapsed().as_secs_f64();
        if !self.json {
            print!("{:<44} {:>10}  ", name, fmt_secs(secs));
            for (k, v) in &metrics {
                print!("{k}={v:.3}  ");
            }
            println!();
        }
        self.workloads.push(Workload {
            name: name.to_string(),
            secs,
            metrics,
        });
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    pub fn workloads(&self) -> &[Workload] {
        &self.workloads
    }

    /// The full run as a JSON document: measurements, workloads, and —
    /// when `--baseline=FILE` parsed — that file embedded verbatim
    /// under `"baseline"` (so one document carries the perf trajectory).
    pub fn to_json(&self, bench_name: &str) -> Json {
        let results = Json::Arr(self.results.iter().map(|m| m.to_json()).collect());
        let workloads = Json::Arr(
            self.workloads
                .iter()
                .map(|w| {
                    let mut metrics = Json::obj();
                    for (k, v) in &w.metrics {
                        metrics = metrics.set(k, *v);
                    }
                    Json::obj()
                        .set("name", w.name.as_str())
                        .set("secs", w.secs)
                        .set("metrics", metrics)
                })
                .collect(),
        );
        let mut doc = Json::obj()
            .set("bench", bench_name)
            .set("quick", self.quick)
            .set("results", results)
            .set("workloads", workloads);
        if let Some(path) = &self.baseline {
            match std::fs::read_to_string(path).map_err(|e| e.to_string()) {
                Ok(text) => match Json::parse(&text) {
                    Ok(parsed) => doc = doc.set("baseline", parsed),
                    Err(e) => {
                        doc = doc.set("baseline_error", format!("parse {path}: {e}").as_str())
                    }
                },
                Err(e) => doc = doc.set("baseline_error", format!("read {path}: {e}").as_str()),
            }
        }
        doc
    }

    /// Print the JSON document to stdout when `--json` was requested;
    /// no-op otherwise.  Call once at the end of a bench main.
    pub fn emit(&self, bench_name: &str) {
        if self.json {
            println!("{}", self.to_json(bench_name));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }

    #[test]
    fn json_doc_carries_results_and_workloads() {
        let mut bench = Bench {
            filter: None,
            quick: true,
            json: true,
            baseline: None,
            results: Vec::new(),
            workloads: Vec::new(),
        };
        bench.run("unit/spin", 5, || {
            std::hint::black_box(1 + 1);
        });
        bench.run_once("unit/workload", || vec![("msgs_per_sec".to_string(), 42.0)]);
        let doc = bench.to_json("unit");
        assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("unit"));
        let results = doc.get("results").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get("name").and_then(|j| j.as_str()),
            Some("unit/spin")
        );
        assert!(results[0].get("mean_secs").and_then(|j| j.as_f64()).unwrap() >= 0.0);
        let workloads = doc.get("workloads").and_then(|j| j.as_arr()).unwrap();
        assert_eq!(workloads.len(), 1);
        let metrics = workloads[0].get("metrics").unwrap();
        assert_eq!(
            metrics.get("msgs_per_sec").and_then(|j| j.as_f64()),
            Some(42.0)
        );
        // Round-trips through the parser (what bench-gate consumes).
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }
}
