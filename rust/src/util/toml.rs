//! Minimal TOML parser emitting [`Json`] values.
//!
//! The offline dependency set has no `toml` crate, and the app-spec
//! loader already has a strict, well-tested validation pipeline over
//! [`Json`] (unknown-key rejection, typed accessors).  So instead of a
//! second document model, this parser maps a practical TOML subset onto
//! the existing `Json` tree — `StreamingAppBuilder::from_toml` is then
//! literally `toml::parse` followed by `from_json`, and both formats
//! share every validation rule and error message.
//!
//! Supported subset (everything the spec format needs, and the common
//! shapes around it):
//!
//! * `[table]` and `[[array-of-tables]]` headers, with dotted paths;
//!   a header path descends into the *last* element of an
//!   array-of-tables (so `[stages.autoscale]` after `[[stages]]`
//!   attaches to the most recent stage, per the TOML spec);
//! * dotted keys (`broker.nodes = 2`), basic (`"..."`, with escapes)
//!   and literal (`'...'`) strings, integers (underscore separators),
//!   floats, booleans, single- or multi-line arrays, inline tables;
//! * `#` comments and blank lines.
//!
//! Not supported (rejected with a parse error): dates/times, multi-line
//! strings, and re-opening a table already defined — none appear in
//! spec files.

use std::collections::btree_map::Entry;

use crate::error::{Error, Result};

use super::json::Json;

/// Parse a TOML document into a [`Json::Obj`] tree.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let mut root = Json::obj();
    // Path of the table subsequent key/value lines land in.
    let mut table: Vec<String> = Vec::new();
    loop {
        p.skip_trivia();
        match p.peek() {
            None => break,
            Some(b'[') => {
                p.pos += 1;
                let array = p.peek() == Some(b'[');
                if array {
                    p.pos += 1;
                }
                let path = p.key_path()?;
                p.expect(b']')?;
                if array {
                    p.expect(b']')?;
                }
                p.end_of_line()?;
                if array {
                    push_array_table(&mut root, &path, &p)?;
                } else {
                    let node = navigate(&mut root, &path, &p)?;
                    if !matches!(node, Json::Obj(_)) {
                        return Err(p.err(&format!(
                            "[{}] redefines a non-table value",
                            path.join(".")
                        )));
                    }
                }
                table = path;
            }
            Some(_) => {
                let path = p.key_path()?;
                p.expect(b'=')?;
                let value = p.value()?;
                p.end_of_line()?;
                let (key, parents) = path.split_last().expect("key path is never empty");
                let mut full = table.clone();
                full.extend(parents.iter().cloned());
                let node = navigate(&mut root, &full, &p)?;
                let Json::Obj(map) = node else {
                    return Err(p.err(&format!(
                        "key '{}' assigned inside a non-table value",
                        path.join(".")
                    )));
                };
                match map.entry(key.clone()) {
                    Entry::Vacant(e) => {
                        e.insert(value);
                    }
                    Entry::Occupied(_) => {
                        return Err(p.err(&format!("duplicate key '{key}'")));
                    }
                }
            }
        }
    }
    Ok(root)
}

/// Walk (and create) tables along `path`, descending into the last
/// element of any array-of-tables encountered.
fn navigate<'j>(root: &'j mut Json, path: &[String], p: &Parser) -> Result<&'j mut Json> {
    let mut cur = root;
    for seg in path {
        let Json::Obj(map) = cur else {
            return Err(p.err(&format!("'{seg}' traverses a non-table value")));
        };
        let entry = map.entry(seg.clone()).or_insert_with(Json::obj);
        cur = match entry {
            Json::Arr(items) => items
                .last_mut()
                .ok_or_else(|| p.err(&format!("'{seg}' is an empty array of tables")))?,
            other => other,
        };
    }
    Ok(cur)
}

/// `[[path]]`: append a fresh table to the array at `path`.
fn push_array_table(root: &mut Json, path: &[String], p: &Parser) -> Result<()> {
    let (last, parents) = path.split_last().expect("header path is never empty");
    let node = navigate(root, parents, p)?;
    let Json::Obj(map) = node else {
        return Err(p.err(&format!("[[{}]] inside a non-table value", path.join("."))));
    };
    let entry = map
        .entry(last.clone())
        .or_insert_with(|| Json::Arr(Vec::new()));
    let Json::Arr(items) = entry else {
        return Err(p.err(&format!("[[{last}]] redefines a non-array value")));
    };
    items.push(Json::obj());
    Ok(())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let line = self.bytes[..self.pos.min(self.bytes.len())]
            .iter()
            .filter(|b| **b == b'\n')
            .count()
            + 1;
        Error::Config(format!("toml parse error at line {line}: {msg}"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    /// Skip spaces/tabs (not newlines) and a trailing comment.
    fn skip_inline_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' => self.pos += 1,
                b'#' => {
                    while !matches!(self.peek(), None | Some(b'\n')) {
                        self.pos += 1;
                    }
                }
                _ => break,
            }
        }
    }

    /// Skip whitespace, newlines and comments between top-level items.
    fn skip_trivia(&mut self) {
        loop {
            self.skip_inline_ws();
            if self.peek() == Some(b'\n') || self.peek() == Some(b'\r') {
                self.pos += 1;
            } else {
                return;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        self.skip_inline_ws();
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// The line must hold nothing further but whitespace/comment.
    fn end_of_line(&mut self) -> Result<()> {
        self.skip_inline_ws();
        match self.peek() {
            None => Ok(()),
            Some(b'\n') | Some(b'\r') => Ok(()),
            Some(b) => Err(self.err(&format!("unexpected '{}' after value", b as char))),
        }
    }

    /// A dotted key path: bare or quoted segments separated by '.'.
    fn key_path(&mut self) -> Result<Vec<String>> {
        let mut path = Vec::new();
        loop {
            self.skip_inline_ws();
            path.push(self.key_segment()?);
            self.skip_inline_ws();
            if self.peek() == Some(b'.') {
                self.pos += 1;
            } else {
                return Ok(path);
            }
        }
    }

    fn key_segment(&mut self) -> Result<String> {
        match self.peek() {
            Some(b'"') => self.basic_string(),
            Some(b'\'') => self.literal_string(),
            Some(b) if b.is_ascii_alphanumeric() || b == b'_' || b == b'-' => {
                let start = self.pos;
                while matches!(self.peek(),
                    Some(b) if b.is_ascii_alphanumeric() || b == b'_' || b == b'-')
                {
                    self.pos += 1;
                }
                Ok(std::str::from_utf8(&self.bytes[start..self.pos])
                    .expect("bare key bytes are ascii")
                    .to_string())
            }
            _ => Err(self.err("expected a key")),
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_inline_ws();
        match self.peek() {
            Some(b'"') => Ok(Json::Str(self.basic_string()?)),
            Some(b'\'') => Ok(Json::Str(self.literal_string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.inline_table(),
            Some(b't') | Some(b'f') => self.boolean(),
            Some(b) if b == b'+' || b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected '{}' in value", b as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn boolean(&mut self) -> Result<Json> {
        for (word, value) in [("true", true), ("false", false)] {
            if self.bytes[self.pos..].starts_with(word.as_bytes()) {
                self.pos += word.len();
                return Ok(Json::Bool(value));
            }
        }
        Err(self.err("expected 'true' or 'false'"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if matches!(self.peek(), Some(b'+') | Some(b'-')) {
            self.pos += 1;
        }
        let digits = |p: &mut Self| {
            while matches!(p.peek(), Some(b) if b.is_ascii_digit() || b == b'_') {
                p.pos += 1;
            }
        };
        digits(self);
        if self.peek() == Some(b'.') {
            self.pos += 1;
            digits(self);
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            digits(self);
        }
        let text: String = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?
            .chars()
            .filter(|c| *c != '_')
            .collect();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("invalid number '{text}'")))
    }

    /// `[v, v, ...]` — newlines, comments and a trailing comma allowed.
    fn array(&mut self) -> Result<Json> {
        self.pos += 1; // consume '['
        let mut out = Vec::new();
        loop {
            self.skip_trivia();
            if self.peek() == Some(b']') {
                self.pos += 1;
                return Ok(Json::Arr(out));
            }
            out.push(self.value()?);
            self.skip_trivia();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {}
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    /// `{ k = v, ... }` inline table.
    fn inline_table(&mut self) -> Result<Json> {
        self.pos += 1; // consume '{'
        let mut obj = Json::obj();
        self.skip_inline_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(obj);
        }
        loop {
            let path = self.key_path()?;
            self.expect(b'=')?;
            let value = self.value()?;
            let (key, parents) = path.split_last().expect("key path is never empty");
            let node = navigate(&mut obj, parents, self)?;
            let Json::Obj(map) = node else {
                return Err(self.err("inline-table key traverses a non-table value"));
            };
            if map.insert(key.clone(), value).is_some() {
                return Err(self.err(&format!("duplicate key '{key}'")));
            }
            self.skip_inline_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(obj),
                _ => return Err(self.err("expected ',' or '}' in inline table")),
            }
        }
    }

    fn basic_string(&mut self) -> Result<String> {
        self.pos += 1; // consume '"'
        let mut out = String::new();
        loop {
            match self.bump() {
                None | Some(b'\n') => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn literal_string(&mut self) -> Result<String> {
        self.pos += 1; // consume '\''
        let start = self.pos;
        loop {
            match self.bump() {
                None | Some(b'\n') => return Err(self.err("unterminated string")),
                Some(b'\'') => {
                    return Ok(std::str::from_utf8(&self.bytes[start..self.pos - 1])
                        .map_err(|_| self.err("invalid utf-8"))?
                        .to_string());
                }
                Some(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_tables_and_dotted_keys() {
        let doc = parse(
            r#"
            # top-level scalars
            machine_nodes = 6
            ratio = 2.5
            on = true
            name = "points stream"
            raw = 'C:\no\escapes'
            big = 1_000_000

            [broker]
            nodes = 2
            limits.max_mb = 64
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("machine_nodes").unwrap().as_usize(), Some(6));
        assert_eq!(doc.get("ratio").unwrap().as_f64(), Some(2.5));
        assert_eq!(doc.get("on").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("name").unwrap().as_str(), Some("points stream"));
        assert_eq!(doc.get("raw").unwrap().as_str(), Some(r"C:\no\escapes"));
        assert_eq!(doc.get("big").unwrap().as_usize(), Some(1_000_000));
        let broker = doc.get("broker").unwrap();
        assert_eq!(broker.get("nodes").unwrap().as_usize(), Some(2));
        assert_eq!(
            broker.get("limits").unwrap().get("max_mb").unwrap().as_usize(),
            Some(64)
        );
    }

    #[test]
    fn array_of_tables_and_subtables_of_last_element() {
        let doc = parse(
            r#"
            [[stages]]
            name = "a"

            [stages.autoscale]
            policy = "threshold"

            [[stages]]
            name = "b"
            "#,
        )
        .unwrap();
        let stages = doc.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].get("name").unwrap().as_str(), Some("a"));
        // The sub-table landed on the element open at that point.
        assert_eq!(
            stages[0].get("autoscale").unwrap().get("policy").unwrap().as_str(),
            Some("threshold")
        );
        assert!(stages[1].get("autoscale").is_none());
    }

    #[test]
    fn arrays_and_inline_tables() {
        let doc = parse(
            r#"
            ports = [1, 2, 3,]
            multi = [
                "a",  # with a comment
                "b",
            ]
            replication = { factor = 2, ack_mode = "quorum" }
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("ports").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("multi").unwrap().as_arr().unwrap()[1].as_str(), Some("b"));
        let rep = doc.get("replication").unwrap();
        assert_eq!(rep.get("factor").unwrap().as_usize(), Some(2));
        assert_eq!(rep.get("ack_mode").unwrap().as_str(), Some("quorum"));
    }

    #[test]
    fn emits_the_same_tree_as_the_json_parser() {
        let from_toml = parse(
            r#"
            machine_nodes = 4
            [broker]
            nodes = 1
            [[broker.topics]]
            name = "t"
            partitions = 2
            "#,
        )
        .unwrap();
        let from_json = Json::parse(
            r#"{"machine_nodes": 4,
                "broker": {"nodes": 1, "topics": [{"name": "t", "partitions": 2}]}}"#,
        )
        .unwrap();
        assert_eq!(from_toml, from_json);
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "x =",                      // missing value
            "x = 1 y = 2",              // junk after value
            "[table",                   // unterminated header
            "x = \"unterminated",       // unterminated string
            "x = 1\nx = 2",             // duplicate key
            "[[a]]\n[a]\nx = nope",     // bare word value
            "x = 1979-05-27",           // dates unsupported (parses as junk)
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
        let err = parse("a = 1\nb = ?").unwrap_err().to_string();
        assert!(err.contains("line 2"), "line numbers in errors: {err}");
    }

    #[test]
    fn duplicate_keys_and_redefined_tables_error() {
        assert!(parse("[a]\nx = 1\n[a.x]\ny = 2").is_err(), "scalar redefined as table");
        assert!(parse("a = 1\n[[a]]").is_err(), "scalar redefined as array of tables");
    }
}
