//! Piecewise-constant rate schedules.
//!
//! Both planes need time-varying offered load: the MASS producers pace
//! real sends against a schedule (`examples/dynamic_scaling.rs` drives a
//! burst through the autoscaler), and the simulation plane's elastic
//! harness replays the same shape in virtual time.  A schedule is a list
//! of `(duration, rate)` segments; after the last segment the final rate
//! holds forever.

/// A piecewise-constant message-rate schedule (messages/second).
#[derive(Debug, Clone, PartialEq)]
pub struct RateSchedule {
    /// `(duration_secs, msgs_per_sec)` segments, played in order.
    segments: Vec<(f64, f64)>,
}

impl RateSchedule {
    /// A flat schedule at `rate` msgs/sec.
    pub fn constant(rate: f64) -> Self {
        RateSchedule {
            segments: vec![(f64::INFINITY, rate.max(0.0))],
        }
    }

    /// Start a schedule with one segment of `secs` at `rate`.
    pub fn starting_at(secs: f64, rate: f64) -> Self {
        RateSchedule {
            segments: vec![(secs.max(0.0), rate.max(0.0))],
        }
    }

    /// Append a segment of `secs` at `rate`.
    pub fn then(mut self, secs: f64, rate: f64) -> Self {
        self.segments.push((secs.max(0.0), rate.max(0.0)));
        self
    }

    /// Convenience burst shape: `base` rate, except `burst` rate during
    /// `[burst_start, burst_start + burst_secs)`.
    pub fn bursty(base: f64, burst: f64, burst_start: f64, burst_secs: f64) -> Self {
        Self::starting_at(burst_start, base)
            .then(burst_secs, burst)
            .then(f64::INFINITY, base)
    }

    /// Offered rate at time `t` (the last segment's rate holds forever).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut start = 0.0;
        for (dur, rate) in &self.segments {
            if t < start + dur {
                return *rate;
            }
            start += dur;
        }
        self.segments.last().map(|(_, r)| *r).unwrap_or(0.0)
    }

    /// Cumulative messages offered by time `t` (the integral of the
    /// rate).
    pub fn count_until(&self, t: f64) -> f64 {
        let mut start = 0.0;
        let mut count = 0.0;
        for (dur, rate) in &self.segments {
            let end = start + dur;
            if t <= end {
                return count + (t - start).max(0.0) * rate;
            }
            count += dur * rate;
            start = end;
        }
        let trailing = self.segments.last().map(|(_, r)| *r).unwrap_or(0.0);
        count + (t - start).max(0.0) * trailing
    }

    /// Earliest time at which `n` messages have been offered — the due
    /// time producers pace against.  Returns `f64::INFINITY` when the
    /// schedule never reaches `n` (e.g. a trailing zero rate).
    pub fn time_for_count(&self, n: f64) -> f64 {
        if n <= 0.0 {
            return 0.0;
        }
        let mut start = 0.0;
        let mut count = 0.0;
        for (dur, rate) in &self.segments {
            let seg_count = dur * rate;
            if count + seg_count >= n {
                return start + (n - count) / rate;
            }
            count += seg_count;
            start += dur;
        }
        let trailing = self.segments.last().map(|(_, r)| *r).unwrap_or(0.0);
        if trailing > 0.0 {
            start + (n - count) / trailing
        } else {
            f64::INFINITY
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_schedule_paces_evenly() {
        let s = RateSchedule::constant(10.0);
        assert_eq!(s.rate_at(0.0), 10.0);
        assert_eq!(s.rate_at(1e6), 10.0);
        assert!((s.count_until(2.5) - 25.0).abs() < 1e-9);
        assert!((s.time_for_count(5.0) - 0.5).abs() < 1e-9);
        assert_eq!(s.time_for_count(0.0), 0.0);
    }

    #[test]
    fn bursty_schedule_integrates_piecewise() {
        // 2/s for 1 s, 20/s for 1 s, back to 2/s.
        let s = RateSchedule::bursty(2.0, 20.0, 1.0, 1.0);
        assert_eq!(s.rate_at(0.5), 2.0);
        assert_eq!(s.rate_at(1.5), 20.0);
        assert_eq!(s.rate_at(3.0), 2.0);
        assert!((s.count_until(1.0) - 2.0).abs() < 1e-9);
        assert!((s.count_until(2.0) - 22.0).abs() < 1e-9);
        assert!((s.count_until(3.0) - 24.0).abs() < 1e-9);
        // Inverse agrees with the integral.
        for n in [1.0, 2.0, 10.0, 22.0, 23.5] {
            let t = s.time_for_count(n);
            assert!((s.count_until(t) - n).abs() < 1e-6, "n={n} t={t}");
        }
    }

    #[test]
    fn zero_tail_never_reaches_count() {
        let s = RateSchedule::starting_at(1.0, 4.0).then(f64::INFINITY, 0.0);
        assert_eq!(s.time_for_count(4.0), 1.0);
        assert_eq!(s.time_for_count(5.0), f64::INFINITY);
    }
}
