//! Hand-rolled arc-swap: epoch-published shared snapshots.
//!
//! The offline dependency set has no `arc-swap` crate, so the broker's
//! lock-split read paths use this minimal equivalent: a cell holding an
//! `Arc<T>` that writers replace wholesale and readers clone out.  The
//! load path takes an internal mutex only for the nanoseconds a
//! refcount bump needs — crucially, readers never hold any lock while
//! *using* the snapshot, so a slow reader (or one parked on a condvar)
//! cannot block writers, and writers publishing a new snapshot cannot
//! invalidate data a reader is still traversing (the old `Arc` stays
//! alive until its last holder drops).
//!
//! This is the primitive behind the broker's zero-copy data plane
//! (`broker::log`): segment lists are published here on roll/retention,
//! while per-record appends touch only atomics.

use std::sync::{Arc, Mutex};

/// A swappable `Arc<T>`: writers `store` a new snapshot, readers `load`
/// a clone of the current one.
pub struct ArcCell<T> {
    inner: Mutex<Arc<T>>,
}

impl<T> ArcCell<T> {
    pub fn new(value: Arc<T>) -> Self {
        ArcCell {
            inner: Mutex::new(value),
        }
    }

    /// Clone out the current snapshot.  The lock is held only for the
    /// refcount bump; the returned `Arc` is usable lock-free and stays
    /// valid even if a writer swaps in a newer snapshot immediately.
    pub fn load(&self) -> Arc<T> {
        self.inner.lock().unwrap().clone()
    }

    /// Publish a new snapshot.  Readers that already loaded the old one
    /// keep it alive; new loads observe `value`.
    pub fn store(&self, value: Arc<T>) {
        *self.inner.lock().unwrap() = value;
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for ArcCell<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ArcCell({:?})", self.load())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_returns_stored_snapshot() {
        let cell = ArcCell::new(Arc::new(vec![1, 2, 3]));
        assert_eq!(*cell.load(), vec![1, 2, 3]);
        cell.store(Arc::new(vec![4]));
        assert_eq!(*cell.load(), vec![4]);
    }

    #[test]
    fn old_snapshot_outlives_swap() {
        let cell = ArcCell::new(Arc::new(String::from("old")));
        let held = cell.load();
        cell.store(Arc::new(String::from("new")));
        assert_eq!(*held, "old", "reader's snapshot survives the swap");
        assert_eq!(*cell.load(), "new");
    }

    #[test]
    fn concurrent_load_store() {
        let cell = Arc::new(ArcCell::new(Arc::new(0u64)));
        let writer = {
            let cell = cell.clone();
            std::thread::spawn(move || {
                for i in 1..=1000u64 {
                    cell.store(Arc::new(i));
                }
            })
        };
        let mut last = 0;
        while last < 1000 {
            let v = *cell.load();
            assert!(v >= last, "snapshots move forward: {v} < {last}");
            last = last.max(v);
        }
        writer.join().unwrap();
    }
}
