//! Minimal JSON parser/serializer.
//!
//! The build environment is fully offline and `serde`/`serde_json` are
//! not in the vendored dependency set, so the coordinator carries its
//! own small JSON implementation (DESIGN.md §Substitutions).  It covers
//! the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) — enough for `artifacts/manifest.json`,
//! experiment configs and result records.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path-style message.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing JSON key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---------------- builders ----------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), value.into());
        }
        self
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}

impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {}", self.pos, msg))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(self.err(&format!("unexpected '{}'", b as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let d =
                                    self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                                low = low * 16
                                    + (d as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex digit"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
        assert!(j.get("missing").is_none());
        assert!(j.req("missing").is_err());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ é 😀");
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"x", "{\"a\"}", "01x", "nul", "[1] extra"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null],"s":"a\"b\nc","n":-3}"#;
        let j = Json::parse(src).unwrap();
        let text = j.to_string();
        let j2 = Json::parse(&text).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn builder_api() {
        let j = Json::obj().set("x", 1.0).set("y", "z").set("b", true);
        assert_eq!(j.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("y").unwrap().as_str(), Some("z"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_real_manifest() {
        // Shape of artifacts/manifest.json.
        let text = r#"{
          "kmeans": {"n_points": 5000, "dim": 3, "k": 10, "decay": 0.9, "block": 500},
          "artifacts": {
            "gridrec": {"file": "gridrec.hlo.txt",
              "inputs": [{"shape": [96, 192], "dtype": "float32"}],
              "outputs": [{"shape": [128, 128], "dtype": "float32"}]}
          }
        }"#;
        let j = Json::parse(text).unwrap();
        let a = j.req("artifacts").unwrap().req("gridrec").unwrap();
        assert_eq!(a.req("file").unwrap().as_str(), Some("gridrec.hlo.txt"));
        let shape = a.req("inputs").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(96));
    }
}
