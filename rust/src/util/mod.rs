//! Self-built utility substrates (offline environment: no serde, rand,
//! clap or criterion in the vendored dependency set — see DESIGN.md
//! §Substitutions).

pub mod arcswap;
pub mod bench;
pub mod circuit;
pub mod json;
pub mod rng;
pub mod schedule;
pub mod toml;

pub use arcswap::ArcCell;
pub use circuit::{BreakerState, CircuitBreaker, CircuitBreakerConfig};
pub use json::Json;
pub use rng::Rng;
pub use schedule::RateSchedule;

/// Split `total` work items into `parts` near-equal integer shares: the
/// first `total % parts` shares are one item larger, so nothing is
/// silently dropped (callers used to compute `total / parts` by hand
/// and lose the remainder).
pub fn split_evenly(total: u64, parts: usize) -> Vec<usize> {
    let parts = parts.max(1);
    let base = (total / parts as u64) as usize;
    let rem = (total % parts as u64) as usize;
    (0..parts).map(|i| base + usize::from(i < rem)).collect()
}

#[cfg(test)]
mod tests {
    use super::split_evenly;

    #[test]
    fn split_evenly_conserves_total_and_spreads_remainder() {
        assert_eq!(split_evenly(25, 4), vec![7, 6, 6, 6]);
        assert_eq!(split_evenly(24, 2), vec![12, 12]);
        assert_eq!(split_evenly(2, 5), vec![1, 1, 0, 0, 0]);
        assert_eq!(split_evenly(0, 3), vec![0, 0, 0]);
        assert_eq!(split_evenly(7, 0), vec![7], "zero parts clamps to one");
        for (total, parts) in [(101u64, 7usize), (13, 13), (1, 2)] {
            let shares = split_evenly(total, parts);
            assert_eq!(shares.iter().map(|s| *s as u64).sum::<u64>(), total);
            let (min, max) = (shares.iter().min().unwrap(), shares.iter().max().unwrap());
            assert!(max - min <= 1, "shares uneven: {shares:?}");
        }
    }
}
