//! Self-built utility substrates (offline environment: no serde, rand,
//! clap or criterion in the vendored dependency set — see DESIGN.md
//! §Substitutions).

pub mod arcswap;
pub mod bench;
pub mod json;
pub mod rng;
pub mod schedule;

pub use arcswap::ArcCell;
pub use json::Json;
pub use rng::Rng;
pub use schedule::RateSchedule;
