//! Circuit breaker + retry budget for pilot/broker actuation.
//!
//! The autoscale control loop actuates external frameworks (pilot
//! extend/stop).  A flapping framework — one that fails every actuation
//! attempt for a while — must not wedge the loop into retrying the same
//! doomed call on every tick.  The classic answer is a three-state
//! circuit breaker:
//!
//! * **Closed** — calls flow; each call gets a small retry budget.
//!   `failure_threshold` *consecutive* exhausted calls trip the breaker.
//! * **Open** — calls fast-fail without touching the framework until
//!   `cooldown` has elapsed.
//! * **HalfOpen** — after the cooldown, up to `half_open_probes` calls
//!   are let through, but only **one at a time**: while a probe is in
//!   flight every other caller fast-fails (otherwise N concurrent
//!   control-loop ticks would all hammer the possibly-still-down
//!   framework at once).  One probe success re-closes the breaker, one
//!   failure re-opens it (and restarts the cooldown).
//!
//! Interior mutability (a mutex around the small state machine) keeps
//! the API `&self`, matching how the control loop shares itself across
//! its tick body.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Breaker states (see module docs for the transition rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl std::fmt::Display for BreakerState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BreakerState::Closed => write!(f, "closed"),
            BreakerState::Open => write!(f, "open"),
            BreakerState::HalfOpen => write!(f, "half-open"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CircuitBreakerConfig {
    /// Consecutive failed calls (retry budget exhausted) before the
    /// breaker trips Open.
    pub failure_threshold: usize,
    /// How long an Open breaker fast-fails before probing again.
    pub cooldown: Duration,
    /// Probe calls admitted in HalfOpen before a failure re-opens.
    pub half_open_probes: usize,
    /// Attempts per [`CircuitBreaker::call`] (1 = no retry).
    pub retry_budget: usize,
}

impl Default for CircuitBreakerConfig {
    fn default() -> Self {
        CircuitBreakerConfig {
            failure_threshold: 3,
            cooldown: Duration::from_secs(5),
            half_open_probes: 1,
            retry_budget: 2,
        }
    }
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    /// Consecutive exhausted calls while Closed.
    consecutive_failures: usize,
    /// When the breaker tripped (valid while Open).
    opened_at: Instant,
    /// Probes admitted since entering HalfOpen.
    probes: usize,
    /// A HalfOpen probe has been admitted and has not yet reported
    /// success or failure.  Fences concurrent callers to exactly one
    /// in-flight probe regardless of `half_open_probes`.
    probe_in_flight: bool,
}

/// A Closed/Open/HalfOpen circuit breaker with a per-call retry budget.
/// Cheap to share behind the control loop's `&self` methods; see the
/// module docs for the state machine.
#[derive(Debug)]
pub struct CircuitBreaker {
    config: CircuitBreakerConfig,
    inner: Mutex<Inner>,
}

impl Default for CircuitBreaker {
    fn default() -> Self {
        Self::new(CircuitBreakerConfig::default())
    }
}

impl CircuitBreaker {
    pub fn new(config: CircuitBreakerConfig) -> Self {
        CircuitBreaker {
            config,
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                consecutive_failures: 0,
                opened_at: Instant::now(),
                probes: 0,
                probe_in_flight: false,
            }),
        }
    }

    pub fn state(&self) -> BreakerState {
        self.inner.lock().unwrap().state
    }

    /// Whether a call would currently be admitted (advances Open →
    /// HalfOpen when the cooldown has elapsed).  A non-consuming peek:
    /// unlike [`CircuitBreaker::call`] it never reserves the HalfOpen
    /// probe slot, so peeking cannot starve a real probe.
    pub fn is_callable(&self) -> bool {
        let mut st = self.inner.lock().unwrap();
        match st.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if st.opened_at.elapsed() >= self.config.cooldown {
                    st.state = BreakerState::HalfOpen;
                    st.probes = 0;
                    st.probe_in_flight = false;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                !st.probe_in_flight && st.probes < self.config.half_open_probes
            }
        }
    }

    /// Admit or fast-fail, advancing Open → HalfOpen on cooldown expiry.
    /// In HalfOpen, admission reserves the single in-flight probe slot;
    /// concurrent callers fast-fail until the probe reports back.
    fn admit(&self) -> Result<()> {
        let mut st = self.inner.lock().unwrap();
        match st.state {
            BreakerState::Closed => Ok(()),
            BreakerState::Open => {
                if st.opened_at.elapsed() >= self.config.cooldown {
                    st.state = BreakerState::HalfOpen;
                    st.probes = 1;
                    st.probe_in_flight = true;
                    Ok(())
                } else {
                    Err(Error::Pilot(format!(
                        "circuit breaker open ({}s cooldown); actuation skipped",
                        self.config.cooldown.as_secs_f64()
                    )))
                }
            }
            BreakerState::HalfOpen => {
                if !st.probe_in_flight && st.probes < self.config.half_open_probes {
                    st.probes += 1;
                    st.probe_in_flight = true;
                    Ok(())
                } else {
                    Err(Error::Pilot(
                        "circuit breaker half-open probe budget spent; actuation skipped".into(),
                    ))
                }
            }
        }
    }

    fn on_success(&self) {
        let mut st = self.inner.lock().unwrap();
        st.state = BreakerState::Closed;
        st.consecutive_failures = 0;
        st.probe_in_flight = false;
    }

    fn on_failure(&self) {
        let mut st = self.inner.lock().unwrap();
        st.probe_in_flight = false;
        match st.state {
            BreakerState::Closed => {
                st.consecutive_failures += 1;
                if st.consecutive_failures >= self.config.failure_threshold {
                    st.state = BreakerState::Open;
                    st.opened_at = Instant::now();
                }
            }
            // A failed half-open probe re-opens and restarts cooldown.
            BreakerState::HalfOpen | BreakerState::Open => {
                st.state = BreakerState::Open;
                st.opened_at = Instant::now();
            }
        }
    }

    /// Run `f` through the breaker: fast-fail while Open, otherwise try
    /// up to `retry_budget` times, returning the first success.  Every
    /// exhausted budget counts one failure toward the trip threshold;
    /// any success re-closes the breaker.
    pub fn call<T>(&self, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        self.admit()?;
        let mut last = None;
        for _ in 0..self.config.retry_budget.max(1) {
            match f() {
                Ok(v) => {
                    self.on_success();
                    return Ok(v);
                }
                Err(e) => last = Some(e),
            }
        }
        self.on_failure();
        Err(last.expect("retry budget >= 1 attempt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn config(cooldown_ms: u64) -> CircuitBreakerConfig {
        CircuitBreakerConfig {
            failure_threshold: 2,
            cooldown: Duration::from_millis(cooldown_ms),
            half_open_probes: 1,
            retry_budget: 2,
        }
    }

    fn fail() -> Result<()> {
        Err(Error::Pilot("framework down".into()))
    }

    #[test]
    fn success_passes_through_closed() {
        let b = CircuitBreaker::new(config(50));
        assert_eq!(b.call(|| Ok(7)).unwrap(), 7);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn retry_budget_retries_within_one_call() {
        let b = CircuitBreaker::new(config(50));
        let attempts = AtomicUsize::new(0);
        let out = b.call(|| {
            if attempts.fetch_add(1, Ordering::Relaxed) == 0 {
                fail()
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.unwrap(), 42);
        assert_eq!(attempts.load(Ordering::Relaxed), 2, "retried once");
        assert_eq!(b.state(), BreakerState::Closed, "a success never counts");
    }

    #[test]
    fn consecutive_exhausted_calls_trip_open_and_fast_fail() {
        let b = CircuitBreaker::new(config(10_000));
        let attempts = AtomicUsize::new(0);
        for _ in 0..2 {
            let _ = b.call(|| {
                attempts.fetch_add(1, Ordering::Relaxed);
                fail()
            });
        }
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(attempts.load(Ordering::Relaxed), 4, "2 calls x 2 attempts");
        // Open: the framework is not touched at all.
        let err = b.call(|| {
            attempts.fetch_add(1, Ordering::Relaxed);
            Ok(())
        });
        assert!(err.unwrap_err().to_string().contains("circuit breaker open"));
        assert_eq!(attempts.load(Ordering::Relaxed), 4);
        assert!(!b.is_callable());
    }

    #[test]
    fn half_open_probe_success_recloses() {
        let b = CircuitBreaker::new(config(20));
        for _ in 0..2 {
            let _ = b.call(fail);
        }
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.is_callable(), "cooldown elapsed: half-open");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.call(|| Ok(())).unwrap();
        assert_eq!(b.state(), BreakerState::Closed);
        // And the failure streak restarted from zero.
        let _ = b.call(fail);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn half_open_admits_exactly_one_probe_under_concurrency() {
        let b = CircuitBreaker::new(CircuitBreakerConfig {
            failure_threshold: 2,
            cooldown: Duration::from_millis(20),
            // Budget > 1: the in-flight fence must still cap concurrent
            // admissions at one (the budget only governs sequential
            // probes, never parallel ones).
            half_open_probes: 4,
            retry_budget: 1,
        });
        for _ in 0..2 {
            let _ = b.call(fail);
        }
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(30));
        // The closure below runs *while the first probe is in flight*:
        // a second caller arriving in that window must fast-fail even
        // though probe budget remains, and must never touch the
        // framework (the old counter-only scheme admitted it).
        let concurrent_ran = AtomicUsize::new(0);
        b.call(|| {
            let second = b.call(|| {
                concurrent_ran.fetch_add(1, Ordering::Relaxed);
                Ok(())
            });
            assert!(second
                .unwrap_err()
                .to_string()
                .contains("half-open probe budget spent"));
            assert!(!b.is_callable(), "peek agrees while the probe is in flight");
            assert_eq!(b.state(), BreakerState::HalfOpen);
            Ok(())
        })
        .unwrap();
        assert_eq!(
            concurrent_ran.load(Ordering::Relaxed),
            0,
            "the concurrent caller never reached the framework"
        );
        assert_eq!(b.state(), BreakerState::Closed, "the one probe re-closed");
    }

    #[test]
    fn half_open_probe_failure_reopens_with_fresh_cooldown() {
        let b = CircuitBreaker::new(config(20));
        for _ in 0..2 {
            let _ = b.call(fail);
        }
        std::thread::sleep(Duration::from_millis(30));
        let _ = b.call(fail); // the probe fails
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.is_callable(), "cooldown restarted");
    }
}
