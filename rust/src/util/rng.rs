//! Deterministic PRNG + distributions.
//!
//! The offline dependency set has no `rand` crate, so the coordinator
//! carries its own small generator (DESIGN.md §Substitutions):
//! xoshiro256++ seeded through SplitMix64, with uniform, normal
//! (Box-Muller) and lognormal sampling — everything the MASS data
//! sources and the cloud latency models need.  Deterministic for a
//! given seed across platforms.

/// xoshiro256++ PRNG (public-domain algorithm by Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare_gauss: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_gauss: None,
        }
    }

    /// Derive an independent stream (e.g. one per producer process).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (n > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.spare_gauss.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_gauss = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Lognormal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gauss()).exp()
    }

    /// Exponential with rate lambda.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_gauss_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gauss() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::seed_from(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::seed_from(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|s| *s), "all residues hit: {seen:?}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::seed_from(3);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_mean_matches_formula() {
        let mut r = Rng::seed_from(4);
        let (mu, sigma) = (0.5, 0.4);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.lognormal(mu, sigma);
        }
        let mean = sum / n as f64;
        let expect = (mu + sigma * sigma / 2.0f64).exp();
        assert!((mean - expect).abs() / expect < 0.03, "mean={mean} expect={expect}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seed_from(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
