//! Elastic autoscaling: closing the metrics→pilot loop.
//!
//! The paper's central claim is that Pilot-Streaming lets applications
//! "dynamically respond to resource requirements by adding/removing
//! resources at runtime" (§1, §4.2) — but Listing 4's
//! `extend_pilot`/`stop_pilot` primitives are *manual*.  This subsystem
//! closes the loop from observed load back to resource changes:
//!
//! ```text
//!   signals ───────────► policy ───────────► actuator
//!   consumer lag          threshold/hysteresis  extend_pilot (scale-up)
//!   lag slope             PD on lag slope       stop_pilot (scale-down,
//!   produce/consume rate  online bin-packing      extension pilots)
//!   window overrun
//! ```
//!
//! (The service also offers an in-place
//! [`crate::pilot::PilotComputeService::shrink_pilot`] and scaling-event
//! hooks for external observers; the controller itself scales down by
//! stopping the extension pilots it created.)
//!
//! * [`signals`] — [`SignalProbe`] samples per-topic consumer lag,
//!   per-partition backlog, produce/consume throughput and the
//!   micro-batch engine's window-overrun gauges into
//!   [`SignalSnapshot`]s;
//! * [`policy`] — pure, pluggable [`ScalingPolicy`] implementations
//!   (threshold + hysteresis + cooldown, lag-slope PD control, and
//!   first-fit-decreasing bin-packing à la Stein et al. 2020), plus the
//!   [`PartitionElastic`] decorator that upgrades a capped scale-up to
//!   a topic repartition so the one-task-per-partition ceiling (§6.4's
//!   knee) moves with the fleet;
//! * [`controller`] — the [`Autoscaler`] thread that actuates decisions
//!   through [`crate::pilot::PilotComputeService`] and records every
//!   action on a [`crate::metrics::ScalingTimeline`].
//!
//! The same policies run deterministically in virtual time through the
//! simulation plane's [`crate::sim::ElasticSim`], which is how the
//! 32-node behaviour is exercised on a small host.
//!
//! See `examples/dynamic_scaling.rs` for the end-to-end loop (bursty
//! MASS source → broker → MASA consumer, no manual extend calls).

pub mod controller;
pub mod policy;
pub mod signals;

pub use controller::{Autoscaler, AutoscalerConfig};
pub use policy::{
    BinPackingPolicy, LagSlopePolicy, PartitionElastic, PolicyDecision, ScalingPolicy,
    ThresholdPolicy,
};
pub use signals::{SignalProbe, SignalSnapshot};
