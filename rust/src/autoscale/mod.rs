//! Elastic autoscaling: closing the metrics→pilot loop.
//!
//! The paper's central claim is that Pilot-Streaming lets applications
//! "dynamically respond to resource requirements by adding/removing
//! resources at runtime" (§1, §4.2) — but Listing 4's
//! `extend_pilot`/`stop_pilot` primitives are *manual*.  This subsystem
//! closes the loop from observed load back to resource changes:
//!
//! ```text
//!   signals ──────────► policy ─────────► planner ──────────► actuator
//!   consumer lag         threshold/        per-framework       extend_pilot
//!   lag slope            hysteresis        extension costs     stop_pilot
//!   produce/consume      PD on lag slope   drain-benefit       repartition_topic
//!   window overrun       bin-packing       gate (defer/        broker extend
//!   broker NIC/disk      (emit intents)    resize), broker     (plan steps)
//!   token-bucket util                      co-scheduling
//! ```
//!
//! (The service also offers an in-place
//! [`crate::pilot::PilotComputeService::shrink_pilot`] and scaling-event
//! hooks for external observers; the controller itself scales down by
//! stopping the extension pilots it created.)
//!
//! * [`signals`] — [`SignalProbe`] samples per-topic consumer lag,
//!   per-partition backlog, produce/consume throughput and the
//!   micro-batch engine's window-overrun gauges into
//!   [`SignalSnapshot`]s;
//! * [`policy`] — pure, pluggable [`ScalingPolicy`] implementations
//!   (threshold + hysteresis + cooldown, lag-slope PD control, and
//!   first-fit-decreasing bin-packing à la Stein et al. 2020), plus the
//!   [`PartitionElastic`] decorator that upgrades a capped scale-up to
//!   a topic repartition so the one-task-per-partition ceiling (§6.4's
//!   knee) moves with the fleet — policies emit [`ScalingIntent`]s,
//!   never actions;
//! * [`planner`] — the [`Planner`] turns each intent into a costed,
//!   multi-step [`ScalingPlan`]: per-framework extension costs (from
//!   [`crate::plugins::bootstrap_model_for`]'s calibrated tables) are
//!   weighed against the expected lag-drain benefit, so a scale-up
//!   that cannot pay for itself within the drain horizon is deferred
//!   or resized, and a repartition that would oversubscribe per-node
//!   NIC/disk budgets co-schedules a broker-extension step;
//! * [`controller`] — the [`Autoscaler`] thread that executes plans
//!   step by step through [`crate::pilot::PilotComputeService`] and
//!   records every step (and deferral) on a
//!   [`crate::metrics::ScalingTimeline`].
//!
//! The same policies run deterministically in virtual time through the
//! simulation plane's [`crate::sim::ElasticSim`], which is how the
//! 32-node behaviour is exercised on a small host.
//!
//! See `examples/dynamic_scaling.rs` for the end-to-end loop (bursty
//! MASS source → broker → MASA consumer, no manual extend calls).

pub mod controller;
pub mod planner;
pub mod policy;
pub mod signals;

pub use controller::{Autoscaler, AutoscalerConfig};
pub use planner::{DeferReason, PlanStep, Planner, PlannerConfig, ScalingPlan, StepCost};
pub use policy::{
    BinPackingPolicy, LagSlopePolicy, PartitionElastic, PolicyDecision, ScalingIntent,
    ScalingPolicy, ThresholdPolicy,
};
pub use signals::{EdgeLag, SignalProbe, SignalSnapshot};
