//! Runtime backpressure signals: what the control loop samples.
//!
//! One [`SignalSnapshot`] per sample tick, assembled by [`SignalProbe`]
//! from the broker's consumer-group offsets (per-topic lag, per-partition
//! backlog), the observed produce/consume throughput (finite differences
//! of the high watermarks) and the micro-batch engine's window-overrun
//! gauges ([`crate::engine::JobStats`]).  Policies consume snapshots;
//! nothing here decides anything.

use std::sync::Arc;

use crate::broker::BrokerCluster;
use crate::engine::JobStats;
use crate::error::Result;

/// One sample of every backpressure signal the policies read.
#[derive(Debug, Clone)]
pub struct SignalSnapshot {
    /// Seconds since the control loop started.
    pub t_secs: f64,
    /// Total consumer lag for the watched (group, topic), messages.
    pub lag: u64,
    /// Rate of lag change, msgs/sec (positive = falling behind).
    pub lag_slope: f64,
    /// Observed production rate into the topic, msgs/sec.
    pub produce_rate: f64,
    /// Observed consumption rate, msgs/sec.
    pub consume_rate: f64,
    /// Lag broken out per partition (bin-packing item sizes).  Includes
    /// partitions retired by a shrink while groups still drain them, so
    /// its length can exceed `partitions`.
    pub partition_backlog: Vec<u64>,
    /// Active partition count of the topic — the one-task-per-partition
    /// parallelism cap (§6.4) that [`crate::autoscale::PartitionElastic`]
    /// moves with the fleet.
    pub partitions: usize,
    /// Cumulative micro-batches that outran their window.
    pub behind_batches: u64,
    /// Duration of the most recent micro-batch, seconds.
    pub last_batch_secs: f64,
    /// The job's micro-batch window, seconds.
    pub window_secs: f64,
    /// Current processing nodes (base pilot + live extensions).
    pub nodes: usize,
    /// Fleet floor (the base pilot's nodes).
    pub min_nodes: usize,
    /// Fleet ceiling (base + allowed extensions).
    pub max_nodes: usize,
    /// Smoothed per-node service rate estimate, msgs/sec/node
    /// (0.0 until the first consumption is observed).
    pub service_rate_per_node: f64,
}

impl SignalSnapshot {
    /// How far the last micro-batch overran its window (1.0 = at the
    /// limit; > 1.0 = falling behind) — the paper's backpressure signal.
    pub fn window_overrun(&self) -> f64 {
        if self.window_secs <= 0.0 {
            return 0.0;
        }
        self.last_batch_secs / self.window_secs
    }
}

/// Samples live signals into [`SignalSnapshot`]s, keeping the little
/// state finite-difference rates and EWMA smoothing need.
pub struct SignalProbe {
    cluster: BrokerCluster,
    topic: String,
    group: String,
    stats: Option<Arc<JobStats>>,
    window_secs: f64,
    prev_t: f64,
    prev_end_sum: u64,
    prev_lag: u64,
    ewma_rate_per_node: f64,
}

impl SignalProbe {
    /// Probe for `group` on `topic`.  `stats` (when the consumer is a
    /// micro-batch job) supplies the window-overrun gauges.
    pub fn new(
        cluster: BrokerCluster,
        topic: &str,
        group: &str,
        stats: Option<Arc<JobStats>>,
        window_secs: f64,
    ) -> Self {
        let mut probe = SignalProbe {
            cluster,
            topic: topic.to_string(),
            group: group.to_string(),
            stats,
            window_secs,
            prev_t: 0.0,
            prev_end_sum: 0,
            prev_lag: 0,
            ewma_rate_per_node: 0.0,
        };
        // Seed the watermark and lag baselines so the first sample sees
        // pre-existing topic history as standing lag, not as a produce
        // burst or a runaway lag slope.
        if let Ok((end_sum, backlog)) = probe.scan() {
            probe.prev_end_sum = end_sum;
            probe.prev_lag = backlog.iter().sum();
        }
        probe
    }

    /// One pass over the topic: total end offset + per-partition
    /// committed lag, both derived from the broker's
    /// [`BrokerCluster::group_progress`] so lag semantics live in one
    /// place.
    fn scan(&self) -> Result<(u64, Vec<u64>)> {
        let progress = self.cluster.group_progress(&self.group, &self.topic)?;
        let end_sum = progress.iter().map(|(end, _)| *end).sum();
        let backlog = progress
            .iter()
            .map(|(end, committed)| end.saturating_sub(*committed))
            .collect();
        Ok((end_sum, backlog))
    }

    /// Take one sample at `t_secs` with the current fleet shape.
    /// Errors only if the topic disappeared.
    pub fn sample(
        &mut self,
        t_secs: f64,
        nodes: usize,
        min_nodes: usize,
        max_nodes: usize,
    ) -> Result<SignalSnapshot> {
        let (end_sum, partition_backlog) = self.scan()?;
        let partitions = self.cluster.partition_count(&self.topic)?;
        let lag: u64 = partition_backlog.iter().sum();

        let dt = (t_secs - self.prev_t).max(1e-6);
        let produce_rate = end_sum.saturating_sub(self.prev_end_sum) as f64 / dt;
        let lag_slope = (lag as f64 - self.prev_lag as f64) / dt;
        let consume_rate = (produce_rate - lag_slope).max(0.0);
        if consume_rate > 0.0 && nodes > 0 {
            let observed = consume_rate / nodes as f64;
            self.ewma_rate_per_node = if self.ewma_rate_per_node > 0.0 {
                0.7 * self.ewma_rate_per_node + 0.3 * observed
            } else {
                observed
            };
        }
        self.prev_t = t_secs;
        self.prev_end_sum = end_sum;
        self.prev_lag = lag;

        let (behind_batches, last_batch_secs) = match &self.stats {
            Some(st) => (
                st.behind.load(std::sync::atomic::Ordering::Relaxed),
                st.last_batch_secs(),
            ),
            None => (0, 0.0),
        };
        Ok(SignalSnapshot {
            t_secs,
            lag,
            lag_slope,
            produce_rate,
            consume_rate,
            partition_backlog,
            partitions,
            behind_batches,
            last_batch_secs,
            window_secs: self.window_secs,
            nodes,
            min_nodes,
            max_nodes,
            service_rate_per_node: self.ewma_rate_per_node,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    #[test]
    fn probe_tracks_lag_and_rates() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        cluster.create_topic("t", 2).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);

        let s = probe.sample(1.0, 1, 1, 4).unwrap();
        assert_eq!(s.lag, 0);
        assert_eq!(s.produce_rate, 0.0);
        assert_eq!(s.min_nodes, 1);
        assert_eq!(s.max_nodes, 4);

        // Produce 10 messages in one "second" of probe time.
        for i in 0..10u8 {
            cluster.produce("t", (i % 2) as usize, 1, &[vec![i]]).unwrap();
        }
        let s = probe.sample(2.0, 1, 1, 4).unwrap();
        assert_eq!(s.lag, 10);
        assert!((s.produce_rate - 10.0).abs() < 1e-9);
        assert!((s.lag_slope - 10.0).abs() < 1e-9);
        assert_eq!(s.consume_rate, 0.0);
        assert_eq!(s.partition_backlog, vec![5, 5]);
        assert_eq!(s.partitions, 2);

        // Consumer catches up on 6 of them.
        cluster.commit("g", "t", 0, 3);
        cluster.commit("g", "t", 1, 3);
        let s = probe.sample(3.0, 2, 1, 4).unwrap();
        assert_eq!(s.lag, 4);
        assert!((s.lag_slope + 6.0).abs() < 1e-9, "slope {}", s.lag_slope);
        assert!((s.consume_rate - 6.0).abs() < 1e-9);
        assert!(s.service_rate_per_node > 0.0);
        assert!(probe.sample(4.0, 2, 1, 4).is_ok());
    }

    #[test]
    fn probe_seeds_watermark_at_construction() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        cluster.create_topic("t", 1).unwrap();
        cluster.produce("t", 0, 1, &[vec![1], vec![2]]).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        // Pre-existing history is standing lag — neither a produce
        // spike nor a lag-slope spike.
        assert_eq!(s.lag, 2);
        assert_eq!(s.produce_rate, 0.0);
        assert_eq!(s.lag_slope, 0.0);
        assert_eq!(s.window_overrun(), 0.0);
    }

    #[test]
    fn probe_errors_on_unknown_topic() {
        let cluster = BrokerCluster::new(Machine::unthrottled(1), vec![0]);
        let mut probe = SignalProbe::new(cluster, "nope", "g", None, 1.0);
        assert!(probe.sample(1.0, 1, 1, 2).is_err());
    }
}
