//! Runtime backpressure signals: what the control loop samples.
//!
//! One [`SignalSnapshot`] per sample tick, assembled by [`SignalProbe`]
//! from the broker's consumer-group offsets (per-topic lag, per-partition
//! backlog), the observed produce/consume throughput (finite differences
//! of the high watermarks), the broker tier's per-node NIC/disk
//! token-bucket counters ([`crate::broker::BrokerCluster::broker_io`] —
//! surfaced as first-class utilization gauges so the planner can see
//! broker saturation, not just consumer lag) and the micro-batch
//! engine's window-overrun gauges ([`crate::engine::JobStats`]).
//! Policies and the planner consume snapshots; nothing here decides
//! anything.

use std::collections::HashMap;
use std::sync::Arc;

use crate::broker::BrokerCluster;
use crate::cluster::NodeId;
use crate::engine::JobStats;
use crate::error::Result;

/// One sample of every backpressure signal the policies read.
#[derive(Debug, Clone)]
pub struct SignalSnapshot {
    /// Seconds since the control loop started.
    pub t_secs: f64,
    /// Total consumer lag for the watched (group, topic), messages.
    pub lag: u64,
    /// Rate of lag change, msgs/sec (positive = falling behind).
    pub lag_slope: f64,
    /// Observed production rate into the topic, msgs/sec.
    pub produce_rate: f64,
    /// Observed consumption rate, msgs/sec.
    pub consume_rate: f64,
    /// Lag broken out per partition (bin-packing item sizes).  Includes
    /// partitions retired by a shrink while groups still drain them, so
    /// its length can exceed `partitions`.
    pub partition_backlog: Vec<u64>,
    /// Active partition count of the topic — the one-task-per-partition
    /// parallelism cap (§6.4) that [`crate::autoscale::PartitionElastic`]
    /// moves with the fleet.
    pub partitions: usize,
    /// Cumulative micro-batches that outran their window.
    pub behind_batches: u64,
    /// Duration of the most recent micro-batch, seconds.
    pub last_batch_secs: f64,
    /// The job's micro-batch window, seconds.
    pub window_secs: f64,
    /// Current processing nodes (base pilot + live extensions).
    pub nodes: usize,
    /// Fleet floor (the base pilot's nodes).
    pub min_nodes: usize,
    /// Fleet ceiling (base + allowed extensions).
    pub max_nodes: usize,
    /// Smoothed per-node service rate estimate, msgs/sec/node
    /// (0.0 until the first consumption is observed).
    pub service_rate_per_node: f64,
    /// Live broker-tier nodes serving the topic's partitions.
    pub broker_nodes: usize,
    /// Peak per-node NIC token-bucket utilization across the broker
    /// tier over the last sample interval (0..~1; 0.0 on unthrottled
    /// machines) — a first-class saturation gauge from
    /// [`crate::cluster::Throttle`] byte counters.
    pub broker_nic_util: f64,
    /// Peak per-node disk token-bucket utilization across the broker
    /// tier over the last sample interval (0..~1).
    pub broker_disk_util: f64,
    /// Partitions of the watched topic whose alive replica count is
    /// below the topic's configured replication factor — non-zero after
    /// a broker-node death until a replacement heals the replica sets.
    /// Durability headroom is reduced, but quorum may still be healthy;
    /// alone this does *not* trigger repair.
    pub under_replicated: usize,
    /// Partitions of the watched topic whose in-sync-replica set is
    /// below the topic's `min_insync` — these reject quorum produces
    /// *right now* (a broker death took the last in-sync follower, or
    /// replication lag shrank the ISR).  The planner treats this as a
    /// first-class signal and answers with a broker replacement step
    /// even when lag alone says Hold.
    pub below_min_insync: usize,
    /// Broker-tier load imbalance: the peak per-node utilization
    /// (each node's worse of NIC and disk) minus the tier mean, over
    /// the last sample interval.  0.0 when the tier is balanced or
    /// unthrottled; approaches the peak util itself when one broker
    /// runs hot while the rest idle.  Together with `rack_skew` this
    /// drives the planner's replica-reassignment step — moving
    /// follower replicas is cheaper than extending the tier.
    pub broker_util_skew: f64,
    /// Fraction of the watched-topic's replicated partitions whose
    /// replica set needlessly co-locates two replicas in one failure
    /// domain ([`crate::broker::BrokerCluster::rack_skew`]).  Non-zero
    /// after rack bounces re-admit brokers into already-full replica
    /// sets; cleared by replica reassignment, not by tier extension.
    pub rack_skew: f64,
    /// Fetchers parked on each broker data-plane shard's doorbell at
    /// sample time, indexed by shard id
    /// ([`crate::broker::BrokerCluster::shard_stats`]).  A planner
    /// signal: one persistently deep shard next to idle siblings means
    /// partitions hash unevenly onto shards (consumers pile up waiting
    /// on one core) — repartitioning spreads the keys, where adding
    /// nodes would not help.
    pub shard_queue_depths: Vec<u64>,
    /// Consumer lag of every dataflow-DAG edge the probe watches
    /// ([`SignalProbe::with_edges`]), sampled alongside the primary
    /// (group, topic).  Empty for flat apps.  Uneven branch load shows
    /// up here as one hot edge among quiet ones — the per-edge signal
    /// each branch stage's autoscale loop scales against.
    pub edge_lags: Vec<EdgeLag>,
}

/// One DAG consumer edge's lag sample: the `group` consuming `topic`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EdgeLag {
    pub topic: String,
    pub group: String,
    pub lag: u64,
}

impl SignalSnapshot {
    /// How far the last micro-batch overran its window (1.0 = at the
    /// limit; > 1.0 = falling behind) — the paper's backpressure signal.
    pub fn window_overrun(&self) -> f64 {
        if self.window_secs <= 0.0 {
            return 0.0;
        }
        self.last_batch_secs / self.window_secs
    }
}

/// Samples live signals into [`SignalSnapshot`]s, keeping the little
/// state finite-difference rates and EWMA smoothing need.
pub struct SignalProbe {
    cluster: BrokerCluster,
    topic: String,
    group: String,
    stats: Option<Arc<JobStats>>,
    window_secs: f64,
    prev_t: f64,
    prev_end_sum: u64,
    prev_lag: u64,
    ewma_rate_per_node: f64,
    /// Per-broker-node (nic_in, nic_out, disk) byte counters from the
    /// previous sample — finite-differenced into utilization gauges.
    prev_broker_io: HashMap<NodeId, (u64, u64, u64)>,
    /// Dataflow-DAG `(topic, group)` consumer edges sampled into
    /// [`SignalSnapshot::edge_lags`] each tick.
    edges: Vec<(String, String)>,
}

impl SignalProbe {
    /// Probe for `group` on `topic`.  `stats` (when the consumer is a
    /// micro-batch job) supplies the window-overrun gauges.
    pub fn new(
        cluster: BrokerCluster,
        topic: &str,
        group: &str,
        stats: Option<Arc<JobStats>>,
        window_secs: f64,
    ) -> Self {
        let mut probe = SignalProbe {
            cluster,
            topic: topic.to_string(),
            group: group.to_string(),
            stats,
            window_secs,
            prev_t: 0.0,
            prev_end_sum: 0,
            prev_lag: 0,
            ewma_rate_per_node: 0.0,
            prev_broker_io: HashMap::new(),
            edges: Vec::new(),
        };
        // Seed the watermark and lag baselines so the first sample sees
        // pre-existing topic history as standing lag, not as a produce
        // burst or a runaway lag slope.  Broker I/O counters are seeded
        // the same way: history must not read as a saturation spike.
        if let Ok((end_sum, backlog)) = probe.scan() {
            probe.prev_end_sum = end_sum;
            probe.prev_lag = backlog.iter().sum();
        }
        for io in probe.cluster.broker_io() {
            probe
                .prev_broker_io
                .insert(io.node, (io.nic_in_bytes, io.nic_out_bytes, io.disk_bytes));
        }
        probe
    }

    /// Watch extra `(topic, group)` consumer edges — the dataflow DAG's
    /// hops — whose lags ride along in every snapshot's
    /// [`SignalSnapshot::edge_lags`].
    pub fn with_edges(mut self, edges: Vec<(String, String)>) -> Self {
        self.edges = edges;
        self
    }

    /// Finite-difference the broker tier's token-bucket counters into
    /// peak per-node NIC/disk utilization over `dt` seconds.  A node
    /// first seen this sample (broker extension mid-run) is seeded at
    /// its current counters — zero delta, so a freshly joined broker's
    /// lifetime bytes never read as one interval's saturation spike.
    /// Unthrottled buckets report 0.0.
    fn broker_utilization(&mut self, dt: f64) -> (usize, f64, f64, f64) {
        let io = self.cluster.broker_io();
        let mut nic_util = 0.0f64;
        let mut disk_util = 0.0f64;
        let mut per_node: Vec<f64> = Vec::with_capacity(io.len());
        let mut next = HashMap::with_capacity(io.len());
        for stat in &io {
            let (prev_in, prev_out, prev_disk) = self
                .prev_broker_io
                .get(&stat.node)
                .copied()
                .unwrap_or((stat.nic_in_bytes, stat.nic_out_bytes, stat.disk_bytes));
            let mut node_util = 0.0f64;
            if let Some(rate) = stat.nic_rate {
                // Each direction has its own token bucket; the gauge is
                // the worse of the two, so a produce-only flood (the
                // backlog-building case) reads as full saturation.
                let used_in = stat.nic_in_bytes.saturating_sub(prev_in) as f64 / dt;
                let used_out = stat.nic_out_bytes.saturating_sub(prev_out) as f64 / dt;
                node_util = node_util.max(used_in.max(used_out) / rate);
                nic_util = nic_util.max(used_in.max(used_out) / rate);
            }
            if let Some(rate) = stat.disk_rate {
                let used = stat.disk_bytes.saturating_sub(prev_disk) as f64 / dt;
                node_util = node_util.max(used / rate);
                disk_util = disk_util.max(used / rate);
            }
            per_node.push(node_util);
            next.insert(stat.node, (stat.nic_in_bytes, stat.nic_out_bytes, stat.disk_bytes));
        }
        self.prev_broker_io = next;
        // Peak-minus-mean over each node's worse gauge: a balanced (or
        // unthrottled) tier reads 0, one hot node among idle peers
        // reads close to the hot node's own utilization.
        let util_skew = if per_node.is_empty() {
            0.0
        } else {
            let peak = per_node.iter().copied().fold(0.0f64, f64::max);
            let mean = per_node.iter().sum::<f64>() / per_node.len() as f64;
            peak - mean
        };
        (io.len(), nic_util, disk_util, util_skew)
    }

    /// One pass over the topic: total end offset + per-partition
    /// committed lag, both derived from the broker's
    /// [`BrokerCluster::group_progress`] so lag semantics live in one
    /// place.
    fn scan(&self) -> Result<(u64, Vec<u64>)> {
        let progress = self.cluster.group_progress(&self.group, &self.topic)?;
        let end_sum = progress.iter().map(|(end, _)| *end).sum();
        let backlog = progress
            .iter()
            .map(|(end, committed)| end.saturating_sub(*committed))
            .collect();
        Ok((end_sum, backlog))
    }

    /// Take one sample at `t_secs` with the current fleet shape.
    /// Errors only if the topic disappeared.
    pub fn sample(
        &mut self,
        t_secs: f64,
        nodes: usize,
        min_nodes: usize,
        max_nodes: usize,
    ) -> Result<SignalSnapshot> {
        let (end_sum, partition_backlog) = self.scan()?;
        let partitions = self.cluster.partition_count(&self.topic)?;
        let under_replicated = self.cluster.under_replicated(&self.topic)?;
        let below_min_insync = self.cluster.below_min_insync(&self.topic)?;
        let shard_queue_depths: Vec<u64> = self
            .cluster
            .shard_stats()
            .iter()
            .map(|s| s.parked_fetchers)
            .collect();
        let lag: u64 = partition_backlog.iter().sum();

        let dt = (t_secs - self.prev_t).max(1e-6);
        let (broker_nodes, broker_nic_util, broker_disk_util, broker_util_skew) =
            self.broker_utilization(dt);
        let rack_skew = self.cluster.rack_skew();
        let produce_rate = end_sum.saturating_sub(self.prev_end_sum) as f64 / dt;
        let lag_slope = (lag as f64 - self.prev_lag as f64) / dt;
        let consume_rate = (produce_rate - lag_slope).max(0.0);
        if consume_rate > 0.0 && nodes > 0 {
            let observed = consume_rate / nodes as f64;
            self.ewma_rate_per_node = if self.ewma_rate_per_node > 0.0 {
                0.7 * self.ewma_rate_per_node + 0.3 * observed
            } else {
                observed
            };
        }
        self.prev_t = t_secs;
        self.prev_end_sum = end_sum;
        self.prev_lag = lag;

        let (behind_batches, last_batch_secs) = match &self.stats {
            Some(st) => (
                st.behind.load(std::sync::atomic::Ordering::Relaxed),
                st.last_batch_secs(),
            ),
            None => (0, 0.0),
        };
        // Per-edge lags: an edge whose topic vanished mid-teardown
        // samples as absent rather than failing the whole snapshot.
        let edge_lags: Vec<EdgeLag> = self
            .edges
            .iter()
            .filter_map(|(topic, group)| {
                self.cluster.group_lag(group, topic).ok().map(|lag| EdgeLag {
                    topic: topic.clone(),
                    group: group.clone(),
                    lag,
                })
            })
            .collect();
        Ok(SignalSnapshot {
            t_secs,
            lag,
            lag_slope,
            produce_rate,
            consume_rate,
            partition_backlog,
            partitions,
            behind_batches,
            last_batch_secs,
            window_secs: self.window_secs,
            nodes,
            min_nodes,
            max_nodes,
            service_rate_per_node: self.ewma_rate_per_node,
            broker_nodes,
            broker_nic_util,
            broker_disk_util,
            under_replicated,
            below_min_insync,
            broker_util_skew,
            rack_skew,
            shard_queue_depths,
            edge_lags,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Machine;

    #[test]
    fn probe_tracks_lag_and_rates() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        cluster.create_topic("t", 2).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);

        let s = probe.sample(1.0, 1, 1, 4).unwrap();
        assert_eq!(s.lag, 0);
        assert_eq!(s.produce_rate, 0.0);
        assert_eq!(s.min_nodes, 1);
        assert_eq!(s.max_nodes, 4);
        // The per-shard queue-depth signal covers every data-plane
        // shard, and an idle cluster parks no fetchers.
        assert_eq!(s.shard_queue_depths.len(), cluster.n_shards());
        assert!(s.shard_queue_depths.iter().all(|d| *d == 0));

        // Produce 10 messages in one "second" of probe time.
        for i in 0..10u8 {
            cluster.produce("t", (i % 2) as usize, 1, &[vec![i]]).unwrap();
        }
        let s = probe.sample(2.0, 1, 1, 4).unwrap();
        assert_eq!(s.lag, 10);
        assert!((s.produce_rate - 10.0).abs() < 1e-9);
        assert!((s.lag_slope - 10.0).abs() < 1e-9);
        assert_eq!(s.consume_rate, 0.0);
        assert_eq!(s.partition_backlog, vec![5, 5]);
        assert_eq!(s.partitions, 2);

        // Consumer catches up on 6 of them.
        cluster.commit("g", "t", 0, 3);
        cluster.commit("g", "t", 1, 3);
        let s = probe.sample(3.0, 2, 1, 4).unwrap();
        assert_eq!(s.lag, 4);
        assert!((s.lag_slope + 6.0).abs() < 1e-9, "slope {}", s.lag_slope);
        assert!((s.consume_rate - 6.0).abs() < 1e-9);
        assert!(s.service_rate_per_node > 0.0);
        assert!(probe.sample(4.0, 2, 1, 4).is_ok());
    }

    #[test]
    fn probe_seeds_watermark_at_construction() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        cluster.create_topic("t", 1).unwrap();
        cluster.produce("t", 0, 1, &[vec![1], vec![2]]).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        // Pre-existing history is standing lag — neither a produce
        // spike nor a lag-slope spike.
        assert_eq!(s.lag, 2);
        assert_eq!(s.produce_rate, 0.0);
        assert_eq!(s.lag_slope, 0.0);
        assert_eq!(s.window_overrun(), 0.0);
    }

    #[test]
    fn probe_surfaces_broker_io_utilization() {
        // Wrangler nodes are throttled, so moved bytes show up as
        // non-zero utilization gauges.
        let machine = crate::cluster::Machine::wrangler(2);
        let cluster = BrokerCluster::new(machine, vec![0]);
        cluster.create_topic("t", 1).unwrap();
        // Pre-probe history must be seeded away, not read as a spike.
        cluster.produce("t", 0, 1, &[vec![0u8; 4096]]).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.broker_nodes, 1);
        assert_eq!(s.broker_nic_util, 0.0, "seeded baseline");
        assert_eq!(s.broker_disk_util, 0.0);
        cluster.produce("t", 0, 1, &[vec![0u8; 8192]]).unwrap();
        let s = probe.sample(2.0, 1, 1, 2).unwrap();
        assert!(s.broker_nic_util > 0.0, "nic util {}", s.broker_nic_util);
        assert!(s.broker_disk_util > 0.0, "disk util {}", s.broker_disk_util);
        assert!(s.broker_nic_util <= 1.0 && s.broker_disk_util <= 1.0);
        // Quiet interval: gauges fall back to zero.
        let s = probe.sample(3.0, 1, 1, 2).unwrap();
        assert_eq!(s.broker_nic_util, 0.0);
    }

    #[test]
    fn probe_reports_unthrottled_brokers_as_unsaturated() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0, 1]);
        cluster.create_topic("t", 2).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        cluster.produce("t", 0, 0, &[vec![0u8; 1 << 20]]).unwrap();
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.broker_nodes, 2);
        assert_eq!(s.broker_nic_util, 0.0);
        assert_eq!(s.broker_disk_util, 0.0);
    }

    #[test]
    fn probe_surfaces_degraded_replication() {
        use crate::broker::{AckMode, ReplicationConfig};
        let cluster = BrokerCluster::new(Machine::unthrottled(3), vec![0, 1]);
        cluster
            .create_topic_replicated(
                "t",
                2,
                ReplicationConfig::new(2).with_ack_mode(AckMode::Quorum).with_min_insync(2),
            )
            .unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!((s.under_replicated, s.below_min_insync), (0, 0));
        cluster.kill_broker(1).unwrap();
        let s = probe.sample(2.0, 1, 1, 2).unwrap();
        assert_eq!(s.under_replicated, 2);
        assert_eq!(s.below_min_insync, 2, "min_insync 2 lost its follower");
        // A replacement broker heals the replica sets.
        cluster.add_brokers(vec![2]);
        let s = probe.sample(3.0, 1, 1, 2).unwrap();
        assert_eq!((s.under_replicated, s.below_min_insync), (0, 0));
    }

    #[test]
    fn probe_splits_under_replicated_from_quorum_degraded() {
        // A factor-2 / min_insync-1 topic that loses a follower is
        // under-replicated but quorum-healthy: only `under_replicated`
        // fires, so the planner will not schedule repair for it.
        use crate::broker::ReplicationConfig;
        let cluster = BrokerCluster::new(Machine::unthrottled(3), vec![0, 1]);
        cluster.create_topic_replicated("t", 2, ReplicationConfig::new(2)).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        cluster.kill_broker(1).unwrap();
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.under_replicated, 2);
        assert_eq!(s.below_min_insync, 0, "quorum still healthy at min_insync 1");
    }

    #[test]
    fn probe_surfaces_broker_util_skew_and_rack_skew() {
        use crate::broker::ReplicationConfig;
        // One hot broker next to an idle peer: peak-minus-mean fires.
        let machine = crate::cluster::Machine::wrangler(3);
        let cluster = BrokerCluster::new(machine, vec![0, 1]);
        cluster.create_topic("t", 2).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.broker_util_skew, 0.0, "seeded baseline");
        assert_eq!(s.rack_skew, 0.0, "unracked tier");
        cluster.produce("t", 0, 2, &[vec![0u8; 8192]]).unwrap();
        let s = probe.sample(2.0, 1, 1, 2).unwrap();
        assert!(s.broker_util_skew > 0.0, "skew {}", s.broker_util_skew);
        assert!(s.broker_util_skew <= s.broker_nic_util.max(s.broker_disk_util));

        // A rack bounce leaves every replica set co-located in the
        // surviving domain; the probe surfaces the placement debt.
        let c = BrokerCluster::with_racks(Machine::unthrottled(6), vec![0, 1, 2, 3], 2);
        c.create_topic_replicated("t", 4, ReplicationConfig::new(2)).unwrap();
        c.kill_rack(1).unwrap();
        c.rejoin_broker(1).unwrap();
        c.rejoin_broker(3).unwrap();
        let mut probe = SignalProbe::new(c.clone(), "t", "g", None, 1.0);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.rack_skew, 1.0, "every set co-located after the rack bounce");
        c.reassign_replicas().unwrap();
        let s = probe.sample(2.0, 1, 1, 2).unwrap();
        assert_eq!(s.rack_skew, 0.0, "reassignment clears the placement debt");
    }

    #[test]
    fn probe_samples_per_edge_lag_alongside_the_primary_signal() {
        let cluster = BrokerCluster::new(Machine::unthrottled(2), vec![0]);
        cluster.create_topic("in", 1).unwrap();
        cluster.create_topic("hot", 1).unwrap();
        cluster.create_topic("cold", 1).unwrap();
        let mut probe = SignalProbe::new(cluster.clone(), "in", "g-in", None, 1.0).with_edges(
            vec![
                ("in".to_string(), "g-in".to_string()),
                ("hot".to_string(), "g-hot".to_string()),
                ("cold".to_string(), "g-cold".to_string()),
            ],
        );
        // Load one branch only: its edge reads hot, the sibling stays 0.
        for i in 0..6u8 {
            cluster.produce("hot", 0, 1, &[vec![i]]).unwrap();
        }
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert_eq!(s.edge_lags.len(), 3);
        let lag_of = |topic: &str| s.edge_lags.iter().find(|e| e.topic == topic).unwrap().lag;
        assert_eq!(lag_of("in"), 0);
        assert_eq!(lag_of("hot"), 6);
        assert_eq!(lag_of("cold"), 0);

        // A vanished edge topic drops out; the snapshot still samples.
        let mut probe = SignalProbe::new(cluster.clone(), "in", "g-in", None, 1.0)
            .with_edges(vec![("gone".to_string(), "g".to_string())]);
        let s = probe.sample(1.0, 1, 1, 2).unwrap();
        assert!(s.edge_lags.is_empty());
    }

    #[test]
    fn probe_errors_on_unknown_topic() {
        let cluster = BrokerCluster::new(Machine::unthrottled(1), vec![0]);
        let mut probe = SignalProbe::new(cluster, "nope", "g", None, 1.0);
        assert!(probe.sample(1.0, 1, 1, 2).is_err());
    }
}
