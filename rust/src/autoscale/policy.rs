//! Scaling policies: pure decision functions over signal snapshots.
//!
//! A policy never touches the broker or the pilot service; it sees a
//! [`SignalSnapshot`] and answers with a [`ScalingIntent`] ("hold,
//! grow by n, shrink by n, or repartition"), which the
//! [`crate::autoscale::Planner`] then turns into a costed multi-step
//! plan before anything is actuated.
//! That keeps every policy unit-testable and lets the same policy run
//! unchanged on the real plane (the [`super::Autoscaler`] control loop)
//! and in virtual time (the [`crate::sim`] elastic harness at 32-node
//! scale).
//!
//! Three families ship in-tree, mirroring the elasticity literature the
//! design follows (de Assunção et al. 2017's survey taxonomy; Stein et
//! al. 2020's online bin-packing controller):
//!
//! * [`ThresholdPolicy`] — lag thresholds with hysteresis, sustain
//!   counts and a cooldown window (the classic reactive controller);
//! * [`LagSlopePolicy`] — proportional-derivative control on lag and
//!   its slope, sizing the node delta to drain within a horizon;
//! * [`BinPackingPolicy`] — first-fit-decreasing packing of
//!   per-partition work onto node-sized bins.
//!
//! Any of them can be wrapped in [`PartitionElastic`], which turns a
//! scale-up that would exceed the topic's one-task-per-partition cap
//! into a [`ScalingIntent::Repartition`] (resize + extend in one
//! action), removing the §6.4 knee.

use super::signals::SignalSnapshot;

/// What a policy *wants* done with the resource footprint — an intent,
/// not an order.  Intents carry no costs and no broker-tier awareness;
/// the [`crate::autoscale::Planner`] turns each intent into a costed,
/// possibly multi-step [`crate::autoscale::ScalingPlan`] (resizing or
/// deferring a scale-up whose modeled cost cannot pay for itself, and
/// co-scheduling broker extensions when a repartition would
/// oversubscribe per-node I/O budgets) before the controller actuates
/// anything.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingIntent {
    /// No change.
    Hold,
    /// Add `n` processing nodes.
    ScaleUp(usize),
    /// Release `n` processing nodes.
    ScaleDown(usize),
    /// Repartition the watched topic to `partitions` partitions, then
    /// add `scale_up` processing nodes.  Emitted (by
    /// [`PartitionElastic`]) when a scale-up would push task slots past
    /// the one-task-per-partition cap — the §6.4 knee — so the cap
    /// moves with the fleet in the same control action.
    Repartition { partitions: usize, scale_up: usize },
}

/// Pre-planner name for [`ScalingIntent`], kept so existing policies
/// and call sites read naturally during the decision-path migration.
pub use self::ScalingIntent as PolicyDecision;

/// The policy SPI (pluggable; applications can bring their own).
pub trait ScalingPolicy: Send {
    /// Short name recorded on every [`crate::metrics::ScalingEvent`].
    fn name(&self) -> &'static str;

    /// Decide on one signal sample.  Policies carry their own state
    /// (streak counters, cooldown clocks) between calls.
    fn decide(&mut self, signals: &SignalSnapshot) -> ScalingIntent;
}

// ---------------------------------------------------------------------
// Threshold + hysteresis
// ---------------------------------------------------------------------

/// Reactive lag thresholds with hysteresis: grow when lag stays above
/// `up_lag`, shrink when it stays below `down_lag`, hold in between.
/// `sustain` consecutive samples are required on either side (a single
/// burst sample never triggers) and `cooldown_secs` must elapse between
/// actions (no flapping while an extension is still booting).
#[derive(Debug, Clone)]
pub struct ThresholdPolicy {
    pub up_lag: u64,
    pub down_lag: u64,
    /// Consecutive out-of-band samples required before acting.
    pub sustain: usize,
    /// Minimum seconds between actions.
    pub cooldown_secs: f64,
    /// Nodes added/released per action.
    pub step: usize,
    high_streak: usize,
    low_streak: usize,
    last_action_t: f64,
}

impl ThresholdPolicy {
    pub fn new(up_lag: u64, down_lag: u64) -> Self {
        assert!(down_lag < up_lag, "hysteresis band must be non-empty");
        ThresholdPolicy {
            up_lag,
            down_lag,
            sustain: 2,
            cooldown_secs: 1.0,
            step: 1,
            high_streak: 0,
            low_streak: 0,
            last_action_t: f64::NEG_INFINITY,
        }
    }

    pub fn with_sustain(mut self, samples: usize) -> Self {
        self.sustain = samples.max(1);
        self
    }

    pub fn with_cooldown_secs(mut self, secs: f64) -> Self {
        self.cooldown_secs = secs.max(0.0);
        self
    }

    pub fn with_step(mut self, nodes: usize) -> Self {
        self.step = nodes.max(1);
        self
    }
}

impl ScalingPolicy for ThresholdPolicy {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> ScalingIntent {
        if s.lag >= self.up_lag {
            self.high_streak += 1;
            self.low_streak = 0;
        } else if s.lag <= self.down_lag {
            self.low_streak += 1;
            self.high_streak = 0;
        } else {
            // Inside the hysteresis band: hold and reset both streaks.
            self.high_streak = 0;
            self.low_streak = 0;
        }
        if s.t_secs - self.last_action_t < self.cooldown_secs {
            return ScalingIntent::Hold;
        }
        if self.high_streak >= self.sustain && s.nodes < s.max_nodes {
            self.high_streak = 0;
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleUp(self.step.min(s.max_nodes - s.nodes));
        }
        if self.low_streak >= self.sustain && s.nodes > s.min_nodes {
            self.low_streak = 0;
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleDown(self.step.min(s.nodes - s.min_nodes));
        }
        ScalingIntent::Hold
    }
}

// ---------------------------------------------------------------------
// Lag slope (PD control)
// ---------------------------------------------------------------------

/// Proportional-derivative controller on consumer lag: project the lag
/// `horizon_secs` ahead along its observed slope, then size the fleet
/// so the offered rate *plus* the drain of the projected excess fits
/// the observed per-node service rate.
#[derive(Debug, Clone)]
pub struct LagSlopePolicy {
    /// How far ahead to project, and how fast excess lag must drain.
    pub horizon_secs: f64,
    /// Standing lag considered healthy (no drain demand below this).
    pub target_lag: u64,
    pub cooldown_secs: f64,
    last_action_t: f64,
}

impl LagSlopePolicy {
    pub fn new(horizon_secs: f64, target_lag: u64) -> Self {
        LagSlopePolicy {
            horizon_secs: horizon_secs.max(1e-3),
            target_lag,
            cooldown_secs: 1.0,
            last_action_t: f64::NEG_INFINITY,
        }
    }

    pub fn with_cooldown_secs(mut self, secs: f64) -> Self {
        self.cooldown_secs = secs.max(0.0);
        self
    }
}

impl ScalingPolicy for LagSlopePolicy {
    fn name(&self) -> &'static str {
        "lag-slope"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> ScalingIntent {
        let rate_per_node = s.service_rate_per_node;
        if rate_per_node <= 0.0 {
            return ScalingIntent::Hold; // no calibration signal yet
        }
        if s.t_secs - self.last_action_t < self.cooldown_secs {
            return ScalingIntent::Hold;
        }
        // P term: projected lag after the horizon; D enters via the slope.
        let projected = (s.lag as f64 + s.lag_slope.max(0.0) * self.horizon_secs).max(0.0);
        let drain_rate = (projected - self.target_lag as f64).max(0.0) / self.horizon_secs;
        let demand = s.produce_rate + drain_rate;
        let desired = ((demand / rate_per_node).ceil() as usize).clamp(s.min_nodes, s.max_nodes);
        if desired > s.nodes {
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleUp(desired - s.nodes);
        }
        // Only shrink once the backlog has actually drained (hysteresis:
        // a smaller desired fleet alone is not enough mid-burst).
        if desired < s.nodes && s.lag <= self.target_lag {
            self.last_action_t = s.t_secs;
            return ScalingIntent::ScaleDown(s.nodes - desired);
        }
        ScalingIntent::Hold
    }
}

// ---------------------------------------------------------------------
// Online bin-packing (à la Stein et al. 2020)
// ---------------------------------------------------------------------

/// First-fit-decreasing packing of per-partition work onto node-sized
/// bins: each partition's next-window work (its backlog plus its share
/// of the offered rate) is an item; a node is a bin holding
/// `node_capacity_msgs * headroom` messages per window.  The bin count
/// is the target fleet size.
#[derive(Debug, Clone)]
pub struct BinPackingPolicy {
    /// Messages one node can process per window.  `None` derives it
    /// from the observed per-node service rate at decision time.
    pub node_capacity_msgs: Option<f64>,
    /// Fill target per bin (0, 1]; packing to 80% absorbs jitter.
    pub headroom: f64,
    pub cooldown_secs: f64,
    last_action_t: f64,
}

impl BinPackingPolicy {
    pub fn new() -> Self {
        BinPackingPolicy {
            node_capacity_msgs: None,
            headroom: 0.8,
            cooldown_secs: 1.0,
            last_action_t: f64::NEG_INFINITY,
        }
    }

    pub fn with_node_capacity(mut self, msgs_per_window: f64) -> Self {
        self.node_capacity_msgs = Some(msgs_per_window.max(1e-9));
        self
    }

    pub fn with_headroom(mut self, headroom: f64) -> Self {
        self.headroom = headroom.clamp(0.05, 1.0);
        self
    }

    pub fn with_cooldown_secs(mut self, secs: f64) -> Self {
        self.cooldown_secs = secs.max(0.0);
        self
    }

    /// First-fit-decreasing bin count for `items` into bins of `cap`.
    fn ffd_bins(mut items: Vec<f64>, cap: f64) -> usize {
        items.sort_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
        let mut bins: Vec<f64> = Vec::new();
        for item in items {
            // A partition is indivisible (one task per partition): an
            // oversized item still occupies exactly one bin.
            let item = item.min(cap);
            match bins.iter_mut().find(|b| **b + item <= cap) {
                Some(b) => *b += item,
                None => bins.push(item),
            }
        }
        bins.len()
    }
}

impl Default for BinPackingPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl ScalingPolicy for BinPackingPolicy {
    fn name(&self) -> &'static str {
        "bin-packing"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> ScalingIntent {
        let n_parts = s.partition_backlog.len();
        if n_parts == 0 {
            return ScalingIntent::Hold;
        }
        let capacity = self
            .node_capacity_msgs
            .unwrap_or(s.service_rate_per_node * s.window_secs);
        if capacity <= 0.0 {
            return ScalingIntent::Hold;
        }
        if s.t_secs - self.last_action_t < self.cooldown_secs {
            return ScalingIntent::Hold;
        }
        let cap = capacity * self.headroom;
        let arrivals_per_part = s.produce_rate * s.window_secs / n_parts as f64;
        let items: Vec<f64> = s
            .partition_backlog
            .iter()
            .map(|b| *b as f64 + arrivals_per_part)
            .filter(|w| *w > 0.0)
            .collect();
        let target = Self::ffd_bins(items, cap).clamp(s.min_nodes, s.max_nodes);
        if target > s.nodes {
            self.last_action_t = s.t_secs;
            ScalingIntent::ScaleUp(target - s.nodes)
        } else if target < s.nodes {
            self.last_action_t = s.t_secs;
            ScalingIntent::ScaleDown(s.nodes - target)
        } else {
            ScalingIntent::Hold
        }
    }
}

// ---------------------------------------------------------------------
// Partition elasticity (decorator over any inner policy)
// ---------------------------------------------------------------------

/// Wraps any [`ScalingPolicy`] with partition elasticity: when the
/// inner policy asks for a scale-up whose resulting task slots
/// (`nodes * tasks_per_node`) would exceed the topic's partition count
/// — beyond which extra nodes sit idle (§6.4's one-task-per-partition
/// knee) — the decision is upgraded to
/// [`ScalingIntent::Repartition`], resizing the topic to match the
/// target fleet before the extension lands.
#[derive(Debug)]
pub struct PartitionElastic<P: ScalingPolicy> {
    inner: P,
    /// Task slots per processing node (Spark executors per node): the
    /// multiplier between fleet size and useful partition count.
    pub tasks_per_node: usize,
    /// Hard ceiling on the partition count requested.
    pub max_partitions: usize,
}

impl<P: ScalingPolicy> PartitionElastic<P> {
    pub fn new(inner: P, tasks_per_node: usize) -> Self {
        PartitionElastic {
            inner,
            tasks_per_node: tasks_per_node.max(1),
            max_partitions: 4096,
        }
    }

    pub fn with_max_partitions(mut self, max: usize) -> Self {
        self.max_partitions = max.max(1);
        self
    }
}

impl<P: ScalingPolicy> ScalingPolicy for PartitionElastic<P> {
    fn name(&self) -> &'static str {
        "partition-elastic"
    }

    fn decide(&mut self, s: &SignalSnapshot) -> ScalingIntent {
        match self.inner.decide(s) {
            ScalingIntent::ScaleUp(n) => {
                let target_slots = (s.nodes + n) * self.tasks_per_node;
                if target_slots > s.partitions && s.partitions < self.max_partitions {
                    ScalingIntent::Repartition {
                        partitions: target_slots.min(self.max_partitions),
                        scale_up: n,
                    }
                } else {
                    ScalingIntent::ScaleUp(n)
                }
            }
            other => other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A snapshot with the given time/lag/fleet and sane defaults.
    fn snap(t_secs: f64, lag: u64, nodes: usize) -> SignalSnapshot {
        SignalSnapshot {
            t_secs,
            lag,
            lag_slope: 0.0,
            produce_rate: 0.0,
            consume_rate: 0.0,
            partition_backlog: Vec::new(),
            partitions: 8,
            behind_batches: 0,
            last_batch_secs: 0.0,
            window_secs: 1.0,
            nodes,
            min_nodes: 1,
            max_nodes: 8,
            service_rate_per_node: 10.0,
            broker_nodes: 1,
            broker_nic_util: 0.0,
            broker_disk_util: 0.0,
            under_replicated: 0,
            below_min_insync: 0,
            broker_util_skew: 0.0,
            rack_skew: 0.0,
            shard_queue_depths: Vec::new(),
            edge_lags: Vec::new(),
        }
    }

    #[test]
    fn threshold_scales_up_on_sustained_lag_only() {
        let mut p = ThresholdPolicy::new(100, 10).with_sustain(2).with_cooldown_secs(0.0);
        // One high sample is not enough.
        assert_eq!(p.decide(&snap(0.0, 150, 1)), ScalingIntent::Hold);
        // A dip resets the streak.
        assert_eq!(p.decide(&snap(1.0, 5, 1)), ScalingIntent::Hold);
        assert_eq!(p.decide(&snap(2.0, 150, 1)), ScalingIntent::Hold);
        // Second consecutive high sample triggers.
        assert_eq!(p.decide(&snap(3.0, 150, 1)), ScalingIntent::ScaleUp(1));
    }

    #[test]
    fn threshold_hysteresis_band_holds() {
        let mut p = ThresholdPolicy::new(100, 10).with_sustain(1).with_cooldown_secs(0.0);
        // Between the thresholds: never an action, regardless of history.
        for t in 0..10 {
            assert_eq!(p.decide(&snap(t as f64, 50, 4)), ScalingIntent::Hold);
        }
    }

    #[test]
    fn threshold_cooldown_prevents_flapping() {
        let mut p = ThresholdPolicy::new(100, 10).with_sustain(1).with_cooldown_secs(5.0);
        assert_eq!(p.decide(&snap(0.0, 200, 1)), ScalingIntent::ScaleUp(1));
        // Still hot, but inside the cooldown window.
        assert_eq!(p.decide(&snap(1.0, 200, 2)), ScalingIntent::Hold);
        assert_eq!(p.decide(&snap(4.9, 200, 2)), ScalingIntent::Hold);
        // Cooldown elapsed.
        assert_eq!(p.decide(&snap(6.0, 200, 2)), ScalingIntent::ScaleUp(1));
    }

    #[test]
    fn threshold_scales_down_after_drain_and_clamps() {
        let mut p = ThresholdPolicy::new(100, 10)
            .with_sustain(2)
            .with_cooldown_secs(0.0)
            .with_step(4);
        assert_eq!(p.decide(&snap(0.0, 0, 3)), ScalingIntent::Hold);
        // Step is clamped to the min-node floor.
        assert_eq!(p.decide(&snap(1.0, 0, 3)), ScalingIntent::ScaleDown(2));
        // At the floor nothing happens.
        assert_eq!(p.decide(&snap(2.0, 0, 1)), ScalingIntent::Hold);
        assert_eq!(p.decide(&snap(3.0, 0, 1)), ScalingIntent::Hold);
        // At the ceiling scale-up is clamped too.
        let mut q = ThresholdPolicy::new(100, 10)
            .with_sustain(1)
            .with_cooldown_secs(0.0)
            .with_step(4);
        assert_eq!(q.decide(&snap(0.0, 500, 6)), ScalingIntent::ScaleUp(2));
        assert_eq!(q.decide(&snap(1.0, 500, 8)), ScalingIntent::Hold);
    }

    #[test]
    fn lag_slope_sizes_delta_to_demand() {
        let mut p = LagSlopePolicy::new(2.0, 5).with_cooldown_secs(0.0);
        // 35 msg/s offered + (100 - 5)/2 = 47.5 msg/s of drain demand
        // over the 2 s horizon -> ceil(82.5/10) = 9, clamped to max 8.
        let mut s = snap(0.0, 100, 2);
        s.produce_rate = 35.0;
        assert_eq!(p.decide(&s), ScalingIntent::ScaleUp(6));
        // Drained and the offered load fits one node: shrink.
        let mut s = snap(1.0, 0, 8);
        s.produce_rate = 8.0;
        assert_eq!(p.decide(&s), ScalingIntent::ScaleDown(7));
        // Desired < nodes but lag still above target: hold (hysteresis).
        let mut s = snap(2.0, 50, 8);
        s.produce_rate = 8.0;
        assert_eq!(p.decide(&s), ScalingIntent::Hold);
        // No calibration signal: hold.
        let mut s = snap(3.0, 1000, 1);
        s.service_rate_per_node = 0.0;
        assert_eq!(p.decide(&s), ScalingIntent::Hold);
    }

    #[test]
    fn bin_packing_counts_bins_first_fit_decreasing() {
        // 6 partitions of 10 messages each into 25-message bins (after
        // headroom 1.0): FFD packs 2 per bin -> 3 nodes.
        let mut p = BinPackingPolicy::new()
            .with_node_capacity(25.0)
            .with_headroom(1.0)
            .with_cooldown_secs(0.0);
        let mut s = snap(0.0, 60, 1);
        s.partition_backlog = vec![10; 6];
        assert_eq!(p.decide(&s), ScalingIntent::ScaleUp(2));
        // Empty partitions pack to the floor -> shrink back.
        let mut s = snap(1.0, 0, 3);
        s.partition_backlog = vec![0; 6];
        assert_eq!(p.decide(&s), ScalingIntent::ScaleDown(2));
        // An oversized partition cannot split across bins: it fills one
        // bin, the two small items share another -> 2 bins.
        let mut s = snap(2.0, 110, 3);
        s.partition_backlog = vec![90, 10, 10];
        assert_eq!(p.decide(&s), ScalingIntent::ScaleDown(1));
    }

    #[test]
    fn bin_packing_oversized_item_occupies_one_bin() {
        assert_eq!(BinPackingPolicy::ffd_bins(vec![90.0, 10.0, 10.0], 25.0), 2);
        assert_eq!(BinPackingPolicy::ffd_bins(vec![10.0; 6], 25.0), 3);
        assert_eq!(BinPackingPolicy::ffd_bins(Vec::new(), 25.0), 0);
    }

    #[test]
    fn partition_elastic_upgrades_capped_scale_ups() {
        let inner = ThresholdPolicy::new(100, 10).with_sustain(1).with_cooldown_secs(0.0);
        let mut p = PartitionElastic::new(inner, 2);
        // 2 partitions, scale 1 -> 3 nodes: 6 task slots > 2 partitions.
        let mut s = snap(0.0, 500, 1);
        s.partitions = 2;
        let mut q = ThresholdPolicy::new(100, 10).with_sustain(1).with_cooldown_secs(0.0);
        let inner_says = q.decide(&s);
        let ScalingIntent::ScaleUp(n) = inner_says else {
            panic!("inner policy should scale up, got {inner_says:?}");
        };
        assert_eq!(
            p.decide(&s),
            ScalingIntent::Repartition { partitions: (1 + n) * 2, scale_up: n }
        );
        // Enough partitions already: the decision passes through.
        let mut s = snap(1.0, 500, 1);
        s.partitions = 64;
        assert_eq!(p.decide(&s), ScalingIntent::ScaleUp(n));
    }

    #[test]
    fn partition_elastic_respects_ceiling_and_forwards_others() {
        let inner = ThresholdPolicy::new(100, 10).with_sustain(1).with_cooldown_secs(0.0);
        let mut p = PartitionElastic::new(inner, 4).with_max_partitions(6);
        let mut s = snap(0.0, 500, 1);
        s.partitions = 2;
        // Target slots 8 clamps to the 6-partition ceiling.
        assert_eq!(
            p.decide(&s),
            ScalingIntent::Repartition { partitions: 6, scale_up: 1 }
        );
        // At the ceiling: plain scale-up (repartition can't help more).
        let mut s = snap(1.0, 500, 1);
        s.partitions = 6;
        assert_eq!(p.decide(&s), ScalingIntent::ScaleUp(1));
        // Hold (inside the hysteresis band) passes through untouched.
        let mut s = snap(2.0, 50, 4);
        s.partitions = 2;
        assert_eq!(p.decide(&s), ScalingIntent::Hold);
        // So does a scale-down (never upgraded to a repartition).
        let mut s = snap(3.0, 0, 4);
        s.partitions = 2;
        assert_eq!(p.decide(&s), ScalingIntent::ScaleDown(1));
    }
}
